"""Version-tolerant accessors over Pallas TPU API drift.

``jax.experimental.pallas.tpu`` renamed its compiler-params container
across releases: older releases expose ``TPUCompilerParams``, newer ones
``CompilerParams`` (and the oldest accept a plain ``dict``).  Every
kernel in this package routes through :func:`tpu_compiler_params` so the
same source runs on whichever jax the environment bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under whichever name this
    jax release exports.  Falls back to a plain dict (the pre-dataclass
    API) and finally to ``None`` (interpret mode ignores the hints)."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:                      # pragma: no cover - ancient jax
        return dict(mosaic=dict(kwargs))
    return cls(**kwargs)
