"""Jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H|K, D), expands GQA, folds heads
into the batch grid dimension, and dispatches to the Pallas kernel
(interpret=True on CPU; compiled on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.models.layers import expand_kv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D).  Returns (B, Sq, H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, D = q.shape
    k = expand_kv(k, H)
    v = expand_kv(v, H)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
