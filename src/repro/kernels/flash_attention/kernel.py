"""Flash-attention prefill kernel (Pallas TPU).

Canonical 3-level grid (batch*heads, q_blocks, kv_blocks) with the kv
dimension sequential ("arbitrary") so the online-softmax state lives in
VMEM scratch between kv steps.  Block shapes are MXU-aligned (q/kv block
multiples of 128 recommended; head_dim 64/128).

HBM->VMEM traffic per program: one (bq, D) q tile + one (bk, D) k tile +
one (bk, D) v tile; the (bq, bk) score tile never leaves VMEM — this is
the IO-awareness the TPU adaptation keeps from FlashAttention, with
systolic-MXU-sized tiles instead of warp-level SRAM staging.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, window: int,
                  scale: float, nk: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                  # (bq, D)
    k = k_ref[0]                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(p.astype(v_ref.dtype), v_ref[0],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q,k,v: (BH, S, D) with identical head counts (GQA pre-expanded).
    Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=scale, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
