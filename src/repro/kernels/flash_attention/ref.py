"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from repro.models.layers import attention_dense


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, D); k/v: (B, Sk, K, D) — dense softmax attention."""
    return attention_dense(q, k, v, causal=causal, window=window)
