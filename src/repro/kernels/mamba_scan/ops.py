"""Jit'd public wrapper for the mamba selective-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_btd


@functools.partial(jax.jit, static_argnames=("block_d", "chunk",
                                             "interpret"))
def mamba_scan(x, dt, Bc, Cc, A_log, D, *, block_d: int = 256,
               chunk: int = 64, interpret: bool | None = None):
    """x, dt: (B, T, di); Bc, Cc: (B, T, ds); A_log: (di, ds); D: (di,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return mamba_scan_btd(x, dt, Bc, Cc, A_log, D, block_d=block_d,
                          chunk=chunk, interpret=interpret)
