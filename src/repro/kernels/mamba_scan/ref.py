"""Pure-jnp oracle for the mamba scan kernel.

Exact f32 recurrence (the model's production scan in
``repro.models.mamba`` additionally rounds per-step outputs to bf16 to
halve activation memory; the kernel keeps f32, so the oracle here stays
f32 too and the bf16 variant is checked with a looser tolerance in the
tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def mamba_scan_ref(x, dt, Bc, Cc, A_log, D):
    """x, dt: (B, T, di); Bc, Cc: (B, T, ds); A_log: (di, ds); D: (di,).
    Returns y (B, T, di) f32."""
    B, T, di = x.shape
    ds = Bc.shape[-1]
    A = -jnp.exp(A_log.astype(F32))

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt.astype(F32)[:, :, None] * A[None])
        dBx = (dtt * xt).astype(F32)[:, :, None] * bt.astype(F32)[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bis,bs->bi", h, ct.astype(F32))
        return h, y

    h0 = jnp.zeros((B, di, ds), F32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    _, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(F32) * D.astype(F32)[None, None]
    return y
