"""Selective-SSM scan kernel (Pallas TPU) — Mamba's recurrence.

Grid (B, d_inner/bd, T/chunk): channels are parallel (each program owns
a (bd, d_state) state tile in VMEM), time chunks are sequential.  The
(bd, d_state) per-channel state never leaves VMEM between chunks; the
discretized dA/dBx products are computed on the VPU per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _mamba_kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, o_ref,
                  h_ref, *, chunk: int, bd: int, ds: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = -jnp.exp(alog_ref[...].astype(jnp.float32))   # (bd, ds)
    D = d_ref[...].astype(jnp.float32)                # (bd,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)          # (bd,)
        dtt = dt_ref[0, t].astype(jnp.float32)        # (bd,)
        bt = b_ref[0, t].astype(jnp.float32)          # (ds,)
        ct = c_ref[0, t].astype(jnp.float32)          # (ds,)
        dA = jnp.exp(dtt[:, None] * A)                # (bd, ds)
        h = dA * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + D * xt
        o_ref[0, t] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def mamba_scan_btd(x, dt, Bc, Cc, A_log, D, *, block_d: int = 256,
                   chunk: int = 64, interpret: bool = True):
    """x, dt: (B, T, di); Bc, Cc: (B, T, ds); A_log: (di, ds); D: (di,).
    Returns y: (B, T, di) f32 (without gating)."""
    B, T, di = x.shape
    ds = Bc.shape[-1]
    bd = min(block_d, di)
    c = min(chunk, T)
    assert di % bd == 0 and T % c == 0, (di, bd, T, c)

    kernel = functools.partial(_mamba_kernel, chunk=c, bd=bd, ds=ds)
    return pl.pallas_call(
        kernel,
        grid=(B, di // bd, T // c),
        in_specs=[
            pl.BlockSpec((1, c, bd), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, c, bd), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, c, ds), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((1, c, ds), lambda b, d, j: (b, j, 0)),
            pl.BlockSpec((bd, ds), lambda b, d, j: (d, 0)),
            pl.BlockSpec((bd,), lambda b, d, j: (d,)),
        ],
        out_specs=pl.BlockSpec((1, c, bd), lambda b, d, j: (b, j, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, Bc, Cc, A_log, D)
