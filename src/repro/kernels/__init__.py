"""Pallas TPU kernels for the serving substrate's compute hot spots.

SAGA itself is a scheduler (no kernel-level contribution), but its
substrate's hot loops are exactly the ops the serving stack spends its
FLOPs on.  Four kernels, each with kernel.py (pl.pallas_call + explicit
BlockSpec VMEM tiling), ops.py (jit'd wrapper), ref.py (pure-jnp oracle):

  flash_attention/  prefill: online-softmax tiled causal/GQA/SWA attention
  paged_attention/  decode: block-table-indirected flash decoding
                    (PagedAttention adapted to TPU scalar prefetch)
  rwkv6/            WKV6 data-dependent-decay recurrence (chunked)
  mamba_scan/       selective-SSM scan (chunked)

All are validated in interpret=True mode on CPU against ref.py across
shape/dtype sweeps (tests/test_kernels.py).
"""
