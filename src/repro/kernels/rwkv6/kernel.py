"""WKV6 recurrence kernel (Pallas TPU) — data-dependent-decay linear
attention (RWKV-6 "Finch").

Grid (B*H, T/chunk): the chunk dimension is sequential with the
(dk, dv) state matrix resident in VMEM scratch between chunks — the
HBM<->VMEM traffic is exactly one (chunk, dh) tile per operand per
step, and the state never spills.  Inside a chunk the recurrence is a
fori loop of rank-1 updates; dh=64 keeps each update a single
(64, 64) VPU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
                 chunk: int, dh: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0]                              # (dh,)

    def step(t, state):
        rt = r_ref[0, t].astype(jnp.float32)  # (dh,)
        kt = k_ref[0, t].astype(jnp.float32)
        vt = v_ref[0, t].astype(jnp.float32)
        wt = w_ref[0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]        # (dk, dv)
        out = ((state + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        o_ref[0, t] = out.astype(o_ref.dtype)
        return wt[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_ref[...])
    state_ref[...] = state


def wkv6_bht(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: (BH, T, dh); u: (BH, dh).  Returns (BH, T, dh) f32."""
    BH, T, dh = r.shape
    c = min(chunk, T)
    assert T % c == 0, (T, c)
    nchunks = T // c

    kernel = functools.partial(_wkv6_kernel, chunk=c, dh=dh)
    return pl.pallas_call(
        kernel,
        grid=(BH, nchunks),
        in_specs=[
            pl.BlockSpec((1, c, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c, dh), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, dh), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dh), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
