"""Jit'd public wrapper for the WKV6 kernel (model layout in/out)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_bht


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 64, interpret: bool | None = None):
    """r,k,v,w: (B, T, H, dh); u: (H, dh) -> (B, T, H, dh) f32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, H, dh = r.shape

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, dh)

    uf = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, dh)
    o = wkv6_bht(fold(r), fold(k), fold(v), fold(w), uf, chunk=chunk,
                 interpret=interpret)
    return o.reshape(B, H, T, dh).transpose(0, 2, 1, 3)
