"""Pure-jnp oracle for the WKV6 kernel (the model's own scan)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.rwkv import _wkv6_scan


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (B, T, H, dh); u: (H, dh).  Returns (B, T, H, dh) f32."""
    B = r.shape[0]
    out, _ = _wkv6_scan(r, k, v, w, u,
                        jnp.zeros((B, r.shape[2], r.shape[3], v.shape[3]),
                                  jnp.float32))
    return out
