"""Paged-attention decode kernel (Pallas TPU).

PagedAttention's pointer-chasing gather is re-thought for TPU: the block
table rides in scalar-prefetch memory (SMEM) so the index_map can stream
exactly the KV pages a sequence owns from HBM into VMEM, page by page,
while the MXU consumes the previous page (automatic double-buffering
from the sequential grid).  No warp-level gather exists on TPU — the
indirection lives entirely in the grid's index_map, which is the
idiomatic TPU equivalent.

Layout: one layer's pool (num_blocks, block, K, dh); query (B, H, dh);
grid (B, max_blocks_per_seq), second dim sequential with online-softmax
state in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, block: int, n_kv: int,
                  groups: int, dh: int, nb: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].reshape(n_kv, groups, dh)            # (K, G, dh)
    k = k_ref[0].transpose(1, 0, 2)                   # (K, block, dh)
    v = v_ref[0]
    # batched over kv heads: (K, G, dh) x (K, block, dh) -> (K, G, block)
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale   # (K, G, block)

    length = lens_ref[b]
    tok = j * block + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, groups, block), 2)
    mask = tok < length
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask, p, 0.0)                       # (K, G, block)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new
    # pv: for each kv head: (G, block) @ (block, dh)
    pv = jax.lax.dot_general(
        p.astype(v.dtype).transpose(0, 1, 2),
        v.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (K, G, dh)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(j == nb - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(n_kv * groups, dh).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lens, *,
                           interpret: bool = True):
    """q: (B, H, dh); pools: (num_blocks, block, K, dh);
    block_tables: (B, nb) int32; lens: (B,) int32 -> (B, H, dh)."""
    B, H, dh = q.shape
    num_blocks, block, K, _ = k_pool.shape
    G = H // K
    nb = block_tables.shape[1]
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(_paged_kernel, block=block, n_kv=K,
                               groups=G, dh=dh, nb=nb, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, j, T, L: (b, 0, 0)),
            pl.BlockSpec((1, block, K, dh),
                         lambda b, j, T, L: (T[b, j], 0, 0, 0)),
            pl.BlockSpec((1, block, K, dh),
                         lambda b, j, T, L: (T[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, j, T, L: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, lens, q, k_pool, v_pool)
