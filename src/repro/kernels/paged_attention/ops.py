"""Jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.kernel import paged_decode_attention


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, block_tables, lens,
                    interpret: bool | None = None):
    """q: (B, H, dh); pools: (num_blocks, block, K, dh);
    block_tables: (B, nb) int32; lens: (B,) int32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lens,
                                  interpret=interpret)
