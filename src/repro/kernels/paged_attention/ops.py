"""Jit'd public wrappers for paged decode attention.

``paged_attention`` is the single-layer kernel entry (Pallas on TPU,
interpret mode elsewhere).  ``paged_decode_step`` is the batched
multi-layer entry the serving layout uses: it dynamic-updates the new
step's K/V into each session's current tail block of the
(L, num_blocks, block, K, dh) pool arrays, then attends every layer
over the block tables — append + attend in one jitted call, no
contiguous copy of parked KV anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, block_tables, lens,
                    interpret: bool | None = None):
    """q: (B, H, dh); pools: (num_blocks, block, K, dh);
    block_tables: (B, nb) int32; lens: (B,) int32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lens,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_step(q, k_new, v_new, k_pool, v_pool, block_tables,
                      lens, append_blocks, append_offsets,
                      interpret: bool | None = None):
    """Batched multi-layer paged decode: append the step's K/V, then
    attend over block tables, for all L layers in one call.

    q: (L, B, H, dh) — per-layer queries for the new token;
    k_new/v_new: (L, B, K, dh) — the new token's per-layer K/V;
    k_pool/v_pool: (L, num_blocks, block, K, dh);
    block_tables: (B, nb) int32; lens: (B,) int32 token counts
    INCLUDING the new token; append_blocks/append_offsets: (B,) int32
    destination of the new token (an out-of-range block id is a drop
    sentinel for idle batch rows).

    Returns (out (L, B, H, dh), k_pool, v_pool) with the pools updated
    in place of the tail blocks only — parked KV never moves.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kp = k_pool.at[:, append_blocks, append_offsets].set(
        k_new.astype(k_pool.dtype), mode="drop")
    vp = v_pool.at[:, append_blocks, append_offsets].set(
        v_new.astype(v_pool.dtype), mode="drop")
    outs = [paged_decode_attention(q[l], kp[l], vp[l], block_tables,
                                   lens, interpret=interpret)
            for l in range(q.shape[0])]
    return jnp.stack(outs), kp, vp
