"""Pure-jnp oracle: gather pages to contiguous KV, run dense decode."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import decode_attention


def paged_decode_ref(q, k_pool, v_pool, block_tables, lens):
    """q: (B, H, dh); pools: (num_blocks, block, K, dh);
    block_tables: (B, nb); lens: (B,).  Returns (B, H, dh)."""
    B, H, dh = q.shape
    _, block, K, _ = k_pool.shape
    k = k_pool[block_tables]            # (B, nb, block, K, dh)
    v = v_pool[block_tables]
    k = k.reshape(B, -1, K, dh)
    v = v.reshape(B, -1, K, dh)
    out = decode_attention(q[:, None], k, v, lens - 1)
    return out[:, 0]
