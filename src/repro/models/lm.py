"""Decoder-only LM assembly: init, sharding rules, train / prefill / decode.

Families handled here: dense, moe, vlm (patch-prefix), hybrid (jamba
superblocks), ssm (rwkv6).  Encoder-decoder (seamless) lives in
``repro.models.encdec`` and is dispatched via ``repro.models.api``.

Conventions:
  * params are bf16; math accumulates in f32 where it matters.
  * uniform archs scan over stacked layer params; jamba scans over
    superblocks of ``attn_period`` python-unrolled slots.
  * caches: dense/moe/vlm {k,v}: (L,B,Smax,K,dh); MLA {ckv,krope};
    hybrid adds {conv,ssm}; rwkv {wkv,shift_tm,shift_cm}.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, ATTN, MAMBA
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.sharding import ShardingEnv

F32 = jnp.float32
BF16 = jnp.bfloat16


# ===========================================================================
# init
# ===========================================================================
def _dense(key, shape, scale=0.02):
    return (jax.random.normal(key, shape, dtype=F32) * scale).astype(BF16)


def _keys(key, n):
    return jax.random.split(key, n)


def _init_attn(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = _keys(key, 8)
    if cfg.use_mla:
        nope, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        p = {
            "wdq": _dense(ks[0], (d, cfg.q_lora_rank)),
            "q_ln": jnp.ones((cfg.q_lora_rank,), BF16),
            "wuq": _dense(ks[1], (cfg.q_lora_rank, H, nope + rd)),
            "wdkv": _dense(ks[2], (d, cfg.kv_lora_rank + rd)),
            "kv_ln": jnp.ones((cfg.kv_lora_rank,), BF16),
            "wukv": _dense(ks[3], (cfg.kv_lora_rank, H, nope + vd)),
            "wo": _dense(ks[4], (H, vd, d)),
        }
        return p
    p = {
        "wq": _dense(ks[0], (d, H, dh)),
        "wk": _dense(ks[1], (d, K, dh)),
        "wv": _dense(ks[2], (d, K, dh)),
        "wo": _dense(ks[3], (H, dh, d)),
    }
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((dh,), BF16)
        p["knorm"] = jnp.ones((dh,), BF16)
    return p


def _init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = _keys(key, 3)
    return {"w1": _dense(ks[0], (d, f)), "w3": _dense(ks[1], (d, f)),
            "w2": _dense(ks[2], (f, d))}


def _init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = _keys(key, 7)
    p = {
        "router": _dense(ks[0], (d, E)),
        "w1": _dense(ks[1], (E, d, f)),
        "w3": _dense(ks[2], (E, d, f)),
        "w2": _dense(ks[3], (E, f, d)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["ws1"] = _dense(ks[4], (d, fs))
        p["ws3"] = _dense(ks[5], (d, fs))
        p["ws2"] = _dense(ks[6], (fs, d))
    return p


def _init_mamba(key, cfg: ModelConfig):
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr = cfg.dt_rank
    ks = _keys(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=F32)[None, :], (di, ds))
    return {
        "in_proj": _dense(ks[0], (d, 2 * di)),
        "conv_w": _dense(ks[1], (di, cfg.mamba_d_conv), 0.2),
        "conv_b": jnp.zeros((di,), BF16),
        "x_proj": _dense(ks[2], (di, dtr + 2 * ds)),
        "dt_w": _dense(ks[3], (dtr, di)),
        "dt_b": jnp.full((di,), -4.6, BF16),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), BF16),
        "out_proj": _dense(ks[4], (di, d)),
    }


def _init_rwkv(key, cfg: ModelConfig):
    d, H, hs, f = cfg.d_model, cfg.rwkv_n_heads, cfg.rwkv_head_size, cfg.d_ff
    ks = _keys(key, 12)
    dec = -5.0 + 8.0 * (jnp.arange(d, dtype=F32) / max(d - 1, 1)) ** 0.7
    tm = {
        "maa_x": jnp.zeros((d,), BF16), "maa_w": jnp.zeros((d,), BF16),
        "maa_k": jnp.zeros((d,), BF16), "maa_v": jnp.zeros((d,), BF16),
        "maa_r": jnp.zeros((d,), BF16), "maa_g": jnp.zeros((d,), BF16),
        "maa_w1": _dense(ks[0], (d, 5 * R.DDLERP_W), 0.01),
        "maa_w2": _dense(ks[1], (5, R.DDLERP_W, d), 0.01),
        "decay": dec.astype(BF16),
        "decay_w1": _dense(ks[2], (d, R.DECAY_W), 0.01),
        "decay_w2": _dense(ks[3], (R.DECAY_W, d), 0.01),
        "faaaa": _dense(ks[4], (H, hs), 0.5),
        "Wr": _dense(ks[5], (d, d)), "Wk": _dense(ks[6], (d, d)),
        "Wv": _dense(ks[7], (d, d)), "Wg": _dense(ks[8], (d, d)),
        "Wo": _dense(ks[9], (d, d)),
        "ln_x": jnp.ones((d,), BF16),
    }
    cm = {
        "cmix_maa_k": jnp.zeros((d,), BF16),
        "cmix_maa_r": jnp.zeros((d,), BF16),
        "Wck": _dense(ks[10], (d, f)),
        "Wcv": _dense(ks[11], (f, d)),
        "Wcr": _dense(ks[0], (d, d)),
    }
    return {"tm": tm, "cm": cm}


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.init_params(cfg, key)
    d = cfg.d_model
    k_emb, k_un, k_layers = _keys(key, 3)
    params: Dict[str, Any] = {
        "embed": _dense(k_emb, (cfg.vocab, d)),
        "final_norm": jnp.ones((d,), BF16),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense(k_un, (d, cfg.vocab))

    if cfg.family == "ssm":
        ls = []
        for i in range(cfg.n_layers):
            kk = jax.random.fold_in(k_layers, i)
            blk = _init_rwkv(kk, cfg)
            blk["ln1"] = jnp.ones((d,), BF16)
            blk["ln2"] = jnp.ones((d,), BF16)
            ls.append(blk)
        params["layers"] = _stack(ls)
        return params

    if cfg.attn_period:   # jamba superblocks
        per = cfg.attn_period
        nsb = cfg.n_layers // per
        sbs = []
        for s in range(nsb):
            kk = jax.random.fold_in(k_layers, s)
            sb: Dict[str, Any] = {}
            sb["attn"] = _init_attn(jax.random.fold_in(kk, 0), cfg)
            sb["attn_ln"] = jnp.ones((d,), BF16)
            mams, moes, ffns = [], [], []
            for slot in range(per):
                kk2 = jax.random.fold_in(kk, 100 + slot)
                gi = s * per + slot
                if cfg.layer_kind(gi) == MAMBA:
                    mams.append(_init_mamba(kk2, cfg))
                if cfg.layer_is_moe(gi):
                    moes.append(_init_moe(jax.random.fold_in(kk2, 1), cfg))
                else:
                    ffns.append(_init_ffn(jax.random.fold_in(kk2, 2), cfg))
            sb["mamba"] = _stack(mams)
            sb["mamba_ln"] = jnp.ones((len(mams), d), BF16)
            sb["moe"] = _stack(moes)
            sb["moe_ln"] = jnp.ones((len(moes), d), BF16)
            sb["ffn"] = _stack(ffns)
            sb["ffn_ln"] = jnp.ones((len(ffns), d), BF16)
            sbs.append(sb)
        params["superblocks"] = _stack(sbs)
        return params

    # uniform decoder (dense / moe / vlm)
    ls = []
    for i in range(cfg.n_layers):
        kk = jax.random.fold_in(k_layers, i)
        blk = {
            "ln1": jnp.ones((d,), BF16),
            "ln2": jnp.ones((d,), BF16),
            "attn": _init_attn(jax.random.fold_in(kk, 0), cfg),
        }
        if cfg.layer_is_moe(i):
            blk["mlp"] = _init_moe(jax.random.fold_in(kk, 1), cfg)
        else:
            blk["mlp"] = _init_ffn(jax.random.fold_in(kk, 1), cfg)
        ls.append(blk)
    params["layers"] = _stack(ls)
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ===========================================================================
# sharding rules
# ===========================================================================
_COL = {"w1", "w3", "wdq", "wdkv", "in_proj", "x_proj", "dt_w", "ws1",
        "ws3", "Wr", "Wk", "Wv", "Wg", "Wck", "Wcr", "maa_w1", "decay_w1"}
_ROW = {"w2", "out_proj", "ws2", "Wo", "Wcv", "decay_w2"}


def param_rules(cfg: ModelConfig, env: ShardingEnv):
    """rules(path, shape) -> per-dim axis wish list (divisibility-pruned
    later by ShardingEnv.spec)."""
    fsdp, tp = env.fsdp_axis, env.tp_axis

    def rules(path: str, shape):
        name = path.split("/")[-1]
        rank = len(shape)
        if name == "embed":
            base = [tp, None]
        elif name == "unembed":
            base = [None, tp]
        elif name in ("conv_w", "A_log"):
            base = [tp, None]
        elif name in ("conv_b", "D", "dt_b"):
            base = [tp]
        elif name == "faaaa":
            base = [tp, None]
        elif name == "router":
            base = [fsdp, None]
        elif name in ("wq", "wuq", "wukv"):
            # (d|r, H, dh): shard heads over tp if divisible, else head_dim
            if env.heads_shardable(cfg.n_heads):
                base = [fsdp, tp, None]
            else:
                base = [fsdp, None, tp]
        elif name in ("wk", "wv"):
            base = [fsdp, None, None]          # kv heads replicated over tp
        elif name == "wo":
            if env.heads_shardable(cfg.n_heads):
                base = [tp, None, fsdp]
            else:
                base = [None, tp, fsdp]
        elif name in _COL:
            base = [fsdp, tp]
        elif name in _ROW:
            base = [tp, fsdp]
        else:
            base = [None] * min(rank, 2)
        if name in ("w1", "w3", "w2") and rank - _n_stack(path) == 3:
            # MoE expert weights
            ep = env.moe_ep(cfg.n_experts)
            if name == "w2":
                base = [tp, None, fsdp] if ep else [None, tp, fsdp]
            else:
                base = [tp, fsdp, None] if ep else [None, fsdp, tp]
        pad = rank - len(base)
        return [None] * pad + base

    return rules


def _n_stack(path: str) -> int:
    n = 0
    if path.startswith("layers/") or "/layers/" in path:
        n = 1
    if "superblocks" in path:
        parts = path.split("/")
        n = 1 + (1 if parts[-2] in ("mamba", "moe", "ffn") else 0)
    return n


def param_shardings(cfg: ModelConfig, env: ShardingEnv):
    from repro.models.sharding import param_pspecs
    return param_pspecs(abstract_params(cfg), env, param_rules(cfg, env))


# ===========================================================================
# embedding / logits / loss
# ===========================================================================
def embed_tokens(params, tokens, cfg):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)


def chunked_xent(params, x, labels, cfg, env: ShardingEnv):
    """Scan-chunked softmax cross-entropy (labels -100 are masked)."""
    B, S, d = x.shape
    c = L._pick_block(S, env.opts.get("loss_chunk", 512))
    n = S // c
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

    def body(carry, i):
        tot, cnt = carry
        xs = lax.dynamic_slice_in_dim(x, i * c, c, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", xs, w,
                            preferred_element_type=F32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        safe = jnp.maximum(ls, 0)
        lab = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = (ls >= 0).astype(F32)
        tot = tot + jnp.sum((lse - lab) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(jax.checkpoint(body),
                             (jnp.zeros((), F32), jnp.zeros((), F32)),
                             jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


# ===========================================================================
# layer stacks
# ===========================================================================
def layer_scan(body, carry, xs, env: ShardingEnv):
    """lax.scan over stacked layers, or a python unroll when
    env.opts['unroll_layers'] is set.

    The dry-run unrolls: XLA's HLO cost analysis counts a while-loop body
    ONCE regardless of trip count, so scanned models under-report
    flops/bytes/collectives by ~n_layers.  Unrolling restores exact
    accounting (and lets XLA schedule across layer boundaries).
    """
    if env.opts.get("unroll_layers", False):
        L = jax.tree_util.tree_leaves(xs)[0].shape[0]
        ys = []
        for i in range(L):
            sl = jax.tree_util.tree_map(lambda a: a[i], xs)
            carry, y = body(carry, sl)
            ys.append(y)
        if ys and ys[0] is not None:
            ys_out = jax.tree_util.tree_map(lambda *z: jnp.stack(z), *ys)
        else:
            ys_out = None
        return carry, ys_out
    return lax.scan(body, carry, xs)


def _res_cs(x, env, sp: bool):
    # pin the residual stream's bf16 rounding so the prefill/full and
    # decode graphs see bit-identical layer inputs (see L.pin_bf16)
    return env.cs(L.pin_bf16(x), env.batch_axes,
                  "model" if sp else None, None)


def _maybe_remat(fn, env):
    if not env.opts.get("remat", False):
        return fn
    policy = None
    if env.opts.get("remat_policy") == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(fn, policy=policy)


def _uniform_block(x, lp, cfg, env, positions, *, collect_kv=False):
    opts = env.opts
    sp = opts.get("sp", True)
    bwd_safe = not collect_kv            # train path recomputes attn in bwd
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        y, c1, c2 = L.mla_attention_full(h, lp["attn"], cfg, env, positions,
                                         attn_mode=opts.get("attn_mode", "full"),
                                         bwd_safe=bwd_safe)
    else:
        y, c1, c2 = L.gqa_attention_full(h, lp["attn"], cfg, env, positions,
                                         attn_mode=opts.get("attn_mode", "full"),
                                         bwd_safe=bwd_safe)
    # constrain the contraction OUTPUT (not just the residual) so XLA can
    # lower the tensor-parallel all-reduce as a reduce-scatter into the
    # sequence-parallel layout (half the ICI bytes)
    y = _res_cs(y, env, sp)
    x = _res_cs(x + y, env, sp)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "router" in lp["mlp"]:
        y = L.moe_block(h, lp["mlp"], cfg, env,
                        impl=opts.get("moe_impl", "ep"))
    else:
        y = L.ffn_swiglu(h, lp["mlp"], env)
    y = _res_cs(y, env, sp)
    x = _res_cs(x + y, env, sp)
    if collect_kv:
        c1 = env.cs(c1, env.batch_axes, "model", *([None] * (c1.ndim - 2)))
        c2 = env.cs(c2, env.batch_axes, "model", *([None] * (c2.ndim - 2)))
        return x, (c1, c2)
    return x, None


def _run_uniform(params, x, cfg, env, positions, *, collect_kv=False):
    def body(x, lp):
        return _uniform_block(x, lp, cfg, env, positions,
                              collect_kv=collect_kv)
    x, kv = layer_scan(_maybe_remat(body, env), x, params["layers"], env)
    return x, kv


def _uniform_decode_block(x, lp, kc, vc, cfg, env, pos):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        y, kc, vc = L.mla_attention_decode(h, lp["attn"], cfg, env, kc, vc, pos)
    else:
        y, kc, vc = L.gqa_attention_decode(h, lp["attn"], cfg, env, kc, vc, pos)
    # pin the sublayer output AND the residual add, mirroring
    # _uniform_block's _res_cs(y) / _res_cs(x + y) pair exactly, so
    # decode and prefill round the stream identically (L.pin_bf16)
    x = L.pin_bf16(x + L.pin_bf16(y))
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "router" in lp["mlp"]:
        y = L.moe_block(h, lp["mlp"], cfg, env,
                        impl=env.opts.get("moe_impl", "ep"))
    else:
        y = L.ffn_swiglu(h, lp["mlp"], env)
    return L.pin_bf16(x + L.pin_bf16(y)), kc, vc


def _uniform_decode_block_paged(x, lp, kp, vp, tables, pos, block_ids,
                                offsets, cfg, env):
    """Twin of ``_uniform_decode_block`` attending over pool blocks
    instead of a contiguous per-slot cache; identical residual-stream
    pinning so both paths round the stream bit-identically."""
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, kp, vp = L.gqa_attention_decode_paged(h, lp["attn"], cfg, env, kp,
                                             vp, tables, pos, block_ids,
                                             offsets)
    x = L.pin_bf16(x + L.pin_bf16(y))
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "router" in lp["mlp"]:
        y = L.moe_block(h, lp["mlp"], cfg, env,
                        impl=env.opts.get("moe_impl", "ep"))
    else:
        y = L.ffn_swiglu(h, lp["mlp"], env)
    return L.pin_bf16(x + L.pin_bf16(y)), kp, vp


# --- jamba superblocks -----------------------------------------------------
def _jamba_superblock(x, sb, cfg, env, positions, *, states=None,
                      collect=False, pos=None):
    """One superblock (attn_period slots).  states: dict of per-superblock
    decode states or None (train).  Returns (x, new_states_or_caches)."""
    per = cfg.attn_period
    opts = env.opts
    sp = opts.get("sp", True) and states is None
    mi = ji = fi = 0
    out_states: Dict[str, list] = {"conv": [], "ssm": []}
    kv_out = None
    for slot in range(per):
        kind = ATTN if slot == per // 2 else MAMBA
        if kind == ATTN:
            h = L.rms_norm(x, sb["attn_ln"], cfg.norm_eps)
            if states is None:
                y, k, v = L.gqa_attention_full(
                    h, sb["attn"], cfg, env, positions,
                    attn_mode=opts.get("attn_mode", "full"),
                    bwd_safe=not collect)
                if collect:
                    k = env.cs(k, env.batch_axes, "model", None, None)
                    v = env.cs(v, env.batch_axes, "model", None, None)
                    kv_out = (k, v)
            else:
                y, kc, vc = L.gqa_attention_decode(
                    h, sb["attn"], cfg, env, states["k"], states["v"], pos)
                kv_out = (kc, vc)
            x = _res_cs(x + y, env, sp)
        else:
            lp = jax.tree_util.tree_map(lambda a: a[mi], sb["mamba"])
            h = L.rms_norm(x, sb["mamba_ln"][mi], cfg.norm_eps)
            if states is None and not collect:
                y = M.mamba_layer(h, lp, cfg, env)
            elif states is None and collect:
                y, conv_s, ssm_s = M.mamba_layer(h, lp, cfg, env,
                                                 return_state=True)
                out_states["conv"].append(conv_s)
                out_states["ssm"].append(ssm_s)
            else:
                y, conv_s, ssm_s = M.mamba_layer(
                    h, lp, cfg, env, conv_state=states["conv"][mi],
                    ssm_state=states["ssm"][mi], return_state=True)
                out_states["conv"].append(conv_s)
                out_states["ssm"].append(ssm_s)
            x = _res_cs(x + y, env, sp)
            mi += 1
        # ffn slot
        if cfg.layer_is_moe(slot):
            lp = jax.tree_util.tree_map(lambda a: a[ji], sb["moe"])
            h = L.rms_norm(x, sb["moe_ln"][ji], cfg.norm_eps)
            y = L.moe_block(h, lp, cfg, env, impl=opts.get("moe_impl", "ep"))
            ji += 1
        else:
            lp = jax.tree_util.tree_map(lambda a: a[fi], sb["ffn"])
            h = L.rms_norm(x, sb["ffn_ln"][fi], cfg.norm_eps)
            y = L.ffn_swiglu(h, lp, env)
            fi += 1
        x = _res_cs(x + y, env, sp)
    new_states = None
    if out_states["conv"]:
        new_states = {"conv": jnp.stack(out_states["conv"]),
                      "ssm": jnp.stack(out_states["ssm"])}
    return x, kv_out, new_states


def _run_jamba(params, x, cfg, env, positions, *, collect=False):
    def body(x, sb):
        x, kv, st = _jamba_superblock(x, sb, cfg, env, positions,
                                      collect=collect)
        return x, (kv, st) if collect else None
    x, ys = layer_scan(_maybe_remat(body, env), x, params["superblocks"], env)
    return x, ys


# --- rwkv ------------------------------------------------------------------
def _run_rwkv(params, x, cfg, env, *, collect=False):
    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if collect:
            y, s_tm, wkv = R.rwkv6_time_mix(h, lp["tm"], cfg, env,
                                            return_state=True)
        else:
            y = R.rwkv6_time_mix(h, lp["tm"], cfg, env)
        x = x + y
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if collect:
            y, s_cm = R.rwkv6_channel_mix(h, lp["cm"], cfg, env,
                                          return_state=True)
        else:
            y = R.rwkv6_channel_mix(h, lp["cm"], cfg, env)
        x = x + y
        x = _res_cs(x, env, env.opts.get("sp", True))
        return x, (wkv, s_tm, s_cm) if collect else None
    x, ys = layer_scan(_maybe_remat(body, env), x, params["layers"], env)
    return x, ys


def _rwkv_decode_block(x, lp, st, cfg, env):
    wkv, s_tm, s_cm = st
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, s_tm2, wkv2 = R.rwkv6_time_mix(h, lp["tm"], cfg, env,
                                      shift_state=s_tm, wkv_state=wkv,
                                      return_state=True)
    x = x + y
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    y, s_cm2 = R.rwkv6_channel_mix(h, lp["cm"], cfg, env,
                                   shift_state=s_cm, return_state=True)
    x = x + y
    return x, (wkv2, s_tm2, s_cm2)


# ===========================================================================
# public entry points
# ===========================================================================
def _assemble_inputs(params, batch, cfg):
    """Returns (x, labels, positions)."""
    if cfg.family == "vlm":
        patches = batch["patches"].astype(BF16)
        tok_emb = embed_tokens(params, batch["tokens"], cfg)
        x = jnp.concatenate([patches, tok_emb], axis=1)
        labels = None
        if "labels" in batch:
            Bt, P = patches.shape[0], patches.shape[1]
            labels = jnp.concatenate(
                [jnp.full((Bt, P), -100, jnp.int32), batch["labels"]],
                axis=1)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
        labels = batch.get("labels")
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    return x, labels, positions


def forward_train(params, batch, cfg: ModelConfig, env: ShardingEnv):
    """Full causal forward; returns scalar mean xent loss."""
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.forward_train(params, batch, cfg, env)
    x, labels, positions = _assemble_inputs(params, batch, cfg)
    x = _res_cs(x, env, env.opts.get("sp", True))
    if cfg.family == "ssm":
        x, _ = _run_rwkv(params, x, cfg, env)
    elif cfg.attn_period:
        x, _ = _run_jamba(params, x, cfg, env, positions)
    else:
        x, _ = _run_uniform(params, x, cfg, env, positions)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return chunked_xent(params, x, labels, cfg, env)


def forward_logits(params, batch, cfg: ModelConfig, env: ShardingEnv):
    """Forward returning full logits (small shapes / tests)."""
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.forward_logits(params, batch, cfg, env)
    x, _, positions = _assemble_inputs(params, batch, cfg)
    if cfg.family == "ssm":
        x, _ = _run_rwkv(params, x, cfg, env)
    elif cfg.attn_period:
        x, _ = _run_jamba(params, x, cfg, env, positions)
    else:
        x, _ = _run_uniform(params, x, cfg, env, positions)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg)


# --- caches ----------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=BF16, src_len: Optional[int] = None) -> Dict[str, Any]:
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.init_cache(cfg, batch, max_len, dtype,
                                 src_len=src_len or max_len)
    d = cfg.d_model
    if cfg.family == "ssm":
        H, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
        Ln = cfg.n_layers
        return {"wkv": jnp.zeros((Ln, batch, H, hs, hs), F32),
                "shift_tm": jnp.zeros((Ln, batch, d), dtype),
                "shift_cm": jnp.zeros((Ln, batch, d), dtype)}
    if cfg.attn_period:
        nsb = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1
        K, dh = cfg.n_kv_heads, cfg.head_dim
        di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
        return {
            "k": jnp.zeros((nsb, batch, max_len, K, dh), dtype),
            "v": jnp.zeros((nsb, batch, max_len, K, dh), dtype),
            "conv": jnp.zeros((nsb, nm, batch, cfg.mamba_d_conv - 1, di), dtype),
            "ssm": jnp.zeros((nsb, nm, batch, di, ds), F32),
        }
    Ln = cfg.n_layers
    if cfg.use_mla:
        return {"ckv": jnp.zeros((Ln, batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((Ln, batch, max_len, cfg.qk_rope_head_dim),
                                   dtype)}
    K, dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((Ln, batch, max_len, K, dh), dtype),
            "v": jnp.zeros((Ln, batch, max_len, K, dh), dtype)}


def abstract_cache(cfg, batch, max_len, dtype=BF16, src_len=None):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, src_len=src_len))


def cache_pspecs(cfg: ModelConfig, env: ShardingEnv, batch: int,
                 max_len: int, src_len: Optional[int] = None):
    """Sharding for the serving cache: batch over data axes, seq over
    'model' (flash-decoding layout); rwkv/mamba states shard their inner
    dim over 'model'."""
    ab = abstract_cache(cfg, batch, max_len, src_len=src_len)
    bt = env.batch_axes
    if env.opts.get("serve_fullshard"):
        # decode mode for >100B archs: batch replicated, sequence sharded
        # over (model x data) -> weights stay fully sharded, no gathers
        bt = None
        seq = ("model", "data")
    elif env.opts.get("cache_2d"):
        # serve layout: KV sequence sharded over BOTH axes (batch stays
        # on 'data'); decode reads it back identically
        seq = ("model", "data")
    else:
        seq = "model"

    def spec_of(path, leaf):
        name = path[-1]
        dims = leaf.shape
        if name in ("k", "v", "ckv", "krope", "cross_k", "cross_v"):
            if len(dims) == 4:
                want = [None, bt, seq, None]
            else:
                want = [None, bt, seq, None, None]
            return env.named(dims, want)
        if name == "wkv":
            return env.named(dims, [None, bt, "model", None, None])
        if name in ("shift_tm", "shift_cm"):
            return env.named(dims, [None, bt, None])
        if name == "conv":
            return env.named(dims, [None, None, bt, None, "model"])
        if name == "ssm":
            return env.named(dims, [None, None, bt, "model", None])
        return env.named(dims, [None] * len(dims))

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: spec_of([getattr(k, "key", getattr(k, "idx", k))
                                  for k in kp], leaf), ab)


# --- prefill ---------------------------------------------------------------
def prefill(params, batch, cfg: ModelConfig, env: ShardingEnv,
            max_len: Optional[int] = None):
    """Full-sequence prefill.  Returns (last_logits, cache)."""
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.prefill(params, batch, cfg, env, max_len)
    x, _, positions = _assemble_inputs(params, batch, cfg)
    S = x.shape[1]
    max_len = max_len or S

    if cfg.family == "ssm":
        x, ys = _run_rwkv(params, x, cfg, env, collect=True)
        wkv, s_tm, s_cm = ys
        cache = {"wkv": wkv, "shift_tm": s_tm, "shift_cm": s_cm}
    elif cfg.attn_period:
        x, ys = _run_jamba(params, x, cfg, env, positions, collect=True)
        (k, v), st = ys
        cache = {"k": _pad_seq(k, max_len, 2), "v": _pad_seq(v, max_len, 2),
                 "conv": st["conv"], "ssm": st["ssm"]}
    else:
        x, kv = _run_uniform(params, x, cfg, env, positions, collect_kv=True)
        c1, c2 = kv
        if cfg.use_mla:
            cache = {"ckv": _pad_seq(c1, max_len, 2),
                     "krope": _pad_seq(c2, max_len, 2)}
        else:
            cache = {"k": _pad_seq(c1, max_len, 2),
                     "v": _pad_seq(c2, max_len, 2)}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = unembed(params, x[:, -1:, :], cfg)
    return last, cache


def _pad_seq(x, max_len, axis):
    if x.shape[axis] == max_len:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, max_len - x.shape[axis])
    return jnp.pad(x, pad)


# --- decode ----------------------------------------------------------------
def decode_step(params, tokens, cache, pos, cfg: ModelConfig,
                env: ShardingEnv):
    """One decode step.  tokens: (B,1) int32; pos: scalar or (B,) position
    of the new token.  Returns (logits (B,1,V), new_cache)."""
    if cfg.enc_dec:
        from repro.models import encdec
        return encdec.decode_step(params, tokens, cache, pos, cfg, env)
    x = embed_tokens(params, tokens, cfg)

    if cfg.family == "ssm":
        def body(x, xs):
            lp, wkv, s_tm, s_cm = xs
            x, st = _rwkv_decode_block(x, lp, (wkv, s_tm, s_cm), cfg, env)
            return x, st
        x, ys = layer_scan(body, x, (params["layers"], cache["wkv"],
                                     cache["shift_tm"], cache["shift_cm"]), env)
        new_cache = {"wkv": ys[0], "shift_tm": ys[1], "shift_cm": ys[2]}
    elif cfg.attn_period:
        def body(x, xs):
            sb, kc, vc, conv, ssm = xs
            x, kv, st = _jamba_superblock(
                x, sb, cfg, env, None,
                states={"k": kc, "v": vc, "conv": conv, "ssm": ssm}, pos=pos)
            return x, (kv[0], kv[1], st["conv"], st["ssm"])
        x, ys = layer_scan(body, x, (params["superblocks"], cache["k"],
                                     cache["v"], cache["conv"], cache["ssm"]), env)
        new_cache = {"k": ys[0], "v": ys[1], "conv": ys[2], "ssm": ys[3]}
    else:
        def body(x, xs):
            lp, c1, c2 = xs
            x, c1, c2 = _uniform_decode_block(x, lp, c1, c2, cfg, env, pos)
            return x, (c1, c2)
        if cfg.use_mla:
            x, ys = layer_scan(body, x, (params["layers"], cache["ckv"],
                                         cache["krope"]), env)
            new_cache = {"ckv": ys[0], "krope": ys[1]}
        else:
            x, ys = layer_scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]), env)
            new_cache = {"k": ys[0], "v": ys[1]}

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, new_cache


def decode_step_paged(params, tokens, k_pool, v_pool, tables, pos,
                      block_ids, offsets, cfg: ModelConfig,
                      env: ShardingEnv):
    """Paged twin of ``decode_step``: the contiguous ``cache`` dict is
    replaced by the serving pool's block arrays plus per-row block
    tables, so parked/resident KV never moves — decode attends over it
    in place.

    tokens: (B, 1) int32; k_pool/v_pool: (L, num_blocks, block, K, dh);
    tables: (B, max_blocks) int32 (rows padded with any in-range id —
    padded positions are masked); pos: (B,) position of the new token;
    block_ids/offsets: (B,) append destination of the new token's K/V
    (idle rows pass num_blocks as an out-of-range drop sentinel).
    Covers the decoder-only GQA families the serving engine admits
    (dense / moe / vlm).  Returns (logits (B,1,V), k_pool, v_pool)."""
    assert not (cfg.enc_dec or cfg.use_mla or cfg.family == "ssm"
                or cfg.attn_period), \
        "paged decode covers the uniform GQA-cache families"
    x = embed_tokens(params, tokens, cfg)

    def body(x, xs):
        lp, kp, vp = xs
        x, kp, vp = _uniform_decode_block_paged(
            x, lp, kp, vp, tables, pos, block_ids, offsets, cfg, env)
        return x, (kp, vp)

    x, ys = layer_scan(body, x, (params["layers"], k_pool, v_pool), env)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), ys[0], ys[1]
