"""Mamba-1 selective-SSM layer (jamba's mixer) in pure jnp.

Sequential lax.scan over time keeps the carry at (B, d_inner, d_state) —
memory-light and SPMD-clean (everything TPs over d_inner on the 'model'
axis).  The chunked-parallel Pallas kernel in ``repro.kernels.mamba_scan``
is the single-device fast path; this module is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def _causal_depthwise_conv(x, w, b):
    """x: (B,S,di); w: (di, k); left-padded causal depthwise conv."""
    k = w.shape[1]
    out = jnp.zeros_like(x, dtype=F32)
    for j in range(k):
        shift = k - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1], :]
        out = out + xs.astype(F32) * w[:, j].astype(F32)
    return (out + b.astype(F32)).astype(x.dtype)


def _ssm_scan(x, dt, Bc, Cc, A, D):
    """x,dt: (B,S,di); Bc,Cc: (B,S,ds); A: (di,ds); D: (di,).

    h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t ;  y_t = h_t·C_t + D⊙x_t
    """
    B, S, di = x.shape
    ds = A.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt.astype(F32)[:, :, None] * A.astype(F32)[None])
        dBx = (dtt * xt).astype(F32)[:, :, None] * bt.astype(F32)[:, None, :]
        h = dA * h + dBx                                  # (B,di,ds)
        y = jnp.einsum("bis,bs->bi", h, ct.astype(F32))
        return h, y.astype(jnp.bfloat16)

    from repro.models.layers import seq_scan
    h0 = jnp.zeros((B, di, ds), dtype=F32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    hT, ys = seq_scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(F32) + \
        x.astype(F32) * D.astype(F32)[None, None]
    return y.astype(x.dtype), hT


def mamba_layer(x, p, cfg, env, *, conv_state=None, ssm_state=None,
                return_state: bool = False):
    """Full-sequence mamba mixer.  x: (B,S,d) -> (B,S,d).

    With ``return_state`` also returns (conv_state, ssm_state) for the
    serving cache: conv_state (B, d_conv-1, di), ssm_state (B, di, ds).
    """
    B, S, d = x.shape
    di = cfg.mamba_d_inner
    ds = cfg.mamba_d_state
    dtr = cfg.dt_rank

    xz = x @ p["in_proj"]
    xr, z = xz[..., :di], xz[..., di:]
    xr = env.cs(xr, env.batch_axes, None, "model")
    if conv_state is not None:
        xr_in = jnp.concatenate([conv_state.astype(xr.dtype), xr], axis=1)
        xr_c = _causal_depthwise_conv(xr_in, p["conv_w"], p["conv_b"])
        xr_c = xr_c[:, conv_state.shape[1]:, :]
    else:
        xr_c = _causal_depthwise_conv(xr, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xr_c)

    dbc = xc @ p["x_proj"]                     # (B,S,dtr+2ds)
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["dt_w"] + p["dt_b"])
    Bc = dbc[..., dtr:dtr + ds]
    Cc = dbc[..., dtr + ds:]
    A = -jnp.exp(p["A_log"].astype(F32))

    if ssm_state is not None:
        # decode: S is tiny (1); fold carried state in by running the scan
        # from the provided h0.
        def step(h, inp):
            xt, dtt, bt, ct = inp
            dA = jnp.exp(dtt.astype(F32)[:, :, None] * A[None])
            dBx = (dtt * xt).astype(F32)[:, :, None] * bt.astype(F32)[:, None, :]
            h = dA * h + dBx
            y = jnp.einsum("bis,bs->bi", h, ct.astype(F32))
            return h, y
        xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
              jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
        hT, ys = lax.scan(step, ssm_state.astype(F32), xs)
        y = jnp.moveaxis(ys, 0, 1) + xc.astype(F32) * p["D"].astype(F32)
        y = y.astype(x.dtype)
    else:
        y, hT = _ssm_scan(xc, dt, Bc, Cc, A, p["D"])

    y = (y.astype(F32) * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = y @ p["out_proj"]
    if return_state:
        k = cfg.mamba_d_conv - 1
        if conv_state is not None:
            tail = jnp.concatenate([conv_state.astype(xr.dtype), xr],
                                   axis=1)[:, -k:, :]
        else:
            tail = jnp.pad(xr, ((0, 0), (max(0, k - S), 0), (0, 0)))[:, -k:, :]
        return out, tail, hT
    return out
