"""Logical-axis sharding rules for the model zoo.

Design:
  * The production mesh is ("data","model") single-pod or
    ("pod","data","model") multi-pod.  "pod" behaves as an outer
    data-parallel axis; batch shards over ``batch_axes = ("pod","data")``.
  * Weights are 2-D sharded (FSDP over "data" x TP over "model") because
    the large assigned archs do not fit 1-D sharding in 16 GB HBM.
  * Every rule is divisibility-checked: jax rejects uneven shardings, so
    ``spec_for`` drops any mesh axis that does not divide the dim
    (e.g. seamless vocab=256206 is not divisible by 16 -> vocab stays
    unsharded and d_model picks up the axes instead).

``ShardingEnv`` is threaded through the forward functions; with
``mesh=None`` every constraint is a no-op so the same model code runs on
a bare CPU for smoke tests.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]


class ShardingEnv:
    """Mesh-aware helper: builds divisible PartitionSpecs + constraints."""

    def __init__(self, mesh: Optional[Mesh] = None, opts: Optional[dict] = None):
        self.mesh = mesh
        # forward-pass options: attn_mode (full|tri), moe_impl (ep|dense),
        # remat (bool), remat_policy (full|dots), sp (bool), loss_chunk (int)
        self.opts = dict(opts or {})
        if mesh is not None:
            self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        else:
            self.axis_sizes = {}

    # -- axis groups -------------------------------------------------
    @property
    def batch_axes(self) -> Tuple[str, ...]:
        if "pod" in self.axis_sizes:
            return ("pod", "data")
        return ("data",) if "data" in self.axis_sizes else ()

    @property
    def fsdp_axis(self) -> Optional[str]:
        # opts['fsdp']=False: serving deployments replicate weights over
        # 'data' (no optimizer state to shard) and kill weight gathers
        if not self.opts.get("fsdp", True):
            return None
        return "data" if "data" in self.axis_sizes else None

    @property
    def tp_axis(self) -> Optional[str]:
        return "model" if "model" in self.axis_sizes else None

    @property
    def tp(self) -> int:
        return self.axis_sizes.get("model", 1)

    @property
    def dp(self) -> int:
        n = self.axis_sizes.get("data", 1)
        n *= self.axis_sizes.get("pod", 1)
        return n

    def axis_size(self, entry: AxisEntry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, str):
            return self.axis_sizes.get(entry, 1)
        n = 1
        for a in entry:
            n *= self.axis_sizes.get(a, 1)
        return n

    # -- spec construction -------------------------------------------
    def spec(self, dims: Sequence[int], wants: Sequence[AxisEntry]) -> P:
        """PartitionSpec keeping only axes that divide the dim evenly."""
        assert len(dims) == len(wants), (dims, wants)
        out = []
        for dim, want in zip(dims, wants):
            if want is None or not self.axis_sizes:
                out.append(None)
                continue
            entries = (want,) if isinstance(want, str) else tuple(want)
            kept = []
            size = 1
            for a in entries:
                asz = self.axis_sizes.get(a, 1)
                if asz > 1 and dim % (size * asz) == 0:
                    kept.append(a)
                    size *= asz
            if not kept:
                out.append(None)
            elif len(kept) == 1:
                out.append(kept[0])
            else:
                out.append(tuple(kept))
        return P(*out)

    def named(self, dims: Sequence[int], wants: Sequence[AxisEntry]):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(dims, wants))

    def cs(self, x, *wants: AxisEntry):
        """with_sharding_constraint with divisibility-checked spec."""
        if self.mesh is None:
            return x
        sh = NamedSharding(self.mesh, self.spec(x.shape, list(wants)))
        return jax.lax.with_sharding_constraint(x, sh)

    # -- family decisions --------------------------------------------
    def heads_shardable(self, n_heads: int) -> bool:
        return self.tp > 1 and n_heads % self.tp == 0

    def moe_ep(self, n_experts: int) -> bool:
        """True -> expert-parallel over 'model'; False -> d_ff TP."""
        return self.tp > 1 and n_experts % self.tp == 0


def param_pspecs(abstract_params, env: ShardingEnv, rules):
    """Map an abstract param tree -> tree of NamedSharding via path rules.

    ``rules(path, shape) -> list[AxisEntry]`` must return the per-dim axis
    wish list; divisibility pruning happens here.
    """
    def visit(path, leaf):
        wants = rules("/".join(str(p) for p in path), leaf.shape)
        return env.named(leaf.shape, wants)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: visit([getattr(k, "key", getattr(k, "idx", k))
                                for k in kp], leaf),
        abstract_params)
