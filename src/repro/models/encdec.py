"""Encoder-decoder assembly (seamless-m4t): speech encoder over precomputed
frame embeddings (frontend STUB per assignment) + text decoder with
cross-attention.  Decode serving state = self-KV cache + frozen cross-KV.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.lm import layer_scan as lm_layer_scan
from repro.models import layers as L
from repro.models.sharding import ShardingEnv

BF16 = jnp.bfloat16
F32 = jnp.float32


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    from repro.models.lm import _dense, _init_attn, _init_ffn, _keys, _stack
    d = cfg.d_model
    k_emb, k_un, k_enc, k_dec = _keys(key, 4)
    params: Dict[str, Any] = {
        "embed": _dense(k_emb, (cfg.vocab, d)),
        "unembed": _dense(k_un, (d, cfg.vocab)),
        "enc_norm": jnp.ones((d,), BF16),
        "final_norm": jnp.ones((d,), BF16),
    }
    enc = []
    for i in range(cfg.n_enc_layers):
        kk = jax.random.fold_in(k_enc, i)
        enc.append({
            "ln1": jnp.ones((d,), BF16), "ln2": jnp.ones((d,), BF16),
            "attn": _init_attn(jax.random.fold_in(kk, 0), cfg),
            "mlp": _init_ffn(jax.random.fold_in(kk, 1), cfg),
        })
    dec = []
    for i in range(cfg.n_dec_layers):
        kk = jax.random.fold_in(k_dec, i)
        dec.append({
            "ln1": jnp.ones((d,), BF16),
            "ln_cross": jnp.ones((d,), BF16),
            "ln2": jnp.ones((d,), BF16),
            "attn": _init_attn(jax.random.fold_in(kk, 0), cfg),
            "cross": _init_attn(jax.random.fold_in(kk, 1), cfg),
            "mlp": _init_ffn(jax.random.fold_in(kk, 2), cfg),
        })
    params["enc_layers"] = _stack(enc)
    params["dec_layers"] = _stack(dec)
    return params


def _run_encoder(params, frames, cfg, env: ShardingEnv, train=False):
    from repro.models.lm import _maybe_remat, _res_cs
    x = frames.astype(BF16)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x = _res_cs(x, env, env.opts.get("sp", True))

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, _, _ = L.gqa_attention_full(
            h, lp["attn"], cfg, env, positions, causal=False,
            attn_mode=env.opts.get("attn_mode", "full"), bwd_safe=train)
        x = _res_cs(x + y, env, env.opts.get("sp", True))
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = _res_cs(x + L.ffn_swiglu(h, lp["mlp"], env), env,
                    env.opts.get("sp", True))
        return x, None

    from repro.models.lm import layer_scan
    x, _ = layer_scan(_maybe_remat(body, env), x, params["enc_layers"], env)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp_cross, enc_out, cfg, env):
    """Project encoder output into per-layer cross K/V."""
    k = jnp.einsum("bsd,dkx->bskx", enc_out, lp_cross["wk"])
    v = jnp.einsum("bsd,dkx->bskx", enc_out, lp_cross["wv"])
    return k, v


def _decoder_block(x, lp, cfg, env, positions, enc_out, *, collect=False,
                   train=False):
    from repro.models.lm import _res_cs
    sp = env.opts.get("sp", True)
    attn_mode = env.opts.get("attn_mode", "full")
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, sk, sv = L.gqa_attention_full(h, lp["attn"], cfg, env, positions,
                                     attn_mode=attn_mode, bwd_safe=train)
    x = _res_cs(x + y, env, sp)
    h = L.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
    ck, cv = _cross_kv(lp["cross"], enc_out, cfg, env)
    y, _, _ = L.gqa_attention_full(h, lp["cross"], cfg, env, positions,
                                   causal=False, kv_override=(ck, cv),
                                   attn_mode=attn_mode, bwd_safe=train)
    x = _res_cs(x + y, env, sp)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = _res_cs(x + L.ffn_swiglu(h, lp["mlp"], env), env, sp)
    if collect:
        cs = env.cs
        bt = env.batch_axes
        return x, (cs(sk, bt, "model", None, None),
                   cs(sv, bt, "model", None, None),
                   cs(ck, bt, "model", None, None),
                   cs(cv, bt, "model", None, None))
    return x, None


def forward_train(params, batch, cfg, env: ShardingEnv):
    from repro.models.lm import _maybe_remat, chunked_xent
    enc_out = _run_encoder(params, batch["frames"], cfg, env, train=True)
    x = jnp.take(params["embed"], batch["tgt_tokens"], axis=0)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]

    def body(x, lp):
        return _decoder_block(x, lp, cfg, env, positions, enc_out,
                              train=True)

    x, _ = lm_layer_scan(_maybe_remat(body, env), x, params["dec_layers"], env)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return chunked_xent(params, x, batch["tgt_labels"], cfg, env)


def forward_logits(params, batch, cfg, env: ShardingEnv):
    from repro.models.lm import unembed
    enc_out = _run_encoder(params, batch["frames"], cfg, env)
    x = jnp.take(params["embed"], batch["tgt_tokens"], axis=0)
    St = x.shape[1]
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]

    def body(x, lp):
        return _decoder_block(x, lp, cfg, env, positions, enc_out)

    x, _ = lm_layer_scan(body, x, params["dec_layers"], env)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg)


def init_cache(cfg, batch, max_len, dtype=BF16, src_len=None):
    """Self-KV cache (decoder) + cross-KV (filled by prefill)."""
    K, dh = cfg.n_kv_heads, cfg.head_dim
    Ld = cfg.n_dec_layers
    src_len = src_len or max_len
    return {"k": jnp.zeros((Ld, batch, max_len, K, dh), dtype),
            "v": jnp.zeros((Ld, batch, max_len, K, dh), dtype),
            "cross_k": jnp.zeros((Ld, batch, src_len, K, dh), dtype),
            "cross_v": jnp.zeros((Ld, batch, src_len, K, dh), dtype)}


def prefill(params, batch, cfg, env: ShardingEnv,
            max_len: Optional[int] = None):
    """Encode source frames + prefill decoder over tgt prefix.

    Returns (last_logits, cache) with cache =
    {k, v (self), cross_k, cross_v}.
    """
    from repro.models.lm import _pad_seq, unembed
    enc_out = _run_encoder(params, batch["frames"], cfg, env)
    x = jnp.take(params["embed"], batch["tgt_tokens"], axis=0)
    St = x.shape[1]
    max_len = max_len or St
    positions = jnp.arange(St, dtype=jnp.int32)[None, :]

    def body(x, lp):
        return _decoder_block(x, lp, cfg, env, positions, enc_out,
                              collect=True)

    x, ys = lm_layer_scan(body, x, params["dec_layers"], env)
    sk, sv, ck, cv = ys
    cache = {"k": _pad_seq(sk, max_len, 2), "v": _pad_seq(sv, max_len, 2),
             "cross_k": ck, "cross_v": cv}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x[:, -1:, :], cfg), cache


def decode_step(params, tokens, cache, pos, cfg, env: ShardingEnv):
    from repro.models.lm import unembed
    x = jnp.take(params["embed"], tokens, axis=0)
    B = x.shape[0]
    Ss = cache["cross_k"].shape[2]

    def body(x, xs):
        lp, sk, sv, ck, cv = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, sk, sv = L.gqa_attention_decode(h, lp["attn"], cfg, env, sk, sv,
                                           pos)
        x = x + y
        h = L.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhx->bshx", h, lp["cross"]["wq"])
        q = L.apply_rope(q, jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None],
                         cfg.rope_theta)
        y = L.decode_attention(q, ck, cv, jnp.full((B,), Ss - 1))
        y = jnp.einsum("bshx,hxd->bsd", y, lp["cross"]["wo"])
        x = x + y
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.ffn_swiglu(h, lp["mlp"], env)
        return x, (sk, sv)

    x, ys = lm_layer_scan(body, x, (params["dec_layers"], cache["k"],
                                    cache["v"], cache["cross_k"],
                                    cache["cross_v"]), env)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ys
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, x, cfg), new_cache
