"""Model-zoo primitive layers (pure jnp; GSPMD-friendly).

Everything here must (a) run on a single CPU device for smoke tests and
(b) lower under 512-way SPMD for the production dry-run.  The Pallas
kernels in ``repro.kernels`` are drop-in single-device replacements for
the hot paths (flash prefill / paged decode / rwkv6 / mamba scan); the
jnp implementations below are simultaneously their reference oracles and
the distributed lowering path.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import ShardingEnv

F32 = jnp.float32
NEG_INF = -1e30


def pin_bf16(x):
    """Force a bf16 tensor's storage rounding to actually happen.

    XLA's excess-precision pass may elide an f32->bf16->f32 convert pair
    inside a fused graph, so the *same* bf16-typed intermediate holds
    different values in differently-fused programs (e.g. the S-token
    prefill graph vs the 1-token decode graph).  Any knife-edge discrete
    decision downstream — the MoE router's top_k above all — then
    diverges between serving paths.  ``lax.reduce_precision`` performs
    the rounding explicitly and is never elided, making residual-stream
    values bit-identical across fusion choices."""
    if x.dtype == jnp.bfloat16:
        return lax.reduce_precision(x, exponent_bits=8, mantissa_bits=7)
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    out = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return pin_bf16((out * scale.astype(F32)).astype(x.dtype))


def group_norm_heads(x, scale, n_heads: int, eps: float = 1e-5):
    """Per-head group norm over the trailing dim split into n_heads groups
    (RWKV's ln_x)."""
    orig = x.shape
    x = x.reshape(orig[:-1] + (n_heads, orig[-1] // n_heads)).astype(F32)
    mean = x.mean(axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x.reshape(orig)
    return (x * scale.astype(F32)).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                      # (D/2,)
    ang = positions.astype(F32)[..., None] * freqs    # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — dense reference (small shapes / oracle)
# ---------------------------------------------------------------------------
def expand_kv(k, n_heads: int):
    """(B,S,K,D) -> (B,S,H,D) by repeating each kv head H/K times."""
    K = k.shape[2]
    if K == n_heads:
        return k
    return jnp.repeat(k, n_heads // K, axis=2)


def attention_dense(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, logit_cap: float = 0.0):
    """q: (B,Sq,H,D), k/v: (B,Sk,K,D[v]).  GQA expanded internally."""
    B, Sq, H, D = q.shape
    k = expand_kv(k, H)
    v = expand_kv(v, H)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k,
                   preferred_element_type=F32) * scale
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=F32)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention — chunked online-softmax (memory-safe; the distributed path)
# ---------------------------------------------------------------------------
def _pick_block(S: int, target: int) -> int:
    if S <= target:
        return S
    b = target
    while S % b:
        b -= 1
    return b


def _visible(i, j, qb, kb, q_offset, causal, window) -> bool:
    q_lo = i * qb + q_offset
    q_hi = q_lo + qb - 1
    k_lo, k_hi = j * kb, j * kb + kb - 1
    if causal and k_lo > q_hi:
        return False
    if window and q_lo - k_hi >= window:
        return False
    return True


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_block: int = 512, kv_block: int = 512,
                      mode: str = "full", q_offset: int = 0,
                      logit_cap: float = 0.0, bwd_safe: bool = False,
                      unroll_pairs: bool = False):
    """Flash-style two-level blocked attention in pure jnp.

    mode="full": every (q_block, kv_block) pair with masking — the
      baseline (compute ~2x for causal).
    mode="tri": only visible block pairs (causal triangle /
      sliding-window band) — the beyond-paper optimized path.
    bwd_safe=True (training): python loop over q blocks with a
      checkpointed inner kv scan, so the backward pass recomputes scores
      instead of saving O(Sq*Sk) residuals.  Inference (prefill) uses the
      flat pair-scan which keeps the HLO small.
    unroll_pairs=True: python-unroll the pair loop — used by the dry-run
      slope compiles so XLA cost analysis sees every block pair (a scan
      body is otherwise counted once regardless of trip count).
    """
    if bwd_safe:
        return _chunked_attention_bwd_safe(
            q, k, v, causal=causal, window=window, q_block=q_block,
            kv_block=kv_block, mode=mode, q_offset=q_offset,
            logit_cap=logit_cap)
    B, Sq, H, D = q.shape
    assert k.shape[2] == H, "expand_kv before chunked_attention"
    Sk = k.shape[1]
    Dv = v.shape[-1]
    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Sk, kv_block)
    nq, nk = Sq // qb, Sk // kb
    scale = 1.0 / math.sqrt(D)

    pairs = []
    for i in range(nq):
        for j in range(nk):
            q_lo = i * qb + q_offset
            q_hi = q_lo + qb - 1
            k_lo, k_hi = j * kb, j * kb + kb - 1
            visible = True
            if causal and k_lo > q_hi:
                visible = False
            if window and q_hi - k_hi >= window + qb - 1 and k_hi < q_lo:
                # entire kv block is left of every q position's window
                if q_lo - k_hi >= window:
                    visible = False
            if mode == "tri" and not visible:
                continue
            pairs.append((i, j))
    ii = jnp.array([p[0] for p in pairs], dtype=jnp.int32)
    jj = jnp.array([p[1] for p in pairs], dtype=jnp.int32)

    m0 = jnp.full((B, H, Sq), NEG_INF, dtype=F32)
    l0 = jnp.zeros((B, H, Sq), dtype=F32)
    a0 = jnp.zeros((B, H, Sq, Dv), dtype=F32)

    def body(carry, idx):
        m, l, acc = carry
        i, j = idx
        qi = lax.dynamic_slice_in_dim(q, i * qb, qb, axis=1)    # (B,qb,H,D)
        kj = lax.dynamic_slice_in_dim(k, j * kb, kb, axis=1)    # (B,kb,H,D)
        vj = lax.dynamic_slice_in_dim(v, j * kb, kb, axis=1)
        s = jnp.einsum("bqhd,bshd->bhqs", qi, kj,
                       preferred_element_type=F32) * scale
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        qpos = i * qb + jnp.arange(qb) + q_offset
        kpos = j * kb + jnp.arange(kb)
        msk = jnp.ones((qb, kb), dtype=bool)
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window:
            msk &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(msk[None, None], s, NEG_INF)

        mi = lax.dynamic_slice_in_dim(m, i * qb, qb, axis=2)
        li = lax.dynamic_slice_in_dim(l, i * qb, qb, axis=2)
        ai = lax.dynamic_slice_in_dim(acc, i * qb, qb, axis=2)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        # guard all-masked rows (m_new == NEG_INF) against inf-inf
        alpha = jnp.exp(jnp.minimum(mi - m_new, 0.0))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk[None, None], p, 0.0)
        l_new = li * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(v.dtype), vj,
                        preferred_element_type=F32)
        a_new = ai * alpha[..., None] + pv
        m = lax.dynamic_update_slice_in_dim(m, m_new, i * qb, axis=2)
        l = lax.dynamic_update_slice_in_dim(l, l_new, i * qb, axis=2)
        acc = lax.dynamic_update_slice_in_dim(acc, a_new, i * qb, axis=2)
        return (m, l, acc), None

    if unroll_pairs:
        carry = (m0, l0, a0)
        for pi, pj in pairs:
            carry, _ = body(carry, (jnp.int32(pi), jnp.int32(pj)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (ii, jj))
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,H,Sq,Dv)
    out = jnp.moveaxis(out, 1, 2)                       # (B,Sq,H,Dv)
    return out.astype(q.dtype)


def _chunked_attention_bwd_safe(q, k, v, *, causal, window, q_block,
                                kv_block, mode, q_offset, logit_cap):
    """Training attention: O(block) backward residuals.

    Outer python loop over q blocks (static), inner checkpointed scan over
    kv blocks; jax.checkpoint forces score recomputation in the backward
    pass so only the small (m,l,acc) block carries are stored.
    """
    B, Sq, H, D = q.shape
    assert k.shape[2] == H, "expand_kv before chunked_attention"
    Sk = k.shape[1]
    Dv = v.shape[-1]
    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Sk, kv_block)
    nq, nk = Sq // qb, Sk // kb
    scale = 1.0 / math.sqrt(D)

    def qblock(qkv, jj, i):
        q_, k_, v_ = qkv

        def inner(carry, j):
            m, l, acc = carry
            qi = lax.dynamic_slice_in_dim(q_, i * qb, qb, axis=1)
            kj = lax.dynamic_slice_in_dim(k_, j * kb, kb, axis=1)
            vj = lax.dynamic_slice_in_dim(v_, j * kb, kb, axis=1)
            s = jnp.einsum("bqhd,bshd->bhqs", qi, kj,
                           preferred_element_type=F32) * scale
            if logit_cap:
                s = logit_cap * jnp.tanh(s / logit_cap)
            qpos = i * qb + jnp.arange(qb) + q_offset
            kpos = j * kb + jnp.arange(kb)
            msk = jnp.ones((qb, kb), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window:
                msk &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk[None, None], p, 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(v_.dtype), vj,
                            preferred_element_type=F32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, qb), NEG_INF, dtype=F32)
        l0 = jnp.zeros((B, H, qb), dtype=F32)
        a0 = jnp.zeros((B, H, qb, Dv), dtype=F32)
        (m, l, acc), _ = lax.scan(jax.checkpoint(inner), (m0, l0, a0), jj)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outs = []
    for i in range(nq):
        js = [j for j in range(nk)
              if mode != "tri" or _visible(i, j, qb, kb, q_offset, causal,
                                           window)]
        jj = jnp.array(js, dtype=jnp.int32)
        outs.append(jax.checkpoint(qblock, static_argnums=(2,))(
            (q, k, v), jj, i))
    out = jnp.concatenate(outs, axis=2)                 # (B,H,Sq,Dv)
    out = jnp.moveaxis(out, 1, 2)                       # (B,Sq,H,Dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token decode attention over a (possibly sharded) KV cache.

    q: (B,1,H,D); k_cache/v_cache: (B,S,K,D[v]); pos: scalar or (B,) —
    the position of the *current* token (already written into the cache).
    """
    B, _, H, D = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=F32) * scale
    idx = jnp.arange(S)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    valid = idx[None, :] <= pos_b[:, None]
    if window:
        valid &= idx[None, :] > (pos_b[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=F32)
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


def paged_kv_update(k_pool, v_pool, k_new, v_new, block_ids, offsets):
    """Write one decode step's K/V per batch row into paged pool blocks.

    k_pool/v_pool: (num_blocks, block, K, dh) — ONE layer's blocks;
    k_new/v_new: (B, 1, K, dh); block_ids/offsets: (B,) int32 append
    destinations.  Rows whose block id is out of range are dropped —
    idle batch rows pass ``num_blocks`` as a sentinel, so a partially
    occupied continuous batch never writes stale KV anywhere.
    """
    kp = k_pool.at[block_ids, offsets].set(
        k_new[:, 0].astype(k_pool.dtype), mode="drop")
    vp = v_pool.at[block_ids, offsets].set(
        v_new[:, 0].astype(v_pool.dtype), mode="drop")
    return kp, vp


def paged_kv_gather(k_pool, v_pool, tables):
    """Gather per-row block tables to a contiguous (B, nb*block, K, dh)
    view.  With nb*block equal to the gather-mode cache's max_len this
    produces the same shapes (hence the same XLA program) as dense
    decode over a contiguous cache; positions past each row's length
    hold unrelated block contents, but ``decode_attention`` masks them
    to NEG_INF before any reduction, so their softmax weight underflows
    to exactly 0.0 and the outputs stay bit-identical."""
    B, nb = tables.shape
    blk = k_pool.shape[1]
    k = k_pool[tables].reshape(B, nb * blk, k_pool.shape[2],
                               k_pool.shape[3])
    v = v_pool[tables].reshape(B, nb * blk, v_pool.shape[2],
                               v_pool.shape[3])
    return k, v


def gqa_attention_decode_paged(x, p, cfg, env, k_pool, v_pool, tables,
                               pos, block_ids, offsets):
    """One-token decode over pool blocks: the twin of
    ``gqa_attention_decode`` with the contiguous (B, S, K, dh) cache
    replaced by (pool, block-table) pairs.  Appends the new token's K/V
    into each row's tail block, then attends over the gathered block
    view.  Returns (y, k_pool, v_pool)."""
    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"], cfg.norm_eps)
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = apply_rope(k, pos_b[:, None], cfg.rope_theta)
    k_pool, v_pool = paged_kv_update(k_pool, v_pool, k, v, block_ids,
                                     offsets)
    kg, vg = paged_kv_gather(k_pool, v_pool, tables)
    y = decode_attention(q, kg, vg, pos_b, window=cfg.sliding_window)
    return jnp.einsum("bshx,hxd->bsd", y, p["wo"]), k_pool, v_pool


# ---------------------------------------------------------------------------
# sqrt(T)-remat sequential scan (mamba / rwkv training)
# ---------------------------------------------------------------------------
def seq_scan(step, carry0, xs, *, chunk: int = 64):
    """lax.scan with two-level sqrt(T) rematerialization.

    Differentiating a length-T scan stores the carry at every step; for
    T=4096 state scans that is tens of GB.  Chunking into sqrt(T)-sized
    checkpointed sub-scans bounds backward residuals to
    O((T/chunk + chunk) * carry).
    """
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S <= chunk or S % chunk != 0:
        return lax.scan(step, carry0, xs)
    n = S // chunk
    xs_r = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def outer(c, xc):
        return lax.scan(step, c, xc)

    cT, ys = lax.scan(jax.checkpoint(outer), carry0, xs_r)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys)
    return cT, ys


# ---------------------------------------------------------------------------
# GQA attention layer (wq/wk/wv/wo), shared by dense/moe/vlm archs
# ---------------------------------------------------------------------------
def _attn_q_spec(cfg, env: ShardingEnv):
    """Shard q heads over 'model' if divisible; otherwise run attention
    pure-DP with batch over (data x model).  (Sharding head_dim instead
    all-reduces every score tile — measured 403 GB/device/step on
    llama3.2 train_4k; the batch reshard is 16x cheaper.)"""
    if env.heads_shardable(cfg.n_heads):
        return (env.batch_axes, None, "model", None)
    combined = tuple(env.batch_axes) + ("model",)
    return (combined, None, None, None)


def gqa_qkv(x, p, cfg, env: ShardingEnv, positions):
    """Project + rope.  Head-factored weights (d,H,dh)/(d,K,dh) — no
    flat<->grouped reshapes, so GSPMD never hits an involuntary
    resharding.  Returns q (B,S,H,D), k,v (B,S,K,D)."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = env.cs(q, *_attn_q_spec(cfg, env))
    k = env.cs(k, env.batch_axes, None, None, None)
    v = env.cs(v, env.batch_axes, None, None, None)
    return q, k, v


def gqa_attention_full(x, p, cfg, env, positions, *, causal=True,
                       kv_override=None, attn_mode="full",
                       bwd_safe=False):
    """Full-sequence attention (train / prefill).  Returns (y, k, v)."""
    q, k, v = gqa_qkv(x, p, cfg, env, positions)
    if kv_override is not None:                 # cross-attention
        k, v = kv_override
    kx = env.cs(expand_kv(k, cfg.n_heads), *_attn_q_spec(cfg, env))
    vx = env.cs(expand_kv(v, cfg.n_heads), *_attn_q_spec(cfg, env))
    y = chunked_attention(q, kx, vx, causal=causal,
                          window=cfg.sliding_window, mode=attn_mode,
                          logit_cap=cfg.attn_logit_softcap,
                          bwd_safe=bwd_safe,
                          q_block=env.opts.get("attn_block", 512),
                          kv_block=env.opts.get("attn_block", 512),
                          unroll_pairs=env.opts.get("unroll_pairs", False))
    if env.opts.get("rs_matmul") and env.heads_shardable(cfg.n_heads):
        return rs_out_proj(y, p["wo"], env, "bshx,hxd->bsd"), k, v
    return jnp.einsum("bshx,hxd->bsd", y, p["wo"]), k, v


def gqa_attention_decode(x, p, cfg, env, k_cache, v_cache, pos):
    """One-token decode.  Returns (y, new_k_cache, new_v_cache)."""
    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"], cfg.norm_eps)
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = apply_rope(k, pos_b[:, None], cfg.rope_theta)
    k_cache = _cache_insert(k_cache, k, pos)
    v_cache = _cache_insert(v_cache, v, pos)
    y = decode_attention(q, k_cache, v_cache, pos_b,
                         window=cfg.sliding_window)
    return jnp.einsum("bshx,hxd->bsd", y, p["wo"]), k_cache, v_cache


def _cache_insert(cache, item, pos):
    """Insert (B,1,...) item into (B,S,...) cache at position(s) ``pos``.

    A scalar position (dry-run / uniform batch) uses a single DUS —
    SPMD-friendly on a sharded seq dim.  Per-batch (B,) positions use a
    vmapped DUS (lowers to scatter; used by the CPU engine).
    """
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        start = (0, pos) + (0,) * (cache.ndim - 2)
        return lax.dynamic_update_slice(cache, item.astype(cache.dtype), start)

    def upd(c, it, p):
        return lax.dynamic_update_slice(c, it.astype(c.dtype),
                                        (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, item, pos)


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2)
# ---------------------------------------------------------------------------
def mla_attention_full(x, p, cfg, env, positions, *, attn_mode="full",
                       bwd_safe=False):
    """Training / prefill MLA.  Returns (y, ckv_cache, krope_cache)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    hspec = _attn_q_spec(cfg, env)
    cq = rms_norm(x @ p["wdq"], p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhx->bshx", cq, p["wuq"])
    q = env.cs(q, *hspec)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["wdkv"]
    ckv = rms_norm(ckv_full[..., :cfg.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)          # (B,S,1,rope)

    kv = jnp.einsum("bsr,rhx->bshx", ckv, p["wukv"])
    kv = env.cs(kv, *hspec)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_d))], axis=-1)
    k = env.cs(k, *hspec)
    v = env.cs(v, *hspec)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_full = env.cs(q_full, *hspec)
    y = chunked_attention(q_full, k, v, causal=True, mode=attn_mode,
                          bwd_safe=bwd_safe,
                          q_block=env.opts.get("attn_block", 512),
                          kv_block=env.opts.get("attn_block", 512),
                          unroll_pairs=env.opts.get("unroll_pairs", False))
    return jnp.einsum("bshv,hvd->bsd", y, p["wo"]), ckv, k_rope[:, :, 0, :]


def mla_attention_decode(x, p, cfg, env, ckv_cache, krope_cache, pos):
    """Absorbed-matrix MLA decode over the compressed latent cache."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))

    cq = rms_norm(x @ p["wdq"], p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhx->bshx", cq, p["wuq"])       # (B,1,H,*)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos_b[:, None], cfg.rope_theta)

    ckv_full = x @ p["wdkv"]                            # (B,1,r+rope)
    ckv_new = rms_norm(ckv_full[..., :r], p["kv_ln"], cfg.norm_eps)
    krope_new = apply_rope(ckv_full[:, :, None, r:], pos_b[:, None],
                           cfg.rope_theta)[:, :, 0, :]
    ckv_cache = _cache_insert(ckv_cache, ckv_new, pos)
    krope_cache = _cache_insert(krope_cache, krope_new, pos)

    wukv = p["wukv"]                                   # (r, H, nope+vd)
    wk_b, wv_b = wukv[..., :nope], wukv[..., nope:]
    q_lat = jnp.einsum("bxhn,rhn->bhr", q_nope, wk_b,
                       preferred_element_type=F32)      # x==1
    scale = 1.0 / math.sqrt(nope + rope_d)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat,
                    ckv_cache.astype(F32)) +
         jnp.einsum("bxhp,bsp->bhs", q_rope.astype(F32),
                    krope_cache.astype(F32))) * scale
    S = ckv_cache.shape[1]
    valid = jnp.arange(S)[None, :] <= pos_b[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, ckv_cache.astype(F32))
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv_b.astype(F32))
    y = o[:, None].astype(x.dtype)                     # (B,1,H,vd)
    return jnp.einsum("bshv,hvd->bsd", y, p["wo"]), ckv_cache, krope_cache


# ---------------------------------------------------------------------------
# reduce-scatter TP matmul (beyond-paper §Perf lever)
# ---------------------------------------------------------------------------
def rs_out_proj(y, w, env: ShardingEnv, einsum_str: str):
    """Tensor-parallel output projection with an explicit
    psum_scatter("model") onto the SEQUENCE dim, producing the
    sequence-parallel layout directly (half the bytes of the all-reduce
    XLA otherwise emits).  Used when opts['rs_matmul'] is set and the
    contraction dims are 'model'-sharded."""
    from jax.experimental.shard_map import shard_map
    bt = env.batch_axes
    S = y.shape[1]
    if (env.tp <= 1 or S % env.tp != 0
            or not env.opts.get("rs_matmul", False)):
        return jnp.einsum(einsum_str, y, w)
    d_out = w.shape[-1]
    y_spec = env.spec(y.shape, [bt, None, "model", None])
    w_spec = env.spec(w.shape, ["model", None, env.fsdp_axis])
    out_spec = env.spec((y.shape[0], S, d_out), [bt, "model", None])
    if w_spec[-1] is not None:          # FSDP'd weight: gather inside
        pass

    def body(yb, wb):
        if wb.shape[-1] != d_out:       # FSDP shard: gather over data
            wb = lax.all_gather(wb, env.fsdp_axis, axis=2, tiled=True)
        part = jnp.einsum(einsum_str, yb, wb)
        return lax.psum_scatter(part, "model", scatter_dimension=1,
                                tiled=True)

    fn = shard_map(body, mesh=env.mesh, in_specs=(y_spec, w_spec),
                   out_specs=out_spec, check_rep=False)
    return fn(y, w)


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU)
# ---------------------------------------------------------------------------
def ffn_swiglu(x, p, env: ShardingEnv):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = env.cs(h, env.batch_axes, None, "model")
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# MoE — dense reference (oracle; small shapes only)
# ---------------------------------------------------------------------------
def moe_router(x2d, router_w, top_k: int):
    logits = (x2d @ router_w).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e


def moe_dense_ref(x2d, p, cfg):
    """Computes every expert then masks — exact oracle for moe_ep."""
    top_p, top_e = moe_router(x2d, p["router"], cfg.top_k)
    h1 = jnp.einsum("td,edf->tef", x2d, p["w1"])
    h3 = jnp.einsum("td,edf->tef", x2d, p["w3"])
    h = jax.nn.silu(h1) * h3
    y_e = jnp.einsum("tef,efd->ted", h, p["w2"])        # (T,E,d)
    T = x2d.shape[0]
    gate = jnp.zeros((T, cfg.n_experts), dtype=F32)
    gate = gate.at[jnp.arange(T)[:, None], top_e].add(top_p)
    y = jnp.einsum("ted,te->td", y_e.astype(F32), gate)
    return y.astype(x2d.dtype)


# ---------------------------------------------------------------------------
# MoE — capacity-buffer dispatch (local math, shared by ep/single-device)
# ---------------------------------------------------------------------------
def _moe_local(x2d, router_w, w1, w3, w2, *, n_experts: int, top_k: int,
               e_start: int, e_local: int, capacity: int):
    """Route local tokens to experts [e_start, e_start+e_local) with a
    static-capacity buffer.  All ops are local (no collectives) so this is
    safe inside shard_map."""
    T, d = x2d.shape
    top_p, top_e = moe_router(x2d, router_w, top_k)     # (T,k)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)

    local = (flat_e >= e_start) & (flat_e < e_start + e_local)
    loc_e = jnp.where(local, flat_e - e_start, e_local)  # overflow bucket
    order = jnp.argsort(loc_e, stable=True)
    s_e = loc_e[order]
    s_t = flat_t[order]
    s_p = flat_p[order]
    counts = jnp.bincount(s_e, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(s_e.shape[0]) - starts[s_e]
    keep = (pos < capacity) & (s_e < e_local)
    slot = jnp.where(keep, s_e * capacity + pos, e_local * capacity)

    buf = jnp.zeros((e_local * capacity + 1, d), dtype=x2d.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x2d[s_t], 0))
    buf = buf[:-1].reshape(e_local, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) * \
        jnp.einsum("ecd,edf->ecf", buf, w3)
    out = jnp.einsum("ecf,efd->ecd", h, w2)             # (e_local,C,d)

    rows = out.reshape(e_local * capacity, -1)
    gathered = jnp.where(keep[:, None], rows[jnp.minimum(slot, rows.shape[0] - 1)], 0)
    y = jnp.zeros((T, rows.shape[-1]), dtype=F32)
    y = y.at[s_t].add(gathered.astype(F32) * s_p[:, None])
    return y.astype(x2d.dtype)


def moe_ep(x, p, cfg, env: ShardingEnv, capacity_factor: float = 1.25):
    """Expert-parallel MoE via shard_map over the 'model' axis.

    Experts shard over 'model' when divisible (deepseek 160, jamba 16);
    otherwise every shard computes all experts over a d_ff slice
    (mixtral 8 experts over tp=16).  Expert weights are FSDP-sharded over
    'data' on d_model and all-gathered inside the body.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    if env.mesh is None:
        y2 = moe_dense_ref(x.reshape(-1, d), p, cfg)
        return y2.reshape(B, S, d)

    ep = env.moe_ep(E)
    fullshard = env.opts.get("serve_fullshard") and ep and \
        "data" in env.axis_sizes
    tp_ax, fsdp_ax = env.tp_axis, env.fsdp_axis or "data"
    bt = None if fullshard else env.batch_axes
    x_spec = env.spec(x.shape, [bt, None, None])
    r_spec = env.spec(p["router"].shape,
                      [None if fullshard else env.fsdp_axis, None])
    if fullshard:
        # experts over 'model', d_model over 'data': weights fully
        # sharded 256-way; tokens replicated; partial-d contraction +
        # psum("data") replaces the FSDP weight all-gather entirely.
        w1_spec = env.spec(p["w1"].shape, [tp_ax, "data", None])
        w2_spec = env.spec(p["w2"].shape, [tp_ax, None, "data"])
    elif ep:
        w1_spec = env.spec(p["w1"].shape, [tp_ax, env.fsdp_axis, None])
        w2_spec = env.spec(p["w2"].shape, [tp_ax, None, env.fsdp_axis])
    else:
        w1_spec = env.spec(p["w1"].shape, [None, env.fsdp_axis, tp_ax])
        w2_spec = env.spec(p["w2"].shape, [None, tp_ax, env.fsdp_axis])
    out_spec = x_spec

    e_local = E // env.tp if ep else E
    # tokens per data-shard replica inside the body (use the PRUNED spec:
    # divisibility pruning may have left the batch replicated):
    b_shards = env.axis_size(x_spec[0]) if len(x_spec) else 1
    t_local = (B // max(b_shards, 1)) * S
    capacity = max(4, int(math.ceil(t_local * k / E * capacity_factor)))
    d_local = d // env.axis_sizes.get("data", 1)

    def body_fullshard(xb, rw, w1, w3, w2):
        T = xb.shape[0] * xb.shape[1]
        x2 = xb.reshape(T, d)
        e0 = lax.axis_index(tp_ax) * e_local
        top_p, top_e = moe_router(x2, rw, k)
        flat_e = top_e.reshape(-1)
        flat_p = top_p.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        local = (flat_e >= e0) & (flat_e < e0 + e_local)
        loc_e = jnp.where(local, flat_e - e0, e_local)
        order = jnp.argsort(loc_e, stable=True)
        s_e, s_t, s_p = loc_e[order], flat_t[order], flat_p[order]
        counts = jnp.bincount(s_e, length=e_local + 1)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(s_e.shape[0]) - starts[s_e]
        keep = (pos < capacity) & (s_e < e_local)
        slot = jnp.where(keep, s_e * capacity + pos, e_local * capacity)
        # dispatch only the LOCAL d-slice of each token
        didx = lax.axis_index("data") * d_local
        x2l = lax.dynamic_slice_in_dim(x2, didx, d_local, axis=1)
        buf = jnp.zeros((e_local * capacity + 1, d_local), dtype=x2.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], x2l[s_t], 0))
        buf = buf[:-1].reshape(e_local, capacity, d_local)
        # partial-d contraction + psum over 'data' (weights never move)
        h1 = lax.psum(jnp.einsum("ecd,edf->ecf", buf, w1), "data")
        h3 = lax.psum(jnp.einsum("ecd,edf->ecf", buf, w3), "data")
        h = jax.nn.silu(h1) * h3
        out = jnp.einsum("ecf,efd->ecd", h, w2)   # (e_local, C, d_local)
        rows = out.reshape(e_local * capacity, d_local)
        gathered = jnp.where(keep[:, None],
                             rows[jnp.minimum(slot, rows.shape[0] - 1)], 0)
        y2 = jnp.zeros((T, d_local), dtype=F32)
        y2 = y2.at[s_t].add(gathered.astype(F32) * s_p[:, None])
        y2 = lax.psum(y2, tp_ax)                  # combine experts
        y2 = lax.all_gather(y2, "data", axis=1, tiled=True)  # (T, d)
        return y2.astype(xb.dtype).reshape(xb.shape)

    def body(xb, rw, w1, w3, w2):
        T = xb.shape[0] * xb.shape[1]
        x2 = xb.reshape(T, d)
        rw = _maybe_gather(rw, env.fsdp_axis, 0, env, p["router"].shape[0])
        w1 = _maybe_gather(w1, env.fsdp_axis, 1, env, p["w1"].shape[1])
        w3 = _maybe_gather(w3, env.fsdp_axis, 1, env, p["w3"].shape[1])
        w2 = _maybe_gather(w2, env.fsdp_axis, 2, env, p["w2"].shape[2])
        if ep:
            e0 = lax.axis_index(tp_ax) * e_local
        else:
            e0 = 0
        y2 = _moe_local(x2, rw, w1, w3, w2, n_experts=E, top_k=k,
                        e_start=e0, e_local=e_local, capacity=capacity)
        y2 = lax.psum(y2, tp_ax)
        return y2.reshape(xb.shape)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(body_fullshard if fullshard else body, mesh=env.mesh,
                   in_specs=(x_spec, r_spec, w1_spec, w1_spec, w2_spec),
                   out_specs=out_spec, check_rep=False)
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"])


def _maybe_gather(w, axis_name, dim, env, full_dim):
    """all_gather a weight block along `axis_name` if it was FSDP-sharded."""
    if axis_name is None or env.axis_sizes.get(axis_name, 1) == 1:
        return w
    if w.shape[dim] == full_dim:    # divisibility pruning left it whole
        return w
    return lax.all_gather(w, axis_name, axis=dim, tiled=True)


def moe_block(x, p, cfg, env: ShardingEnv, impl: str = "ep"):
    """MoE FFN + optional shared experts."""
    B, S, d = x.shape
    if impl == "dense" or env.mesh is None:
        y = moe_dense_ref(x.reshape(-1, d), p, cfg).reshape(B, S, d)
    else:
        y = moe_ep(x, p, cfg, env)
    if cfg.n_shared_experts:
        y = y + ffn_swiglu(x, {"w1": p["ws1"], "w3": p["ws3"],
                               "w2": p["ws2"]}, env)
    return y
