"""RWKV-6 "Finch" layer (data-dependent decay) in pure jnp.

Time-mix (WKV6 recurrence) + channel-mix, both with token-shift and the
ddlerp data-dependent interpolation [arXiv:2404.05892].  The sequential
scan carries (B, H, dk, dv) state — exactly the serving-session state
that SAGA schedules for attention-free archs.  ``repro.kernels.rwkv6``
holds the chunked Pallas fast path; this module is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import group_norm_heads

F32 = jnp.float32
DDLERP_W = 32      # ddlerp lora width
DECAY_W = 64       # decay lora width


def _token_shift(x, last):
    """Returns x_{t-1} with `last` (B,d) as the t=0 predecessor."""
    if last is None:
        last = jnp.zeros_like(x[:, :1, :])
    else:
        last = last[:, None, :].astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1, :]], axis=1)


def _wkv6_scan(r, k, v, w, u, state0):
    """r,k,w: (B,S,H,dk); v: (B,S,H,dv); u: (H,dk); state0: (B,H,dk,dv)."""
    def step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,dk|dv)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,dk,dv)
        out = ((S + u[None, :, :, None] * kv) * rt[..., :, None]).sum(axis=-2)
        S = wt[..., :, None] * S + kv
        return S, out

    from repro.models.layers import seq_scan
    xs = tuple(jnp.moveaxis(t.astype(F32), 1, 0) for t in (r, k, v, w))
    S_T, outs = seq_scan(step, state0.astype(F32), xs)
    return jnp.moveaxis(outs, 0, 1), S_T           # (B,S,H,dv), (B,H,dk,dv)


def rwkv6_time_mix(x, p, cfg, env, *, shift_state=None, wkv_state=None,
                   return_state: bool = False):
    B, S, d = x.shape
    H = cfg.rwkv_n_heads
    hs = cfg.rwkv_head_size

    xprev = _token_shift(x, shift_state)
    dx = (xprev - x).astype(F32)
    xf = x.astype(F32)

    xxx = xf + dx * p["maa_x"].astype(F32)
    kk = jnp.tanh(xxx @ p["maa_w1"].astype(F32))            # (B,S,5W)
    kk = kk.reshape(B, S, 5, DDLERP_W)
    mix = jnp.einsum("bsfw,fwd->fbsd", kk, p["maa_w2"].astype(F32))
    mw, mk, mv, mr, mg = mix[0], mix[1], mix[2], mix[3], mix[4]

    xw = (xf + dx * (p["maa_w"].astype(F32) + mw)).astype(x.dtype)
    xk = (xf + dx * (p["maa_k"].astype(F32) + mk)).astype(x.dtype)
    xv = (xf + dx * (p["maa_v"].astype(F32) + mv)).astype(x.dtype)
    xr = (xf + dx * (p["maa_r"].astype(F32) + mr)).astype(x.dtype)
    xg = (xf + dx * (p["maa_g"].astype(F32) + mg)).astype(x.dtype)

    r = (xr @ p["Wr"]).reshape(B, S, H, hs)
    k = (xk @ p["Wk"]).reshape(B, S, H, hs)
    v = (xv @ p["Wv"]).reshape(B, S, H, hs)
    g = jax.nn.silu((xg @ p["Wg"]).astype(F32))

    dec = p["decay"].astype(F32) + \
        jnp.tanh(xw.astype(F32) @ p["decay_w1"].astype(F32)) @ \
        p["decay_w2"].astype(F32)                            # (B,S,d)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hs)

    r = env.cs(r, env.batch_axes, None, "model", None)
    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, hs, hs), dtype=F32)
    out, S_T = _wkv6_scan(r, k, v, w, p["faaaa"].astype(F32), wkv_state)

    out = group_norm_heads(out.reshape(B, S, d), p["ln_x"], H, cfg.norm_eps)
    out = (out.astype(F32) * g).astype(x.dtype)
    y = out @ p["Wo"]
    if return_state:
        return y, x[:, -1, :], S_T
    return y


def rwkv6_channel_mix(x, p, cfg, env, *, shift_state=None,
                      return_state: bool = False):
    xprev = _token_shift(x, shift_state)
    dx = (xprev - x).astype(F32)
    xf = x.astype(F32)
    xk = (xf + dx * p["cmix_maa_k"].astype(F32)).astype(x.dtype)
    xr = (xf + dx * p["cmix_maa_r"].astype(F32)).astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["Wck"]))
    h = env.cs(h, env.batch_axes, None, "model")
    v = h @ p["Wcv"]
    y = (jax.nn.sigmoid((xr @ p["Wcr"]).astype(F32)) * v.astype(F32)
         ).astype(x.dtype)
    if return_state:
        return y, x[:, -1, :]
    return y
