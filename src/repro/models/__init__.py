"""JAX model zoo: init / forward / prefill / decode for all 10 assigned
architectures plus the paper's Llama-3-70B serving model."""
from repro.models.lm import (abstract_cache, abstract_params, cache_pspecs,
                             decode_step, forward_logits, forward_train,
                             init_cache, init_params, param_rules,
                             param_shardings, prefill)
from repro.models.sharding import ShardingEnv

__all__ = [
    "abstract_cache", "abstract_params", "cache_pspecs", "decode_step",
    "forward_logits", "forward_train", "init_cache", "init_params",
    "param_rules", "param_shardings", "prefill", "ShardingEnv",
]
