"""AgentProgram: graph-structured, dynamically-resolved agent workflows
as the schedulable unit (paper §3.1-§3.3).

See ``repro.workflow`` for the flavor overview.  Determinism contract:
every random choice a program makes flows through two per-instance
seeded streams derived from a stable FNV-1a hash of the program id —

  * the **path stream** resolves taken edges (graph flavor) and feeds
    the dynamic callback's ``ctx.rng``, so the executed node path for a
    given (program_id, seed) is identical across processes AND across
    the two execution substrates;
  * the **realization stream** samples unspecified tool latencies and
    generates prompt token ids (runtime), so realization draws never
    perturb the path.

Nothing here touches Python's builtin ``hash`` or global RNG state, so
identical-seed runs stay byte-identical across ``PYTHONHASHSEED``.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.workload import (TOOL_LATENCY_TABLE, Step,
                                    lognormal_params, sample_tool_latency)
from repro.core.aeg import AEG

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_FNV_MASK = 0xFFFFFFFFFFFFFFFF


def _fnv1a(s: str) -> int:
    h = _FNV_OFFSET
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * _FNV_PRIME) & _FNV_MASK
    return h


def _median_latency(tool: str) -> float:
    mu, _ = lognormal_params(tool)
    return math.exp(mu)


@dataclass
class StepSpec:
    """Declared parameters of one workflow step (one AEG node).

    Carries both representations so one spec drives both substrates:
    the simulator's float token economics (``new_prompt_tokens`` /
    ``out_tokens`` / ``obs_tokens``) and the serving runtime's real
    realization (``prompt_ids`` / ``n_out``).  Whichever side is
    omitted is derived from the other; ``tool_latency_s=None`` samples
    a fresh Table-1 log-normal latency per *execution* (a retry edge
    revisiting the node re-rolls the tool)."""
    tool: str
    new_prompt_tokens: Optional[float] = None
    out_tokens: Optional[float] = None
    obs_tokens: float = 0.0
    tool_latency_s: Optional[float] = None
    prompt_ids: Optional[List[int]] = None
    n_out: Optional[int] = None

    def __post_init__(self) -> None:
        if self.new_prompt_tokens is None and self.prompt_ids is None:
            raise ValueError(
                f"StepSpec({self.tool}): need new_prompt_tokens or "
                f"prompt_ids")
        if self.out_tokens is None and self.n_out is None:
            raise ValueError(
                f"StepSpec({self.tool}): need out_tokens or n_out")

    # -- derived views ---------------------------------------------------
    def sim_prompt_tokens(self) -> float:
        if self.new_prompt_tokens is not None:
            return self.new_prompt_tokens
        return float(len(self.prompt_ids))

    def sim_out_tokens(self) -> float:
        if self.out_tokens is not None:
            return self.out_tokens
        return float(self.n_out)

    def rt_n_out(self) -> int:
        if self.n_out is not None:
            return self.n_out
        return max(1, int(round(self.out_tokens)))

    def rt_n_prompt(self) -> int:
        if self.prompt_ids is not None:
            return len(self.prompt_ids)
        return max(1, int(round(self.new_prompt_tokens)))


@dataclass
class DynamicContext:
    """What a dynamic program's callback sees when deciding the next
    step: the executed history, per-step outputs (runtime: decoded
    token-id lists; simulator: ``out_tokens`` floats), the completed
    step's tool observation size, and the instance's seeded path RNG
    (use it — not global randomness — to keep replays byte-identical)."""
    step_idx: int                  # index of the step that just finished
    history: Sequence[Step]        # executed steps, economics view
    outputs: Sequence[object]      # per-step outputs so far
    last_tool: str                 # tool the finished step invokes
    last_obs_tokens: float         # its observation size
    rng: random.Random             # deterministic per-instance stream


@dataclass
class AgentProgram:
    """One agent workflow submission, consumed by BOTH ``ClusterSim``
    and ``ServingRuntime``.  Use the ``scripted`` / ``graph`` /
    ``dynamic`` constructors (or the ``from_task`` / ``from_request``
    backward-compat adapters) rather than filling fields by hand."""
    program_id: str
    tenant: str
    kind: str                              # scripted | graph | dynamic
    arrival_s: float = 0.0
    prefix_tokens: float = 0.0
    seed: int = 0
    max_steps: int = 64                    # cycle guard for graph/dynamic
    workload: str = "program"
    steps: Optional[List[StepSpec]] = None             # scripted
    nodes: Optional[Dict[int, StepSpec]] = None        # graph
    edges: Optional[List[Tuple[int, int, float]]] = None
    entry: int = 0
    next_step_fn: Optional[Callable[[DynamicContext],
                                    Optional[StepSpec]]] = None
    planned_tools: Optional[List[str]] = None          # dynamic hint

    # -- constructors ----------------------------------------------------
    @classmethod
    def scripted(cls, program_id: str, tenant: str,
                 steps: Sequence[StepSpec], *, arrival_s: float = 0.0,
                 prefix_tokens: float = 0.0, seed: int = 0,
                 workload: str = "program") -> "AgentProgram":
        if not steps:
            raise ValueError("scripted program needs at least one step")
        return cls(program_id, tenant, "scripted", arrival_s,
                   prefix_tokens, seed, len(steps), workload,
                   steps=list(steps))

    @classmethod
    def graph(cls, program_id: str, tenant: str,
              nodes: Dict[int, StepSpec],
              edges: Sequence[Tuple[int, int, float]], *,
              entry: int = 0, arrival_s: float = 0.0,
              prefix_tokens: float = 0.0, seed: int = 0,
              max_steps: int = 64,
              workload: str = "program") -> "AgentProgram":
        """Explicit-AEG flavor: ``edges`` are (u, v, p) with p the
        probability of taking u->v; residual mass at a node (1 - sum of
        its out-edge probabilities) terminates the workflow there.  A
        node with no out-edges is terminal after it executes."""
        if entry not in nodes:
            raise ValueError(f"entry node {entry} not in nodes")
        out: Dict[int, float] = {}
        for u, v, p in edges:
            if u not in nodes or v not in nodes:
                raise ValueError(f"edge ({u},{v}) references unknown node")
            if p < 0.0:
                raise ValueError(f"edge ({u},{v}) probability {p} < 0")
            out[u] = out.get(u, 0.0) + p
        for u, tot in out.items():
            if tot > 1.0 + 1e-9:
                raise ValueError(
                    f"node {u} out-probabilities sum to {tot} > 1")
        return cls(program_id, tenant, "graph", arrival_s, prefix_tokens,
                   seed, max_steps, workload, nodes=dict(nodes),
                   edges=list(edges), entry=entry)

    @classmethod
    def dynamic(cls, program_id: str, tenant: str,
                next_step_fn: Callable[[DynamicContext],
                                       Optional[StepSpec]], *,
                planned_tools: Optional[Sequence[str]] = None,
                arrival_s: float = 0.0, prefix_tokens: float = 0.0,
                seed: int = 0, max_steps: int = 64,
                workload: str = "program") -> "AgentProgram":
        """Callback flavor: ``next_step_fn(ctx)`` returns the next
        ``StepSpec`` (or None to finish).  Called once before the first
        step (empty history) and once at each park boundary."""
        return cls(program_id, tenant, "dynamic", arrival_s,
                   prefix_tokens, seed, max_steps, workload,
                   next_step_fn=next_step_fn,
                   planned_tools=list(planned_tools or []))

    # -- backward-compat adapters ---------------------------------------
    @classmethod
    def from_task(cls, task) -> "AgentProgram":
        """Compile a ``cluster.workload.Task`` into a scripted program.
        The instance reuses the task's ``Step`` objects directly, so the
        simulator sees bit-identical economics."""
        prog = cls(task.task_id, task.tenant, "scripted", task.arrival_s,
                   task.prefix_tokens, 0, max(len(task.steps), 1),
                   task.workload)
        prog._raw_steps = task.steps          # shared, never mutated
        return prog

    @classmethod
    def from_request(cls, req) -> "AgentProgram":
        """Compile a ``serving.runtime.AgentRequest`` into a scripted
        program.  The instance reuses the request's step tuples, so the
        runtime prefills bit-identical token ids."""
        prog = cls(req.session_id, req.tenant, "scripted", req.arrival_s,
                   0.0, 0, max(len(req.steps), 1), "request")
        prog._raw_rt_steps = req.steps        # shared, never mutated
        return prog

    # -- instantiation ---------------------------------------------------
    def instantiate(self, *, vocab: Optional[int] = None,
                    max_ctx_tokens: Optional[int] = None,
                    max_gap_s: Optional[float] = None
                    ) -> "WorkflowInstance":
        return WorkflowInstance(self, vocab=vocab,
                                max_ctx_tokens=max_ctx_tokens,
                                max_gap_s=max_gap_s)


class WorkflowInstance:
    """Execution cursor for one submitted program: materializes the
    taken path lazily and presents BOTH substrate surfaces.

    Simulator surface (Task-shaped): ``task_id`` / ``tenant`` /
    ``workload`` / ``arrival_s`` / ``prefix_tokens`` / ``steps`` (the
    materialized ``workload.Step`` list, grows as branches resolve) /
    ``n_steps`` / O(1) ``context_before`` / ``context_after`` /
    ``tools()``.

    Runtime surface: ``rt_step(i)`` -> (prompt token ids, n_out, tool,
    gap seconds), materialized alongside ``steps`` when the instance
    was created with ``vocab``.

    Advancement: ``resolve_next(i, outputs=...)`` is called exactly once
    per executed step at the park boundary (LLM step i finished, its
    tool about to run); it resolves the taken edge / calls the dynamic
    callback, materializes step i+1, and returns it — or None when the
    workflow terminates.  Memoized, so fault-retried steps never re-roll
    the path.
    """

    def __init__(self, program: AgentProgram, *,
                 vocab: Optional[int] = None,
                 max_ctx_tokens: Optional[int] = None,
                 max_gap_s: Optional[float] = None):
        self.program = program
        self.task_id = program.program_id
        self.tenant = program.tenant
        self.workload = program.workload
        self.arrival_s = program.arrival_s
        self.prefix_tokens = program.prefix_tokens
        self._vocab = vocab
        self._max_ctx = max_ctx_tokens
        self._max_gap_s = max_gap_s
        base = _fnv1a(program.program_id) ^ (program.seed & _FNV_MASK)
        self._rng_path = random.Random(base)
        self._rng_real = random.Random((base * _FNV_PRIME + 1) & _FNV_MASK)
        self.steps: List[Step] = []
        self.rt_steps: List[Tuple[List[int], int, str, float]] = []
        self.path: List[int] = []              # node id per executed step
        self._terminated = False
        self.truncated = False                 # ended by the context cap,
        self._rt_ctx = 0                       # not by the graph/callback
        self._cum: List[float] = [self.prefix_tokens]
        self._succs: Dict[int, List[Tuple[int, float]]] = {}
        self._nominal: Optional[List[Step]] = None
        self._aeg: Optional[AEG] = None
        if program.kind == "graph":
            for u, v, p in program.edges:
                self._succs.setdefault(u, []).append((v, p))
            tools = {nid: s.tool for nid, s in program.nodes.items()}
            self._aeg = AEG.from_edges(tools, program.edges)
            self._materialize(program.nodes[program.entry], program.entry)
        elif program.kind == "dynamic":
            first = program.next_step_fn(self._ctx(-1, []))
            if first is None:
                raise ValueError(
                    f"dynamic program {self.task_id}: first callback "
                    f"returned None (a program needs >= 1 step)")
            self._materialize(first, 0)
        else:                                  # scripted
            raw = getattr(program, "_raw_steps", None)
            raw_rt = getattr(program, "_raw_rt_steps", None)
            if raw is not None and vocab is None:
                # Task adapter on the simulator: share the Step objects
                # so execution is bit-identical to the pre-API path
                self.steps = raw
                self.path = list(range(len(raw)))
            elif raw is not None:
                # Task adapter on the serving runtime: realize token
                # ids from the realization stream; the context cap
                # truncates (flagged) rather than crashing mid-run
                for s in raw:
                    ids = [self._rng_real.randrange(1, vocab)
                           for _ in range(max(1,
                                              int(round(s.new_prompt_tokens))))]
                    n_out = max(1, int(round(s.out_tokens)))
                    if self._max_ctx is not None and \
                            self._rt_ctx + len(ids) + n_out > self._max_ctx:
                        if not self.steps:
                            raise ValueError(
                                f"program {self.task_id}: first step "
                                f"({len(ids)}+{n_out} tokens) does not "
                                f"fit max_ctx={self._max_ctx}")
                        self._terminated = True
                        self.truncated = True
                        break
                    self._rt_ctx += len(ids) + n_out
                    self.steps.append(s)
                    self.rt_steps.append((ids, n_out, s.tool,
                                          s.tool_latency_s))
                    self.path.append(len(self.path))
            elif raw_rt is not None:           # AgentRequest adapter
                for p, n, tool, gap in raw_rt:
                    self.steps.append(Step(float(len(p)), float(n), tool,
                                           0.0, float(gap)))
                self.rt_steps = raw_rt
                self.path = list(range(len(raw_rt)))
            else:
                for i, spec in enumerate(program.steps):
                    self._materialize(spec, i)

    # -- materialization -------------------------------------------------
    def _ctx(self, step_idx: int, outputs: Sequence[object]
             ) -> DynamicContext:
        last_tool = self.steps[step_idx].tool if 0 <= step_idx else ""
        last_obs = self.steps[step_idx].obs_tokens if 0 <= step_idx \
            else 0.0
        return DynamicContext(step_idx, self.steps, outputs, last_tool,
                              last_obs, self._rng_path)

    def _materialize(self, spec: StepSpec, node_id: int) -> bool:
        """Realize one StepSpec as the next executed step.  Returns
        False (and terminates the workflow) when the runtime context cap
        would be exceeded — the realization-side twin of the graph's
        ``max_steps`` cycle guard."""
        gap = spec.tool_latency_s
        if gap is None:
            gap = sample_tool_latency(spec.tool, self._rng_real)
            if self._max_gap_s is not None:
                gap = min(gap, self._max_gap_s)
        step = Step(spec.sim_prompt_tokens(), spec.sim_out_tokens(),
                    spec.tool, spec.obs_tokens, gap)
        if self._vocab is not None:
            ids = spec.prompt_ids
            if ids is None:
                ids = [self._rng_real.randrange(1, self._vocab)
                       for _ in range(spec.rt_n_prompt())]
            n_out = spec.rt_n_out()
            if self._max_ctx is not None and \
                    self._rt_ctx + len(ids) + n_out > self._max_ctx:
                if not self.steps:
                    raise ValueError(
                        f"program {self.task_id}: first step "
                        f"({len(ids)}+{n_out} tokens) does not fit "
                        f"max_ctx={self._max_ctx}")
                # flagged: a truncated run's taken path is a PREFIX of
                # the unconstrained path, so cross-substrate path
                # identity only holds while ``truncated`` is False
                self._terminated = True
                self.truncated = True
                return False
            self._rt_ctx += len(ids) + n_out
            self.rt_steps.append((list(ids), n_out, spec.tool, gap))
        self.steps.append(step)
        self.path.append(node_id)
        return True

    # -- advancement (the park-boundary resolver) ------------------------
    def resolve_next(self, i: int,
                     outputs: Optional[Sequence[object]] = None
                     ) -> Optional[Step]:
        if i + 1 < len(self.steps):
            return self.steps[i + 1]           # memoized (fault retry)
        if self._terminated or i + 1 >= self.program.max_steps:
            self._terminated = True
            return None
        kind = self.program.kind
        if kind == "scripted":
            self._terminated = True            # all steps prematerialized
            return None
        if kind == "graph":
            node = self.path[i]
            succs = self._succs.get(node, ())
            u = self._rng_path.random()
            acc = 0.0
            for v, p in succs:
                acc += p
                if u < acc:
                    if self._materialize(self.program.nodes[v], v):
                        return self.steps[-1]
                    return None
            self._terminated = True            # residual mass: finish
            return None
        if outputs is None:
            # simulator-side default: the economics view of each
            # executed step's output (the runtime passes real token ids)
            outputs = [s.out_tokens for s in self.steps[:i + 1]]
        spec = self.program.next_step_fn(self._ctx(i, outputs))
        if spec is None:
            self._terminated = True
            return None
        if self._materialize(spec, i + 1):
            return self.steps[-1]
        return None

    def next_node_hint(self, step_idx: int) -> Optional[int]:
        """AEG node id of materialized step ``step_idx`` for graph
        programs (the taken edge, threaded into the coordinator), None
        for scripted/dynamic (legacy linear advancement)."""
        if self.program.kind != "graph" or step_idx >= len(self.path):
            return None
        return self.path[step_idx]

    # -- Task-shaped simulator surface -----------------------------------
    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def _ensure_cum(self) -> List[float]:
        cum = self._cum
        while len(cum) < len(self.steps) + 1:
            s = self.steps[len(cum) - 1]
            cum.append(cum[-1] + (s.new_prompt_tokens + s.out_tokens +
                                  s.obs_tokens))
        return cum

    def context_after(self, step_idx: int) -> float:
        return self._ensure_cum()[step_idx + 1]

    def context_before(self, step_idx: int) -> float:
        return self._ensure_cum()[step_idx] + \
            self.steps[step_idx].new_prompt_tokens

    def tools(self) -> List[str]:
        return [s.tool for s in self.nominal_steps()]

    # -- planning estimates ----------------------------------------------
    def nominal_steps(self) -> List[Step]:
        """Expected path for admission-time estimates (deadline, Eq. 9
        work, ideal time).  Scripted: the actual steps.  Graph: the
        max-probability path (capped at ``max_steps``), median-latency
        where unspecified.  Dynamic: the ``planned_tools`` hint with
        default economics, or a single default step.  Never consumes
        instance RNG state — estimates must not perturb the path."""
        if self.program.kind == "scripted":
            return self.steps
        if self._nominal is not None:
            return self._nominal
        out: List[Step] = []
        if self.program.kind == "graph":
            for node in self._nominal_path():
                out.append(self._nominal_step(self.program.nodes[node]))
        else:
            tools = self.program.planned_tools or ["unknown"]
            for t in tools:
                lat = _median_latency(t) if t in TOOL_LATENCY_TABLE \
                    else 1.0
                out.append(Step(300.0, 150.0, t, 600.0, lat))
        self._nominal = out
        return out

    def _nominal_path(self) -> List[int]:
        """Max-probability node walk, discounted by edge mass: stop once
        the probability of still being in the workflow drops below 0.5
        (so low-probability cycles — retry loops, self-loops — don't
        inflate the estimate to ``max_steps``)."""
        nodes, mass = [self.program.entry], 1.0
        while len(nodes) < self.program.max_steps:
            succs = self._succs.get(nodes[-1], ())
            if not succs:
                break
            mass *= sum(p for _, p in succs)
            if mass < 0.5:
                break
            nodes.append(max(succs, key=lambda vp: vp[1])[0])
        return nodes

    def _nominal_step(self, spec: StepSpec) -> Step:
        lat = spec.tool_latency_s
        if lat is None:
            lat = _median_latency(spec.tool)
            if self._max_gap_s is not None:
                lat = min(lat, self._max_gap_s)
        return Step(spec.sim_prompt_tokens(), spec.sim_out_tokens(),
                    spec.tool, spec.obs_tokens, lat)

    def nominal_rt_counts(self) -> List[Tuple[int, int, str]]:
        """(n_prompt, n_out, tool) per nominal step — the runtime's
        admission-time work estimate.  For scripted programs these are
        the exact realized counts."""
        if self.program.kind == "scripted" and self.rt_steps:
            return [(len(p), n, t) for p, n, t, _ in self.rt_steps]
        out = []
        if self.program.kind == "graph":
            return [(self.program.nodes[n].rt_n_prompt(),
                     self.program.nodes[n].rt_n_out(),
                     self.program.nodes[n].tool)
                    for n in self._nominal_path()]
        return [(max(1, int(round(s.new_prompt_tokens))),
                 max(1, int(round(s.out_tokens))), s.tool)
                for s in self.nominal_steps()]

    def declared_aeg(self) -> Optional[AEG]:
        """The client-declared AEG (tier-a observability) — graph
        programs only."""
        return self._aeg

    # -- runtime surface -------------------------------------------------
    def rt_step(self, i: int) -> Tuple[List[int], int, str, float]:
        return self.rt_steps[i]


def as_instance(obj, *, vocab: Optional[int] = None,
                max_ctx_tokens: Optional[int] = None,
                max_gap_s: Optional[float] = None) -> WorkflowInstance:
    """Normalize any submission format to a fresh WorkflowInstance:
    AgentProgram -> instantiate; Task / AgentRequest -> scripted adapter
    (byte-identical execution); an existing instance passes through."""
    if isinstance(obj, WorkflowInstance):
        return obj
    if isinstance(obj, AgentProgram):
        return obj.instantiate(vocab=vocab, max_ctx_tokens=max_ctx_tokens,
                               max_gap_s=max_gap_s)
    if hasattr(obj, "task_id"):               # cluster.workload.Task
        return AgentProgram.from_task(obj).instantiate(
            vocab=vocab, max_ctx_tokens=max_ctx_tokens,
            max_gap_s=max_gap_s)
    if hasattr(obj, "session_id"):            # serving AgentRequest
        return AgentProgram.from_request(obj).instantiate(
            vocab=vocab, max_ctx_tokens=max_ctx_tokens,
            max_gap_s=max_gap_s)
    raise TypeError(f"cannot submit {type(obj).__name__} as a workflow")
