"""Unified AgentProgram submission API (paper §3.1-§3.3).

The *workflow* — not the request — is the schedulable unit.  An
``AgentProgram`` is one submission format consumed by BOTH execution
substrates (the discrete-event ``ClusterSim`` and the real-inference
``ServingRuntime``) in three flavors:

  * **scripted** — a pre-resolved linear step list.  The legacy
    ``cluster.workload.Task`` and ``serving.runtime.AgentRequest``
    formats compile to this flavor through thin adapters
    (``AgentProgram.from_task`` / ``AgentProgram.from_request``), so
    every existing entry point keeps working byte-identically.
  * **graph** — an explicit Agent Execution Graph (tier-a
    observability, §3.3): per-node step parameters plus probabilistic
    edges.  Branches *execute* — a seeded per-program RNG resolves the
    taken edge at each park boundary — and the declared AEG is handed
    to the ``GlobalCoordinator`` at admission, so reuse probability
    (Eq. 4), prefetch targeting (§4.3), tool TTLs (§4.2) and AFS
    work-remaining (Eq. 9) all operate on the true branch structure.
  * **dynamic** — a client callback decides the next step from prior
    step outputs and the tool observation, resolved deterministically
    at park/resume boundaries in virtual time (the tier-b/c path where
    ``PatternInferencer`` drives predictions).

``WorkflowInstance`` is the per-run execution cursor: it materializes
the taken path lazily, keeps O(1) cumulative context sums, and exposes
the Task-shaped surface the simulator schedules plus the token-id
realization the serving runtime prefills.
"""
from repro.workflow.program import (AgentProgram, DynamicContext,
                                    StepSpec, WorkflowInstance,
                                    as_instance)

__all__ = ["AgentProgram", "DynamicContext", "StepSpec",
           "WorkflowInstance", "as_instance"]
