"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H (GQA kv=128) d_ff=1536 vocab=102400  [arXiv:2405.04434; hf]
d_ff=1536 is the per-expert (MoE) intermediate size per the assigned spec.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: all heads share the compressed latent
    d_ff=1536,
    vocab=102400,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=1e4,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-tiny", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
        n_experts=8, top_k=2, n_shared_experts=1,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
