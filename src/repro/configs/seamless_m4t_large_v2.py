"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal; frame frontend STUB.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206  [arXiv:2308.11596; hf]
24 encoder + 24 decoder layers (speech encoder / text decoder, large-v2).
Encoder input is precomputed frame embeddings (the conformer feature
frontend is stubbed per the assignment).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=48,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    enc_dec=True,
    n_enc_layers=24,
    n_dec_layers=24,
    frontend="frame_embed",
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="seamless-tiny", family="audio", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
        enc_dec=True, n_enc_layers=2, n_dec_layers=2,
        frontend="frame_embed")
