"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768  [arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1e6,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="mixtral-tiny", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        n_experts=4, top_k=2, sliding_window=32)
