"""qwen3-32b [dense] — qk_norm, GQA.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="qwen3-tiny", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        qk_norm=True)
