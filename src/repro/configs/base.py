"""Model configuration schema shared by every assigned architecture.

A single dataclass covers all six families (dense / moe / hybrid / ssm /
vlm / audio).  Family-specific switches are plain fields so a config is a
pure value object: configs never touch jax device state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds used by hybrid layouts.
ATTN = "attn"
MAMBA = "mamba"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free archs)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0               # routed experts (0 = dense FFN)
    top_k: int = 0
    n_shared_experts: int = 0
    moe_layer_period: int = 1        # MoE every k-th layer (jamba: 2)

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- attention variants ---
    sliding_window: int = 0          # 0 = full attention
    qk_norm: bool = False            # qwen3
    attn_logit_softcap: float = 0.0

    # --- hybrid (jamba): attention every attn_period layers, mamba else ---
    attn_period: int = 0             # 0 = all layers attention
    # --- mamba ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0           # 0 -> ceil(d_model/16)

    # --- rwkv6 ---
    rwkv_head_size: int = 64

    # --- enc-dec (seamless) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # --- modality frontend stub ---
    # none: token ids. patch_embed: precomputed image-patch embeddings are
    # prepended. frame_embed: encoder input is precomputed frames (enc-dec).
    frontend: str = "none"
    n_frontend_tokens: int = 0       # patches per image for vlm

    # --- common ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        if self.mamba_dt_rank:
            return self.mamba_dt_rank
        return -(-self.d_model // 16)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def layer_kind(self, i: int) -> str:
        """attn|mamba for layer i (hybrid layouts)."""
        if self.family == "ssm":
            return "rwkv"
        if self.attn_period:
            # jamba: one attention layer per attn_period block, at the middle
            # slot (index attn_period//2) of each block [arXiv:2403.19887].
            return ATTN if (i % self.attn_period) == self.attn_period // 2 else MAMBA
        return ATTN

    def layer_is_moe(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1) \
            if self.moe_layer_period > 1 else True

    # --- parameter counting (for roofline MODEL_FLOPS) -----------------
    def param_counts(self) -> Tuple[int, int]:
        """Returns (total_params, active_params) excluding embeddings.

        active counts only top_k (+shared) experts per MoE layer.
        """
        d, f = self.d_model, self.d_ff
        total = 0
        active = 0
        n_layers = (self.n_enc_layers + self.n_dec_layers) if self.enc_dec \
            else self.n_layers

        def attn_params() -> int:
            if self.use_mla:
                qh = self.qk_nope_head_dim + self.qk_rope_head_dim
                p = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qh
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            return (d * self.n_heads * self.head_dim
                    + 2 * d * self.n_kv_heads * self.head_dim
                    + self.n_heads * self.head_dim * d)

        def mamba_params() -> int:
            di = self.mamba_d_inner
            return (d * 2 * di + di * self.mamba_d_conv
                    + di * (self.dt_rank + 2 * self.mamba_d_state)
                    + self.dt_rank * di + di * self.mamba_d_state + di
                    + di * d)

        def rwkv_params() -> int:
            # time-mix ~ 4*d^2 + decay/mix lora; channel-mix ~ 2*d*3.5d
            return int(4 * d * d + 2 * d * 3.5 * d + 6 * d + d * 192 + d * 128)

        for i in range(n_layers):
            kind = self.layer_kind(i)
            if kind == ATTN:
                total += attn_params()
                active += attn_params()
            elif kind == MAMBA:
                total += mamba_params()
                active += mamba_params()
            else:
                total += rwkv_params()
                active += rwkv_params()
                continue  # rwkv params include channel mix
            if self.layer_is_moe(i):
                ffn = 3 * d * f
                total += self.n_experts * ffn + d * self.n_experts
                active += self.top_k * ffn
                if self.n_shared_experts:
                    total += self.n_shared_experts * ffn
                    active += self.n_shared_experts * ffn
            else:
                dense_f = f if not self.n_experts else f  # same width
                total += 3 * d * dense_f
                active += 3 * d * dense_f
        if self.enc_dec:
            # cross attention in decoder layers
            for _ in range(self.n_dec_layers):
                total += attn_params()
                active += attn_params()
        return total, active


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs  # noqa: F401
        import importlib
        for mod in configs.ALL_ARCH_MODULES:
            importlib.import_module(f"repro.configs.{mod}")
    if name not in _REGISTRY:
        raise KeyError(f"unknown config {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    get_config  # ensure registry populated on demand by callers
    return sorted(_REGISTRY)
