"""The paper's own evaluation model + small models for runnable examples.

SAGA's empirical evaluation serves Llama-3-70B-Instruct (GQA, L=80,
n_kv=8, d_h=128; §2.2) — a 32K-context session holds ~10.7 GB of KV.
We register it so the serving stack and dry-run can exercise the exact
model the paper schedules, and a ~100M config for CPU end-to-end drivers.
"""
from repro.configs.base import ModelConfig, register

LLAMA3_70B = register(ModelConfig(
    name="llama3-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    rope_theta=5e5,
))

# ~100M-param dense model for the end-to-end train/serve examples on CPU.
SMALL_100M = register(ModelConfig(
    name="small-100m",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab=32768,
))

# Micro model for fast engine/integration tests.
MICRO = register(ModelConfig(
    name="micro",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
))
