"""Assigned-architecture configs (one module per arch) + the paper config.

Canonical ids (use with ``--arch``):
  jamba-v0.1-52b  deepseek-v2-236b  mixtral-8x22b  command-r-35b
  mistral-nemo-12b  qwen3-32b  llama3.2-3b  llava-next-34b
  rwkv6-7b  seamless-m4t-large-v2
"""
from repro.configs.base import ModelConfig, get_config, list_configs, register

ALL_ARCH_MODULES = [
    "jamba_v0_1_52b",
    "deepseek_v2_236b",
    "mixtral_8x22b",
    "command_r_35b",
    "mistral_nemo_12b",
    "qwen3_32b",
    "llama3_2_3b",
    "llava_next_34b",
    "rwkv6_7b",
    "seamless_m4t_large_v2",
    "saga_paper",
]

ARCH_IDS = [
    "jamba-v0.1-52b",
    "deepseek-v2-236b",
    "mixtral-8x22b",
    "command-r-35b",
    "mistral-nemo-12b",
    "qwen3-32b",
    "llama3.2-3b",
    "llava-next-34b",
    "rwkv6-7b",
    "seamless-m4t-large-v2",
]


def load_all() -> None:
    import importlib
    for mod in ALL_ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


__all__ = ["ModelConfig", "get_config", "list_configs", "register",
           "ARCH_IDS", "ALL_ARCH_MODULES", "load_all"]
