"""command-r-35b [dense] — GQA, no-bias.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    rope_theta=8e6,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="command-r-tiny", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
