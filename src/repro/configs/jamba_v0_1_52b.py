"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536  [arXiv:2403.19887; hf]
Attention every 8th layer (1 attn : 7 mamba); MoE every other layer.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_layer_period=2,
    attn_period=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=1e4,
))


def tiny() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name="jamba-tiny", family="hybrid", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        n_experts=4, top_k=2, moe_layer_period=2, attn_period=4,
        mamba_d_state=8, mamba_d_conv=4, mamba_expand=2)
