"""mistral-nemo-12b [dense] — 128k ctx.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-tiny", family="dense", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
