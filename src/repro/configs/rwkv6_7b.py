"""rwkv6-7b [ssm] — Finch, data-dependent decay; attention-free.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536  [arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=14336,
    vocab=65536,
    rwkv_head_size=64,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-tiny", family="ssm", n_layers=3, d_model=64,
        n_heads=0, n_kv_heads=0, d_ff=128, vocab=256, rwkv_head_size=16)
