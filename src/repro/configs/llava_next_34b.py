"""llava-next-34b [vlm] — anyres tiling; backbone only, patch frontend STUB.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Per the assignment the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, d_model) which are
prepended to the text token embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    frontend="patch_embed",
    n_frontend_tokens=2048,          # anyres tiling budget per image
    rope_theta=5e6,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llava-next-tiny", family="vlm", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        frontend="patch_embed", n_frontend_tokens=16)
