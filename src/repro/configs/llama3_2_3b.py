"""llama3.2-3b [dense] — small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    rope_theta=5e5,
    tie_embeddings=True,
))


def tiny() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-tiny", family="dense", n_layers=3, d_model=48,
        n_heads=3, n_kv_heads=1, head_dim=16, d_ff=128, vocab=256,
        tie_embeddings=True)
