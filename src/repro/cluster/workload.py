"""Agent workload generators (paper §9.1).

Three sources:
  * SWE-bench: 500 verified tasks, mean 37 steps (max 150); each step
    2-4K prompt tokens, 100-500 output tokens; code/file/db/web tools.
  * WebArena: 812 tasks, mean 18 steps; 4-8K prompt (page content),
    50-200 output tokens; web-heavy tools.
  * BurstGPT-derived multi-tenant: 10 tenants — 3 heavy (100-step,
    16 tasks/min), 4 medium (30-step, 8/min), 3 light (10-step, 4/min),
    Poisson arrivals (§9.1 "Workloads").

Tool latencies are log-normal fits of Table 1 (P50/P95/P99).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

# Table 1: (P50 s, P95 s, P99 s)
TOOL_LATENCY_TABLE = {
    "code_execution": (0.180, 2.400, 28.000),
    "file_operations": (0.045, 0.320, 1.200),
    "web_api": (0.850, 4.500, 45.000),
    "database_query": (0.120, 0.890, 3.500),
}
Z95, Z99 = 1.6448536, 2.3263479


def lognormal_params(tool: str) -> tuple:
    """(mu, sigma) matching the table's median; sigma averages the
    P95- and P99-implied spreads (the empirical tail is heavy)."""
    p50, p95, p99 = TOOL_LATENCY_TABLE[tool]
    mu = math.log(p50)
    s95 = math.log(p95 / p50) / Z95
    s99 = math.log(p99 / p50) / Z99
    return mu, 0.5 * (s95 + s99)


def sample_tool_latency(tool: str, rng: random.Random,
                        cv_scale: float = 1.0) -> float:
    mu, sigma = lognormal_params(tool)
    return math.exp(mu + sigma * cv_scale * rng.gauss(0, 1))


@dataclass
class Step:
    new_prompt_tokens: float     # tokens appended before this LLM call
    out_tokens: float
    tool: str                    # tool invoked after this step
    obs_tokens: float            # observation appended by the tool
    tool_latency_s: float


@dataclass
class Task:
    task_id: str
    tenant: str
    workload: str                # swebench | webarena | burstgpt
    arrival_s: float
    steps: List[Step]
    prefix_tokens: float = 1200.0   # shared system prompt + tool defs

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def context_after(self, step_idx: int) -> float:
        """Context tokens right after step step_idx's tool returns."""
        ctx = self.prefix_tokens
        for s in self.steps[:step_idx + 1]:
            ctx += s.new_prompt_tokens + s.out_tokens + s.obs_tokens
        return ctx

    def context_before(self, step_idx: int) -> float:
        ctx = self.prefix_tokens
        for s in self.steps[:step_idx]:
            ctx += s.new_prompt_tokens + s.out_tokens + s.obs_tokens
        ctx += self.steps[step_idx].new_prompt_tokens
        return ctx

    def tools(self) -> List[str]:
        return [s.tool for s in self.steps]


# ---------------------------------------------------------------------------
_SWE_TOOLS = (["code_execution"] * 45 + ["file_operations"] * 35 +
              ["database_query"] * 10 + ["web_api"] * 10)
_WEB_TOOLS = (["web_api"] * 80 + ["file_operations"] * 10 +
              ["database_query"] * 10)


def _n_steps(rng: random.Random, mean: int, max_steps: int) -> int:
    # log-normal step counts: long tail to max_steps (paper: mean 37/150)
    mu = math.log(mean) - 0.18
    n = int(round(math.exp(mu + 0.6 * rng.gauss(0, 1))))
    return max(2, min(max_steps, n))


def make_task(task_id: str, tenant: str, workload: str, arrival: float,
              rng: random.Random, n_steps: Optional[int] = None,
              cv_scale: float = 1.0) -> Task:
    if workload == "webarena":
        n = n_steps or _n_steps(rng, 18, 60)
        tools = _WEB_TOOLS
        prompt_rng = (600, 1200)       # page deltas appended per step
        first_prompt = (4000, 8000)
        out_rng = (50, 200)
        obs_rng = (400, 1600)
    elif workload == "burstgpt":
        # API-style agent traffic: shorter per-step deltas, long chains
        n = n_steps or _n_steps(rng, 30, 120)
        tools = _SWE_TOOLS
        prompt_rng = (80, 300)
        first_prompt = (1500, 3000)
        out_rng = (80, 300)
        obs_rng = (100, 700)
    else:                               # swebench-like
        n = n_steps or _n_steps(rng, 37, 150)
        tools = _SWE_TOOLS
        prompt_rng = (150, 500)
        first_prompt = (2000, 4000)
        out_rng = (100, 500)
        # SWE-bench observations are big (test logs, diffs, file dumps):
        # contexts grow 2-4K -> 16-128K over a task (paper §2.1)
        obs_rng = (300, 3000)
    steps = []
    for i in range(n):
        tool = rng.choice(tools)
        steps.append(Step(
            new_prompt_tokens=rng.uniform(*(first_prompt if i == 0
                                            else prompt_rng)),
            out_tokens=rng.uniform(*out_rng),
            tool=tool,
            obs_tokens=rng.uniform(*obs_rng),
            tool_latency_s=sample_tool_latency(tool, rng, cv_scale),
        ))
    return Task(task_id, tenant, workload, arrival, steps)


def poisson_arrivals(rate_per_min: float, horizon_s: float,
                     rng: random.Random) -> List[float]:
    out, t = [], 0.0
    lam = rate_per_min / 60.0
    while True:
        t += rng.expovariate(lam)
        if t > horizon_s:
            return out
        out.append(t)


def swebench_workload(n_tasks: int = 500, rate_per_min: float = 8.0,
                      seed: int = 0, cv_scale: float = 1.0) -> List[Task]:
    """§9.2: single-tenant replay under a Poisson schedule (~8 tasks/min)."""
    rng = random.Random(seed)
    horizon = n_tasks / (rate_per_min / 60.0) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_tasks]
    return [make_task(f"swe-{i}", "tenant0", "swebench", t, rng,
                      cv_scale=cv_scale)
            for i, t in enumerate(arr)]


def webarena_workload(n_tasks: int = 812, rate_per_min: float = 8.0,
                      seed: int = 0) -> List[Task]:
    rng = random.Random(seed + 1)
    horizon = n_tasks / (rate_per_min / 60.0) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_tasks]
    return [make_task(f"web-{i}", "tenant0", "webarena", t, rng)
            for i, t in enumerate(arr)]


def scale_workload(n_workers: int, tasks_per_worker: float = 2.0,
                   seed: int = 0, horizon_s: float = 600.0,
                   n_steps: int = 8, burst_frac: float = 0.0,
                   burst_window_s: float = 30.0) -> List[Task]:
    """Cluster-scale driver for the schedulers' hot paths (the 256-worker
    ``benchmarks/scale_sweep.py``): short fixed-length swebench-style
    tasks at an aggregate arrival rate proportional to cluster size, so
    per-worker pressure — and therefore queue depth, the thing the heap
    queues are meant to handle — stays constant as workers grow.

    ``burst_frac`` > 0 front-loads that fraction of the tasks uniformly
    into the first ``burst_window_s`` seconds (adversarial arrival
    spike: queues build cluster-wide, the regime straggler/preemption
    chaos is meant to stress)."""
    rng = random.Random(seed + 3)
    n_tasks = int(n_workers * tasks_per_worker)
    n_burst = int(n_tasks * burst_frac)
    burst = sorted(rng.uniform(0.0, burst_window_s)
                   for _ in range(n_burst))
    rate = max(n_tasks - n_burst, 1) / (horizon_s / 60.0)
    arr = burst + poisson_arrivals(rate, horizon_s * 1.5,
                                   rng)[:n_tasks - n_burst]
    arr.sort()
    return [make_task(f"scale-{i}", f"tenant{i % 8}", "burstgpt", t, rng,
                      n_steps=n_steps)
            for i, t in enumerate(arr)]


def runtime_requests(n_sessions: int = 16, vocab: int = 512,
                     seed: int = 0,
                     mix: Sequence[str] = ("swebench", "webarena",
                                           "burstgpt"),
                     n_steps: int = 4, max_ctx: int = 224,
                     arrival_window_s: float = 2.0,
                     token_scale: float = 1.0 / 64.0,
                     max_gap_s: float = 20.0) -> List:
    """Trace-driven agent mixes emitted as SERVING-RUNTIME requests.

    Draws SWE-bench / WebArena / BurstGPT-style task structures from
    ``make_task`` (step counts, tool sequences, Table-1 tool latencies)
    and scales the token economics down by ``token_scale`` so the steps
    run as REAL forward passes on the micro model: each step's prompt
    (previous tool observation + new turn) becomes actual token ids,
    contexts are capped at ``max_ctx`` so every session fits a slot.
    Deterministic for a given seed across processes (one seeded
    ``random.Random``, no builtin ``hash``)."""
    # lazy: repro.serving pulls jax, which simulator-only users of this
    # module never need
    from repro.serving.runtime import AgentRequest

    if max_ctx < 16:
        raise ValueError(f"max_ctx={max_ctx} too small for 2-step tasks")
    rng = random.Random(seed + 11)
    reqs: List = []
    for i in range(n_sessions):
        kind = mix[i % len(mix)]
        task = make_task(f"rt-{kind[:3]}-{i}", f"tenant{i % 4}", kind,
                         rng.uniform(0.0, arrival_window_s), rng,
                         n_steps=n_steps)
        steps: List = []
        ctx = 0
        prev_obs = 0.0
        for s in task.steps:
            n_prompt = max(2, int((s.new_prompt_tokens + prev_obs)
                                  * token_scale))
            n_out = max(1, min(8, int(s.out_tokens * token_scale)))
            if ctx + n_prompt + n_out > max_ctx:
                break
            prompt = [rng.randrange(1, vocab) for _ in range(n_prompt)]
            steps.append((prompt, n_out, s.tool,
                          min(s.tool_latency_s, max_gap_s)))
            ctx += n_prompt + n_out
            prev_obs = s.obs_tokens
        if len(steps) < 2:         # degenerate draw (huge first prompt):
            # replace with a minimal 2-step task that respects max_ctx
            steps = [([rng.randrange(1, vocab) for _ in range(4)],
                      2, "file_operations", 0.1) for _ in range(2)]
        reqs.append(AgentRequest(task.task_id, task.tenant, steps,
                                 arrival_s=task.arrival_s))
    return reqs


def burstgpt_workload(horizon_s: float = 1800.0, seed: int = 0,
                      load_factor: float = 0.5) -> List[Task]:
    """10 tenants: 3 heavy (100-step), 4 medium (30-step), 3 light
    (10-step).  ``load_factor`` scales the paper's nominal 16/8/4
    tasks/min/tenant so aggregate offered load sits at ~80% of the
    simulated cluster's peak throughput (the paper's stated operating
    point; the nominal rates are 'approximate' per §9.1)."""
    rng = random.Random(seed + 2)
    tasks: List[Task] = []
    tenant_specs = ([("heavy", 100, 16.0 * load_factor)] * 3 +
                    [("medium", 30, 8.0 * load_factor)] * 4 +
                    [("light", 10, 4.0 * load_factor)] * 3)
    for ti, (kind, steps, rate) in enumerate(tenant_specs):
        tenant = f"{kind}-{ti}"
        for j, t in enumerate(poisson_arrivals(rate, horizon_s, rng)):
            tasks.append(make_task(f"{tenant}-task{j}", tenant, "burstgpt",
                                   t, rng, n_steps=max(
                                       2, int(rng.gauss(steps, steps * 0.15)))))
    tasks.sort(key=lambda t: t.arrival_s)
    return tasks
