"""Agent workload generators (paper §9.1).

Three sources:
  * SWE-bench: 500 verified tasks, mean 37 steps (max 150); each step
    2-4K prompt tokens, 100-500 output tokens; code/file/db/web tools.
  * WebArena: 812 tasks, mean 18 steps; 4-8K prompt (page content),
    50-200 output tokens; web-heavy tools.
  * BurstGPT-derived multi-tenant: 10 tenants — 3 heavy (100-step,
    16 tasks/min), 4 medium (30-step, 8/min), 3 light (10-step, 4/min),
    Poisson arrivals (§9.1 "Workloads").

Tool latencies are log-normal fits of Table 1 (P50/P95/P99).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

# Table 1: (P50 s, P95 s, P99 s)
TOOL_LATENCY_TABLE = {
    "code_execution": (0.180, 2.400, 28.000),
    "file_operations": (0.045, 0.320, 1.200),
    "web_api": (0.850, 4.500, 45.000),
    "database_query": (0.120, 0.890, 3.500),
}
Z95, Z99 = 1.6448536, 2.3263479


def lognormal_params(tool: str) -> tuple:
    """(mu, sigma) matching the table's median; sigma averages the
    P95- and P99-implied spreads (the empirical tail is heavy)."""
    p50, p95, p99 = TOOL_LATENCY_TABLE[tool]
    mu = math.log(p50)
    s95 = math.log(p95 / p50) / Z95
    s99 = math.log(p99 / p50) / Z99
    return mu, 0.5 * (s95 + s99)


def sample_tool_latency(tool: str, rng: random.Random,
                        cv_scale: float = 1.0) -> float:
    mu, sigma = lognormal_params(tool)
    return math.exp(mu + sigma * cv_scale * rng.gauss(0, 1))


@dataclass
class Step:
    new_prompt_tokens: float     # tokens appended before this LLM call
    out_tokens: float
    tool: str                    # tool invoked after this step
    obs_tokens: float            # observation appended by the tool
    tool_latency_s: float


@dataclass
class Task:
    task_id: str
    tenant: str
    workload: str                # swebench | webarena | burstgpt
    arrival_s: float
    steps: List[Step]
    prefix_tokens: float = 1200.0   # shared system prompt + tool defs
    # lazily-built cumulative token sums: context queries are O(1), not
    # an O(n) prefix walk per call (which made the simulator's per-step
    # context lookups O(n^2) over a 150-step SWE-bench task).  Rebuilt
    # if the step list's length changes; steps are treated as immutable
    # once queried (every generator here constructs them up front).
    _cum: Optional[List[float]] = field(default=None, init=False,
                                        repr=False, compare=False)

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def _cumsum(self) -> List[float]:
        cum = self._cum
        if cum is None or len(cum) != len(self.steps) + 1:
            c = self.prefix_tokens
            cum = [c]
            # same accumulation order as the original per-call loop, so
            # every query is bit-identical to the O(n) path it replaces
            for s in self.steps:
                c = c + (s.new_prompt_tokens + s.out_tokens +
                         s.obs_tokens)
                cum.append(c)
            self._cum = cum
        return cum

    def context_after(self, step_idx: int) -> float:
        """Context tokens right after step step_idx's tool returns."""
        return self._cumsum()[step_idx + 1]

    def context_before(self, step_idx: int) -> float:
        return self._cumsum()[step_idx] + \
            self.steps[step_idx].new_prompt_tokens

    def tools(self) -> List[str]:
        return [s.tool for s in self.steps]


# ---------------------------------------------------------------------------
_SWE_TOOLS = (["code_execution"] * 45 + ["file_operations"] * 35 +
              ["database_query"] * 10 + ["web_api"] * 10)
_WEB_TOOLS = (["web_api"] * 80 + ["file_operations"] * 10 +
              ["database_query"] * 10)


def _n_steps(rng: random.Random, mean: int, max_steps: int) -> int:
    # log-normal step counts: long tail to max_steps (paper: mean 37/150)
    mu = math.log(mean) - 0.18
    n = int(round(math.exp(mu + 0.6 * rng.gauss(0, 1))))
    return max(2, min(max_steps, n))


def make_task(task_id: str, tenant: str, workload: str, arrival: float,
              rng: random.Random, n_steps: Optional[int] = None,
              cv_scale: float = 1.0) -> Task:
    if workload == "webarena":
        n = n_steps or _n_steps(rng, 18, 60)
        tools = _WEB_TOOLS
        prompt_rng = (600, 1200)       # page deltas appended per step
        first_prompt = (4000, 8000)
        out_rng = (50, 200)
        obs_rng = (400, 1600)
    elif workload == "burstgpt":
        # API-style agent traffic: shorter per-step deltas, long chains
        n = n_steps or _n_steps(rng, 30, 120)
        tools = _SWE_TOOLS
        prompt_rng = (80, 300)
        first_prompt = (1500, 3000)
        out_rng = (80, 300)
        obs_rng = (100, 700)
    else:                               # swebench-like
        n = n_steps or _n_steps(rng, 37, 150)
        tools = _SWE_TOOLS
        prompt_rng = (150, 500)
        first_prompt = (2000, 4000)
        out_rng = (100, 500)
        # SWE-bench observations are big (test logs, diffs, file dumps):
        # contexts grow 2-4K -> 16-128K over a task (paper §2.1)
        obs_rng = (300, 3000)
    steps = []
    for i in range(n):
        tool = rng.choice(tools)
        steps.append(Step(
            new_prompt_tokens=rng.uniform(*(first_prompt if i == 0
                                            else prompt_rng)),
            out_tokens=rng.uniform(*out_rng),
            tool=tool,
            obs_tokens=rng.uniform(*obs_rng),
            tool_latency_s=sample_tool_latency(tool, rng, cv_scale),
        ))
    return Task(task_id, tenant, workload, arrival, steps)


def poisson_arrivals(rate_per_min: float, horizon_s: float,
                     rng: random.Random) -> List[float]:
    if rate_per_min <= 0.0 or horizon_s <= 0.0:
        # zero offered load is a valid workload knob (e.g. disabling a
        # tenant class in a sweep); it used to ZeroDivisionError inside
        # expovariate
        return []
    out, t = [], 0.0
    lam = rate_per_min / 60.0
    while True:
        t += rng.expovariate(lam)
        if t > horizon_s:
            return out
        out.append(t)


def swebench_workload(n_tasks: int = 500, rate_per_min: float = 8.0,
                      seed: int = 0, cv_scale: float = 1.0) -> List[Task]:
    """§9.2: single-tenant replay under a Poisson schedule (~8 tasks/min)."""
    rng = random.Random(seed)
    horizon = n_tasks / (rate_per_min / 60.0) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_tasks]
    return [make_task(f"swe-{i}", "tenant0", "swebench", t, rng,
                      cv_scale=cv_scale)
            for i, t in enumerate(arr)]


def webarena_workload(n_tasks: int = 812, rate_per_min: float = 8.0,
                      seed: int = 0, cv_scale: float = 1.0) -> List[Task]:
    rng = random.Random(seed + 1)
    horizon = n_tasks / (rate_per_min / 60.0) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_tasks]
    return [make_task(f"web-{i}", "tenant0", "webarena", t, rng,
                      cv_scale=cv_scale)
            for i, t in enumerate(arr)]


def scale_workload(n_workers: int, tasks_per_worker: float = 2.0,
                   seed: int = 0, horizon_s: float = 600.0,
                   n_steps: int = 8, burst_frac: float = 0.0,
                   burst_window_s: float = 30.0) -> List[Task]:
    """Cluster-scale driver for the schedulers' hot paths (the 256-worker
    ``benchmarks/scale_sweep.py``): short fixed-length swebench-style
    tasks at an aggregate arrival rate proportional to cluster size, so
    per-worker pressure — and therefore queue depth, the thing the heap
    queues are meant to handle — stays constant as workers grow.

    ``burst_frac`` > 0 front-loads that fraction of the tasks uniformly
    into the first ``burst_window_s`` seconds (adversarial arrival
    spike: queues build cluster-wide, the regime straggler/preemption
    chaos is meant to stress)."""
    rng = random.Random(seed + 3)
    n_tasks = int(n_workers * tasks_per_worker)
    n_burst = int(n_tasks * burst_frac)
    burst = sorted(rng.uniform(0.0, burst_window_s)
                   for _ in range(n_burst))
    rate = max(n_tasks - n_burst, 1) / (horizon_s / 60.0)
    arr = burst + poisson_arrivals(rate, horizon_s * 1.5,
                                   rng)[:n_tasks - n_burst]
    arr.sort()
    return [make_task(f"scale-{i}", f"tenant{i % 8}", "burstgpt", t, rng,
                      n_steps=n_steps)
            for i, t in enumerate(arr)]


def runtime_requests(n_sessions: int = 16, vocab: int = 512,
                     seed: int = 0,
                     mix: Sequence[str] = ("swebench", "webarena",
                                           "burstgpt"),
                     n_steps: int = 4, max_ctx: int = 224,
                     arrival_window_s: float = 2.0,
                     token_scale: float = 1.0 / 64.0,
                     max_gap_s: float = 20.0) -> List:
    """Trace-driven agent mixes emitted as SERVING-RUNTIME requests.

    Draws SWE-bench / WebArena / BurstGPT-style task structures from
    ``make_task`` (step counts, tool sequences, Table-1 tool latencies)
    and scales the token economics down by ``token_scale`` so the steps
    run as REAL forward passes on the micro model: each step's prompt
    (previous tool observation + new turn) becomes actual token ids,
    contexts are capped at ``max_ctx`` so every session fits a slot.
    Deterministic for a given seed across processes (one seeded
    ``random.Random``, no builtin ``hash``)."""
    # lazy: repro.serving pulls jax, which simulator-only users of this
    # module never need
    from repro.serving.runtime import AgentRequest

    if max_ctx < 16:
        raise ValueError(f"max_ctx={max_ctx} too small for 2-step tasks")
    rng = random.Random(seed + 11)
    reqs: List = []
    for i in range(n_sessions):
        kind = mix[i % len(mix)]
        task = make_task(f"rt-{kind[:3]}-{i}", f"tenant{i % 4}", kind,
                         rng.uniform(0.0, arrival_window_s), rng,
                         n_steps=n_steps)
        steps: List = []
        ctx = 0
        prev_obs = 0.0
        for s in task.steps:
            n_prompt = max(2, int((s.new_prompt_tokens + prev_obs)
                                  * token_scale))
            n_out = max(1, min(8, int(s.out_tokens * token_scale)))
            if ctx + n_prompt + n_out > max_ctx:
                break
            prompt = [rng.randrange(1, vocab) for _ in range(n_prompt)]
            steps.append((prompt, n_out, s.tool,
                          min(s.tool_latency_s, max_gap_s)))
            ctx += n_prompt + n_out
            prev_obs = s.obs_tokens
        if len(steps) < 2:         # degenerate draw (huge first prompt):
            # replace with a minimal 2-step task that respects max_ctx
            steps = [([rng.randrange(1, vocab) for _ in range(4)],
                      2, "file_operations", 0.1) for _ in range(2)]
        reqs.append(AgentRequest(task.task_id, task.tenant, steps,
                                 arrival_s=task.arrival_s))
    return reqs


# --- branching AgentProgram generators (repro.workflow) --------------------
def swebench_retry_programs(n_programs: int = 16, rate_per_min: float = 4.0,
                            seed: int = 0, retry_p: float = 0.25,
                            n_nodes: int = 10, p_term: float = 0.02,
                            max_steps: int = 48) -> List:
    """SWE-bench-style mix as GRAPH AgentPrograms with executable retry
    loops: a chain of edit/test nodes where every ``code_execution``
    node carries a backward retry edge (test failed -> re-edit) taken
    with probability ``retry_p``.  The declared AEG reaches the
    coordinator at admission (tier-a), so reuse probability, prefetch
    targeting and Eq. 9 work estimates see the true loop structure —
    and the loops actually execute via each program's seeded resolver."""
    # lazy: repro.workflow imports this module at top level
    from repro.workflow.program import AgentProgram, StepSpec

    rng = random.Random(seed + 7)
    horizon = n_programs / max(rate_per_min / 60.0, 1e-9) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_programs]
    while len(arr) < n_programs:          # tail draws past the horizon
        arr.append((arr[-1] if arr else 0.0) + rng.uniform(1.0, 10.0))
    progs = []
    for i, t in enumerate(arr):
        nodes = {}
        edges = []
        for v in range(n_nodes):
            tool = rng.choice(_SWE_TOOLS)
            nodes[v] = StepSpec(
                tool,
                new_prompt_tokens=rng.uniform(150, 500),
                out_tokens=rng.uniform(100, 500),
                obs_tokens=rng.uniform(300, 3000),
                tool_latency_s=None)          # fresh draw per execution
            if v + 1 < n_nodes:
                retry = retry_p if tool == "code_execution" and v > 0 \
                    else 0.0
                edges.append((v, v + 1, (1.0 - p_term) * (1.0 - retry)))
                if retry > 0.0:
                    edges.append((v, v - 1, (1.0 - p_term) * retry))
        progs.append(AgentProgram.graph(
            f"swe-retry-{i}", f"tenant{i % 4}", nodes, edges,
            arrival_s=t, seed=seed * 1000 + i, max_steps=max_steps,
            prefix_tokens=1200.0, workload="swebench"))
    return progs


def webarena_branch_programs(n_programs: int = 16,
                             rate_per_min: float = 4.0, seed: int = 0,
                             nav_p: float = 0.55,
                             max_steps: int = 32) -> List:
    """WebArena-style conditional workflows: after the landing page the
    agent either NAVIGATES (browse-heavy subchain: big page deltas,
    web_api tools) or FILLS A FORM (form subchain: file/db lookups,
    small deltas), converging on a final submit node.  The branch is a
    real conditional edge pair resolved per program at run time, and
    both subchains are visible to the scheduler in the declared AEG."""
    from repro.workflow.program import AgentProgram, StepSpec

    rng = random.Random(seed + 13)
    horizon = n_programs / max(rate_per_min / 60.0, 1e-9) * 1.2
    arr = poisson_arrivals(rate_per_min, horizon, rng)[:n_programs]
    while len(arr) < n_programs:
        arr.append((arr[-1] if arr else 0.0) + rng.uniform(1.0, 10.0))
    progs = []
    for i, t in enumerate(arr):
        def page(lo, hi, tool="web_api", obs=(400, 1600)):
            return StepSpec(tool, new_prompt_tokens=rng.uniform(lo, hi),
                            out_tokens=rng.uniform(50, 200),
                            obs_tokens=rng.uniform(*obs),
                            tool_latency_s=None)
        # 0: landing  1-3: nav subchain  4-5: form subchain  6: submit
        nodes = {0: page(4000, 8000),
                 1: page(600, 1200), 2: page(600, 1200),
                 3: page(600, 1200),
                 4: page(200, 500, "file_operations", (100, 400)),
                 5: page(150, 400, "database_query", (100, 400)),
                 6: page(300, 700)}
        edges = [(0, 1, nav_p), (0, 4, 0.97 - nav_p),        # the branch
                 (1, 2, 0.95), (2, 3, 0.95), (3, 6, 0.9),
                 (4, 5, 0.95), (5, 6, 0.9)]
        progs.append(AgentProgram.graph(
            f"web-branch-{i}", f"tenant{i % 4}", nodes, edges,
            arrival_s=t, seed=seed * 1000 + i, max_steps=max_steps,
            prefix_tokens=1200.0, workload="webarena"))
    return progs


def runtime_programs(n_sessions: int = 8, seed: int = 0,
                     retry_p: float = 0.35, n_nodes: int = 4,
                     max_steps: int = 10) -> List:
    """Branching graph programs sized for the serving runtime's micro
    models: small token counts, short tool gaps, a retry edge on the
    test node.  Prompt token ids are left unspecified — the runtime
    realizes them deterministically from each program's seed against
    the model's vocab."""
    from repro.workflow.program import AgentProgram, StepSpec

    rng = random.Random(seed + 17)
    progs = []
    for i in range(n_sessions):
        nodes = {}
        edges = []
        for v in range(n_nodes):
            tool = rng.choice(_SWE_TOOLS)
            nodes[v] = StepSpec(tool,
                                new_prompt_tokens=float(rng.randint(6, 14)),
                                n_out=rng.randint(2, 4),
                                obs_tokens=float(rng.randint(4, 12)),
                                tool_latency_s=round(
                                    rng.uniform(0.05, 0.4), 3))
            if v + 1 < n_nodes:
                retry = retry_p if v == n_nodes - 2 else 0.0
                edges.append((v, v + 1, 0.98 * (1.0 - retry)))
                if retry > 0.0:
                    edges.append((v, max(v - 1, 0), 0.98 * retry))
        progs.append(AgentProgram.graph(
            f"rt-wf-{i}", f"tenant{i % 4}", nodes, edges,
            arrival_s=rng.uniform(0.0, 1.0), seed=seed * 100 + i,
            max_steps=max_steps, workload="runtime"))
    return progs


def burstgpt_workload(horizon_s: float = 1800.0, seed: int = 0,
                      load_factor: float = 0.5,
                      cv_scale: float = 1.0) -> List[Task]:
    """10 tenants: 3 heavy (100-step), 4 medium (30-step), 3 light
    (10-step).  ``load_factor`` scales the paper's nominal 16/8/4
    tasks/min/tenant so aggregate offered load sits at ~80% of the
    simulated cluster's peak throughput (the paper's stated operating
    point; the nominal rates are 'approximate' per §9.1)."""
    rng = random.Random(seed + 2)
    tasks: List[Task] = []
    tenant_specs = ([("heavy", 100, 16.0 * load_factor)] * 3 +
                    [("medium", 30, 8.0 * load_factor)] * 4 +
                    [("light", 10, 4.0 * load_factor)] * 3)
    for ti, (kind, steps, rate) in enumerate(tenant_specs):
        tenant = f"{kind}-{ti}"
        for j, t in enumerate(poisson_arrivals(rate, horizon_s, rng)):
            tasks.append(make_task(f"{tenant}-task{j}", tenant, "burstgpt",
                                   t, rng, n_steps=max(
                                       2, int(rng.gauss(steps, steps * 0.15))),
                                   cv_scale=cv_scale))
    tasks.sort(key=lambda t: t.arrival_s)
    return tasks
