"""Worker performance model (paper §9.1 hardware).

A worker = one vLLM instance = 4x A100-80GB under TP4 serving
Llama-3-70B-Instruct.  The 64-GPU cluster is 16 workers.  Constants are
calibrated against the paper's own measurements: ~10.7 GB KV per 32K
session (§2.2), regeneration ~0.3 s/step at 8B scaling to ~5 s/step at
405B (§9.1.1 => ~1.5-2.5 s at 70B for 16-32K contexts), migration mean
230 ms / P95 890 ms (Table 7).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass


@dataclass
class PerfModel:
    # serving rates per worker (70B, TP4, A100):
    # prefill: chunked-prefill at ~45% MFU: 4*312e12*0.45/(2*70e9) ~= 8000
    prefill_tokens_per_s: float = 8000.0
    decode_tokens_per_s: float = 45.0          # per sequence
    max_batch: int = 16                        # concurrent decodes
    # KV economics (Llama-3-70B GQA: 10.7GB / 32K tokens)
    kv_bytes_per_token: float = 10.7e9 / 32768.0
    # HBM available for KV per worker: 4x80GB minus weights (140GB TP4)
    # and activations/overheads => ~150GB usable KV pool
    kv_pool_bytes: float = 150e9
    # migration (Llumnix-style, Table 7)
    migration_mean_s: float = 0.230
    migration_p95_s: float = 0.890
    # coordinator epoch
    epoch_s: float = 0.100

    def step_compute_s(self, regen_tokens: float, new_tokens: float,
                       out_tokens: float) -> float:
        prefill = (regen_tokens + new_tokens) / self.prefill_tokens_per_s
        decode = out_tokens / self.decode_tokens_per_s
        return prefill + decode

    def sample_migration_s(self, rng: random.Random) -> float:
        mu = math.log(self.migration_mean_s) - 0.3
        sigma = math.log(self.migration_p95_s /
                         self.migration_mean_s) / 1.645 + 0.3
        return min(math.exp(mu + sigma * rng.gauss(0, 1)), 5.0)
