"""Discrete-event simulator of the 64-GPU (16-worker) serving cluster.

Reproduces the paper's evaluation harness: agent tasks move through
  arrival -> [route -> queue -> LLM step -> tool call]* -> done
with per-worker continuous-batching slots, a WA-LRU/LRU/prefix KV pool,
tool-aware TTLs, session-affinity routing, work stealing (with Llumnix
migration costs), AFS fairness, optional fault injection and elastic
scaling.  The GlobalCoordinator (repro.core) makes every policy
decision; the simulator only advances time.

Routing modes (baseline matrix, §9.1 "Baselines"):
  session — Eq. 7 affinity (SAGA, SGLang-like cache-aware)
  least   — least-loaded per request (vLLM FCFS)
  group   — prefix-hash affinity (vLLM+APC PrefixCacheAffinityRouter)
  sticky  — always the home worker (KVFlow / TRT-LLM single-node)
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.cluster.perf import PerfModel
from repro.cluster.workload import Task

INF = float("inf")


@dataclass
class SimPolicy:
    """Scheduler variant = SAGAConfig + routing/admission knobs."""
    name: str = "saga"
    saga: SAGAConfig = field(default_factory=SAGAConfig)
    routing: str = "session"          # session | least | group | sticky
    admission_max_tasks: Optional[int] = None   # DFS/BFS knob (Table 8)
    queue_discipline: str = "afs"     # afs | fcfs


@dataclass
class StepJob:
    task: Task
    step_idx: int
    enqueued_at: float
    worker: int = -1


@dataclass
class WorkerState:
    active: int = 0                    # busy batch slots
    queue: List[Tuple[float, str, StepJob]] = field(default_factory=list)
    busy_s: float = 0.0                # cumulative compute-busy seconds
    regen_s: float = 0.0               # of which: cache regeneration
    prefill_free_at: float = 0.0       # serial prefill pipeline head
    active_kv: float = 0.0             # bytes held by running requests
    alive: bool = True

    def load(self, max_batch: int) -> float:
        if not self.alive:
            return INF
        return (self.active + len(self.queue)) / max_batch


@dataclass
class TaskMetrics:
    task_id: str
    tenant: str
    arrival: float
    finish: float = -1.0
    ideal_s: float = 0.0               # no-queue no-regen time incl tools
    regen_tokens: float = 0.0
    migrations: int = 0
    steps: int = 0

    @property
    def tct(self) -> float:
        return self.finish - self.arrival


class ClusterSim:
    def __init__(self, tasks: Sequence[Task], policy: SimPolicy,
                 n_workers: int = 16, perf: Optional[PerfModel] = None,
                 seed: int = 0,
                 fault_plan: Optional[Sequence[Tuple[float, str, int]]] = None):
        self.tasks = {t.task_id: t for t in tasks}
        self.policy = policy
        self.perf = perf or PerfModel()
        self.rng = random.Random(seed)
        self.n_workers = n_workers
        cap = self.perf.kv_pool_bytes
        self.co = GlobalCoordinator(policy.saga, n_workers, cap)
        self.workers = [WorkerState() for _ in range(n_workers)]
        self.metrics: Dict[str, TaskMetrics] = {}
        self.events: List[Tuple[float, int, str, tuple]] = []
        self._eid = itertools.count()
        self.now = 0.0
        self.active_tasks = 0
        self.admission_queue: List[Task] = []
        self.mem_samples: List[Tuple[float, float]] = []   # (dt, util)
        self._last_mem_t = 0.0
        self.migrations = 0
        self.fault_plan = list(fault_plan or [])
        # group routing: stable hash of workload name
        self._group_worker = {}

    # -- event plumbing ----------------------------------------------------
    def _push(self, t: float, kind: str, args: tuple = ()) -> None:
        heapq.heappush(self.events, (t, next(self._eid), kind, args))

    def run(self, horizon_s: float = INF) -> Dict[str, TaskMetrics]:
        for task in self.tasks.values():
            self._push(task.arrival_s, "arrival", (task.task_id,))
        self._push(self.perf.epoch_s, "epoch")
        for t, kind, w in self.fault_plan:
            self._push(t, kind, (w,))
        while self.events:
            t, _, kind, args = heapq.heappop(self.events)
            if t > horizon_s:
                break
            self._sample_mem(t)
            self.now = t
            getattr(self, f"_on_{kind}")(*args)
            if kind != "epoch" and self._all_done():
                break
        return self.metrics

    def _all_done(self) -> bool:
        return all(m.finish >= 0 for m in self.metrics.values()) and \
            len(self.metrics) == len(self.tasks) and not self.admission_queue

    def _sample_mem(self, t: float) -> None:
        dt = t - self._last_mem_t
        if dt <= 0:
            return
        util = (sum(p.used for p in self.co.pools) +
                sum(w.active_kv for w in self.workers)) / \
            (self.co.capacity * self.n_workers)
        self.mem_samples.append((dt, util))
        self._last_mem_t = t

    # -- helpers -------------------------------------------------------------
    def _loads(self) -> List[float]:
        return [w.load(self.perf.max_batch) for w in self.workers]

    def _route(self, task: Task) -> int:
        mode = self.policy.routing
        sid = task.task_id
        loads = self._loads()
        if mode == "least":
            return min(range(self.n_workers),
                       key=lambda i: (loads[i], self.rng.random()))
        if mode == "group":
            # PrefixCacheAffinityRouter: load-blind consistent hash of the
            # request prefix.  An agent session's prompt keeps its own
            # prefix, so the hash is stable per session — but the router
            # cannot rebalance (hotspots) and overflows when the preferred
            # worker saturates.
            if sid not in self._group_worker:
                self._group_worker[sid] = (hash(sid) * 2654435761)                     % self.n_workers
            w = self._group_worker[sid]
            if loads[w] < self.policy.saga.theta and self.workers[w].alive:
                return w
            return min(range(self.n_workers),
                       key=lambda i: (loads[i], self.rng.random()))
        if mode == "sticky":
            home = self.co.router.home.get(sid)
            if home is not None and self.workers[home].alive:
                return home
            w = min(range(self.n_workers), key=lambda i: loads[i])
            self.co.router.set_home(sid, w)
            return w
        return self.co.route(sid, loads, self.now)

    def _ideal_time(self, task: Task) -> float:
        t = 0.0
        for i, s in enumerate(task.steps):
            t += self.perf.step_compute_s(0.0, s.new_prompt_tokens,
                                          s.out_tokens)
            t += s.tool_latency_s
        return t

    # -- events ----------------------------------------------------------------
    def _on_arrival(self, task_id: str) -> None:
        task = self.tasks[task_id]
        self.metrics[task_id] = TaskMetrics(
            task_id, task.tenant, task.arrival_s,
            ideal_s=self._ideal_time(task), steps=task.n_steps)
        cap = self.policy.admission_max_tasks
        if cap is not None and self.active_tasks >= cap:
            self.admission_queue.append(task)
            return
        self._admit(task)

    def _admit(self, task: Task) -> None:
        self.active_tasks += 1
        work_est = self._ideal_time(task)
        deadline = self.now + 1.5 * work_est
        self.co.register_task(task.task_id, task.tenant, task.tools(),
                              deadline, work_est, self.now,
                              prefix_tokens=task.prefix_tokens)
        self._enqueue_step(StepJob(task, 0, self.now))

    def _can_admit(self, w: int, job: StepJob) -> bool:
        """Slot AND memory admission: a decode starts only if its KV fits
        beside the running requests (idle cache is evictable)."""
        ws = self.workers[w]
        if not ws.alive or ws.active >= self.perf.max_batch:
            return False
        ctx_bytes = job.task.context_before(job.step_idx) * \
            self.perf.kv_bytes_per_token
        return ws.active_kv + ctx_bytes <= self.co.capacity

    def _enqueue_step(self, job: StepJob) -> None:
        w = self._route(job.task)
        job.worker = w
        ws = self.workers[w]
        if self._can_admit(w, job):
            ws.active += 1
            self._start_step(job)
        else:
            prio = -self.co.afs.priority(job.task.tenant) \
                if self.policy.queue_discipline == "afs" else job.enqueued_at
            ws.queue.append((prio, job.task.task_id, job))
            ws.queue.sort(key=lambda x: (x[0], x[2].enqueued_at))

    def _start_step(self, job: StepJob) -> None:
        task, i, w = job.task, job.step_idx, job.worker
        step = task.steps[i]
        ctx = task.context_before(i)
        ws = self.workers[w]
        self.co.ensure_headroom(w, ws.active_kv,
                                ctx * self.perf.kv_bytes_per_token, self.now)
        hit, pf_extra, bg_tokens = self.co.on_step_start(
            task.task_id, w, ctx, self.now)
        rate = self.perf.prefill_tokens_per_s
        # prefill is compute-bound and serializes per worker; decode slots
        # run in parallel (continuous batching is memory-bound).
        pf_tokens = pf_extra if hit else pf_extra + step.new_prompt_tokens
        regen = 0.0 if hit else pf_extra
        if bg_tokens > 0.0:
            # speculative prefetch: the suffix regeneration ran during
            # the tool gap IF the prefill server had idle time; compute
            # is charged either way (speculation is never free work).
            bg_dur = bg_tokens / rate
            if ws.prefill_free_at + bg_dur <= self.now:
                ws.busy_s += bg_dur          # hidden off the critical path
            else:
                pf_tokens += bg_tokens       # server busy: regen on path
                regen += bg_tokens
        pf_start = max(self.now, ws.prefill_free_at)
        pf_dur = pf_tokens / rate
        ws.prefill_free_at = pf_start + pf_dur
        decode_dur = step.out_tokens / self.perf.decode_tokens_per_s
        done = pf_start + pf_dur + decode_dur
        ws.busy_s += pf_dur + decode_dur
        ws.regen_s += regen / rate
        ws.active_kv += ctx * self.perf.kv_bytes_per_token
        self.metrics[task.task_id].regen_tokens += regen
        self._push(done, "llm_done", (task.task_id, i, w))

    def _on_llm_done(self, task_id: str, i: int, w: int) -> None:
        task = self.tasks[task_id]
        ws = self.workers[w]
        ws.active = max(0, ws.active - 1)
        ws.active_kv = max(
            0.0, ws.active_kv -
            task.context_before(i) * self.perf.kv_bytes_per_token)
        self._drain_queue(w)
        step = task.steps[i]
        ctx_after = task.context_after(i)
        if i + 1 >= task.n_steps:
            # final step's action is "finish" — no tool wait
            self.co.task_finished(task_id, self.now)
            self.metrics[task_id].finish = self.now
            self.active_tasks -= 1
            if self.admission_queue:
                self._admit(self.admission_queue.pop(0))
            return
        # the tool observation has not arrived yet: the cached context
        # covers everything up to and including this step's output
        ctx_cached = ctx_after - step.obs_tokens
        entry_bytes = ctx_cached * self.perf.kv_bytes_per_token
        self.co.on_step_end(task_id, w, ctx_cached, entry_bytes,
                            step.tool, self.now)
        self._push(self.now + step.tool_latency_s, "tool_done",
                   (task_id, i, w))

    def _on_tool_done(self, task_id: str, i: int, w: int) -> None:
        task = self.tasks[task_id]
        step = task.steps[i]
        self.co.on_tool_done(task_id, step.tool, step.tool_latency_s,
                             step.obs_tokens, self.now)
        self._enqueue_step(StepJob(task, i + 1, self.now))

    def _drain_queue(self, w: int) -> None:
        ws = self.workers[w]
        while ws.queue and self._can_admit(w, ws.queue[0][2]):
            _, _, job = ws.queue.pop(0)
            ws.active += 1
            self._start_step(job)

    # -- epoch: AFS + work stealing ------------------------------------------
    def _on_epoch(self) -> None:
        loads = self._loads()
        queues = [[(j.enqueued_at, j.task.task_id) for _, _, j in w.queue]
                  for w in self.workers]
        decision, _ = self.co.epoch_tick(self.now, loads, queues)
        if decision is not None:
            vq = self.workers[decision.victim].queue
            if self.co.stealer.accept(decision, len(vq), self.now):
                idx = next((k for k, (_, sid, _) in enumerate(vq)
                            if sid == decision.session_id), None)
                if idx is not None:
                    _, _, job = vq.pop(idx)
                    mig = self.perf.sample_migration_s(self.rng)
                    self.migrations += 1
                    self.metrics[job.task.task_id].migrations += 1
                    self._push(self.now + mig, "migr_done",
                               (job.task.task_id, job.step_idx,
                                decision.victim, decision.thief))
        if self.events or not self._all_done():
            self._push(self.now + self.perf.epoch_s, "epoch")

    def _on_migr_done(self, task_id: str, step_idx: int, src: int,
                      dst: int) -> None:
        if task_id not in self.tasks:
            return
        self.co.migrate_session(task_id, src, dst, self.now)
        job = StepJob(self.tasks[task_id], step_idx, self.now, dst)
        ws = self.workers[dst]
        if self._can_admit(dst, job):
            ws.active += 1
            self._start_step(job)
        else:
            ws.queue.append((0.0, task_id, job))

    # -- faults / elasticity ---------------------------------------------------
    def _on_fail(self, w: int) -> None:
        ws = self.workers[w]
        ws.alive = False
        self.co.worker_failed(w)
        requeue = [j for _, _, j in ws.queue]
        ws.queue.clear()
        ws.active = 0
        for job in requeue:
            self._enqueue_step(StepJob(job.task, job.step_idx, self.now))

    def _on_recover(self, w: int) -> None:
        self.workers[w].alive = True
        self.co.worker_recovered(w)

    def _on_scale_up(self, _unused: int = 0) -> None:
        self.co.add_worker()
        self.workers.append(WorkerState())
        self.n_workers += 1


# --- summary ----------------------------------------------------------------
def summarize(sim: ClusterSim) -> dict:
    ms = [m for m in sim.metrics.values() if m.finish >= 0]
    if not ms:
        return {}
    tcts = sorted(m.tct for m in ms)
    slo = sum(1 for m in ms if m.tct <= 1.5 * m.ideal_s) / len(ms)
    total_busy = sum(w.busy_s for w in sim.workers) or 1.0
    regen_frac = sum(w.regen_s for w in sim.workers) / total_busy
    mem_num = sum(dt * u for dt, u in sim.mem_samples)
    mem_den = sum(dt for dt, u in sim.mem_samples) or 1.0
    span = (max(m.finish for m in ms) - min(m.arrival for m in ms)) or 1.0
    pool = sim.co.pools[0]
    hits = sim.co.cache_hits
    miss = sim.co.cache_misses
    by_tenant: Dict[str, List[TaskMetrics]] = {}
    for m in ms:
        by_tenant.setdefault(m.tenant.split("-")[0], []).append(m)
    slo_by = {k: sum(1 for m in v if m.tct <= 1.5 * m.ideal_s) / len(v)
              for k, v in by_tenant.items()}
    evictions = sum(p.evictions for p in sim.co.pools)
    inserts = evictions + sum(len(p.entries) for p in sim.co.pools) + hits
    return {
        "n_tasks": len(ms),
        "tct_mean": sum(tcts) / len(tcts),
        "tct_p50": tcts[len(tcts) // 2],
        "tct_p99": tcts[min(len(tcts) - 1, int(0.99 * len(tcts)))],
        "ideal_mean": sum(m.ideal_s for m in ms) / len(ms),
        "slo_attainment": slo,
        "slo_by_tenant": slo_by,
        "mem_util": mem_num / mem_den,
        "regen_time_frac": regen_frac,
        "throughput_tasks_per_min": len(ms) / span * 60.0,
        "cache_hit_rate": hits / max(hits + miss, 1),
        "migrations_per_task": sim.migrations / len(ms),
        "evict_rate": evictions / max(inserts, 1),
        "regen_tokens_total": sum(m.regen_tokens for m in ms),
    }
