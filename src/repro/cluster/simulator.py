"""Discrete-event simulator of the 64-GPU (16-worker) serving cluster.

Reproduces the paper's evaluation harness: agent tasks move through
  arrival -> [route -> queue -> LLM step -> tool call]* -> done
with per-worker continuous-batching slots, a WA-LRU/LRU/prefix KV pool,
tool-aware TTLs, session-affinity routing, work stealing (with Llumnix
migration costs), AFS fairness, optional fault injection and elastic
scaling.  The GlobalCoordinator (repro.core) makes every policy
decision; the simulator only advances time.

Submission is the unified ``repro.workflow.AgentProgram`` API: legacy
``Task`` lists compile to scripted programs (byte-identical execution),
while explicit-graph and dynamic programs resolve their branches at
park boundaries (``WorkflowInstance.resolve_next`` inside
``_on_llm_done``) — retry loops and conditionals execute, the taken
edge is threaded into the coordinator (``on_step_end(next_node=...)``),
and a declared AEG reaches admission (``register_task(aeg=...)``) so
reuse probability, prefetch targeting and Eq. 9 work estimates see the
true branch structure.

Routing modes (baseline matrix, §9.1 "Baselines"):
  session — Eq. 7 affinity (SAGA, SGLang-like cache-aware)
  least   — least-loaded per request (vLLM FCFS)
  group   — prefix-hash affinity (vLLM+APC PrefixCacheAffinityRouter)
  sticky  — always the home worker (KVFlow / TRT-LLM single-node)

Execution lifecycle & failure semantics
---------------------------------------
Every running LLM step is tracked in an explicit in-flight registry
(``ClusterSim.inflight``): task, step index, worker, start/finish time,
KV bytes held, and a monotonically increasing *attempt* id.  Completion
events (``llm_done``) carry the attempt id and validate against the
registry, so an event for a step that was cancelled in the meantime is
recognised as stale and dropped instead of firing blindly.

When a worker fails (``fail`` event):
  * its queued steps are drained and re-enqueued on live workers;
  * its in-flight steps are *cancelled*: the un-executed tail of their
    charged compute is refunded (end-first: decode before prefill, so
    regeneration time/tokens are only un-charged if the prefill that
    held them never ran), their KV reservation is released, and the
    steps are re-enqueued from scratch.  The failed
    worker's KV pool is wiped (GlobalCoordinator.worker_failed), so the
    retried step misses cache and pays full regeneration — the §3.1
    cache-loss accounting.  Compute already executed on the aborted
    attempt stays charged (work lost to a crash was still real work).
  * in-flight migrations targeting the dead worker are re-routed to a
    live worker when their ``migr_done`` event arrives.

Work stealing, routing and migration all consult worker liveness
through the same flags (``WorkerState.alive`` here, mirrored into
``GlobalCoordinator.alive`` by the fail/recover/scale handlers), so a
dead worker can never be picked as a thief, a victim, a routing target
or a migration destination.  If *every* worker is dead, steps park in
an orphan buffer and re-enqueue on the next recover/scale-up.

Incremental epoch tick
----------------------
The 100 ms epoch tick (AFS shares + steal decision) is O(changes), not
O(cluster size).  Every structure it consumes is maintained at the
event sites that mutate it:

  * ``_loadnum`` — integer active+queued count per worker, turned into
    the float load vector by one C-level numpy division (exact: same
    IEEE result as ``WorkerState.load``), dead workers masked to inf;
  * the stealer's ``idle_since`` dict — the indexed idle-worker set,
    entered/left on queue-depth transitions (empty<->nonempty), with
    exact transition times instead of epoch-quantized ones;
  * ``_nonempty`` — the victim-candidate index (workers with pending
    queue work), so the steal scan never walks all workers;
  * persistent ``_QueueView``/alive lists — zero per-epoch allocation;
  * AFS columns — persistent, delta-updated (see ``repro.core.afs``).

``check_conservation`` cross-checks every mirror against ground truth,
so index drift fails loudly rather than skewing scheduling silently.

Straggler injection: a ``StragglerInjector`` (static) and/or
``("slow", w)`` / ``("heal", w)`` plan events (dynamic) scale worker
``w``'s service rates by ``straggler_slowdown``; work stealing is the
paper's own mitigation (§5.2).

Determinism: all randomness flows through one seeded ``random.Random``;
string hashing (``group`` routing) uses a stable FNV-1a hash, so two
identical-seed runs produce byte-identical ``summarize()`` output even
across processes with different ``PYTHONHASHSEED``.
"""
from __future__ import annotations

import heapq
import itertools
import os
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy ships with repo
    np = None

from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.cluster.perf import PerfModel
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import ROOT, as_tracer
from repro.workflow.program import WorkflowInstance, as_instance

INF = float("inf")

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_FNV_MASK = 0xFFFFFFFFFFFFFFFF


def _fnv1a(s: str) -> int:
    """Stable 64-bit FNV-1a string hash.  Python's builtin ``hash`` is
    randomized per process (PYTHONHASHSEED), which made ``group``
    routing — and therefore every baseline number — irreproducible."""
    h = _FNV_OFFSET
    for b in s.encode("utf-8"):
        h ^= b
        h = (h * _FNV_PRIME) & _FNV_MASK
    return h


@dataclass
class SimPolicy:
    """Scheduler variant = SAGAConfig + routing/admission knobs."""
    name: str = "saga"
    saga: SAGAConfig = field(default_factory=SAGAConfig)
    routing: str = "session"          # session | least | group | sticky
    admission_max_tasks: Optional[int] = None   # DFS/BFS knob (Table 8)
    queue_discipline: str = "afs"     # afs | fcfs


@dataclass
class StepJob:
    task: WorkflowInstance
    step_idx: int
    enqueued_at: float
    worker: int = -1
    cancelled: bool = False           # lazy-deletion flag (StepQueue)


class StepQueue:
    """Per-worker pending-step priority queue.

    A lazy-deletion binary heap keyed by ``(priority, enqueued_at,
    seq)`` — O(log n) push/pop instead of the previous
    sort-per-enqueue O(n log n) list.  Stealing removes arbitrary
    sessions by tombstoning (``StepJob.cancelled``); dead entries are
    skipped on the next peek/pop.  ``seq`` is a global monotone counter
    so ties break deterministically (FIFO), never by object identity.
    """

    __slots__ = ("_heap", "_live")

    def __init__(self) -> None:
        self._heap: List[Tuple[float, float, int, StepJob]] = []
        self._live = 0

    def __len__(self) -> int:
        return self._live

    def __bool__(self) -> bool:
        return self._live > 0

    def push(self, prio: float, seq: int, job: StepJob) -> None:
        heapq.heappush(self._heap, (prio, job.enqueued_at, seq, job))
        self._live += 1

    def peek(self) -> Optional[StepJob]:
        h = self._heap
        while h and h[0][3].cancelled:
            heapq.heappop(h)
        return h[0][3] if h else None

    def pop(self) -> Optional[StepJob]:
        job = self.peek()
        if job is not None:
            heapq.heappop(self._heap)
            self._live -= 1
        return job

    def remove(self, session_id: str) -> Optional[StepJob]:
        """Tombstone and return the queued step of ``session_id`` (the
        steal path; O(n) scan, but steals are epoch-rate events)."""
        for _, _, _, job in self._heap:
            if not job.cancelled and job.task.task_id == session_id:
                job.cancelled = True
                self._live -= 1
                return job
        return None

    def drain(self) -> List[StepJob]:
        """Remove and return all live jobs (worker-failure requeue),
        oldest-first for deterministic re-enqueue order."""
        jobs = [j for _, _, _, j in self._heap if not j.cancelled]
        jobs.sort(key=lambda j: (j.enqueued_at, j.task.task_id,
                                 j.step_idx))
        self._heap.clear()
        self._live = 0
        return jobs

    def snapshot(self) -> List[Tuple[float, str]]:
        """(enqueued_at, session_id) pairs oldest-first, as the work
        stealer expects."""
        return sorted((j.enqueued_at, j.task.task_id)
                      for _, _, _, j in self._heap if not j.cancelled)


class _QueueView:
    """Lazy stealer-facing view of a worker's StepQueue.  Built once per
    worker at sim construction (the epoch tick reuses the same list
    every 100 ms — zero per-epoch allocation); emptiness checks are
    O(1) and the sorted (enqueued_at, session_id) dump is built only if
    the stealer actually iterates this worker's queue (i.e. it became
    the victim).  Wraps the WorkerState, not the queue object, so
    benchmark harnesses that swap ``ws.queue`` stay visible."""

    __slots__ = ("_ws",)

    def __init__(self, ws) -> None:
        self._ws = ws

    def __len__(self) -> int:
        return len(self._ws.queue)

    def __bool__(self) -> bool:
        return bool(self._ws.queue)

    def __iter__(self):
        return iter(self._ws.queue.snapshot())


@dataclass
class WorkerState:
    active: int = 0                    # busy batch slots
    queue: StepQueue = field(default_factory=StepQueue)
    busy_s: float = 0.0                # cumulative compute-busy seconds
    regen_s: float = 0.0               # of which: cache regeneration
    prefill_free_at: float = 0.0       # serial prefill pipeline head
    active_kv: float = 0.0             # bytes held by running requests
    alive: bool = True

    def load(self, max_batch: int) -> float:
        if not self.alive:
            return INF
        return (self.active + len(self.queue)) / max_batch


@dataclass
class InFlightStep:
    """Registry record for one running LLM step (one per task, max).

    ``attempt`` stamps the matching ``llm_done`` event; a mismatch at
    delivery time means the step was cancelled (worker fault) and the
    event is stale.  ``busy_charged`` / ``regen_s_charged`` /
    ``regen_tokens`` record what was charged to the worker and task at
    start, so cancellation can refund the un-executed tail of each."""
    job: StepJob
    attempt: int
    worker: int
    started: float
    finish: float
    kv_bytes: float
    busy_charged: float
    decode_s: float = 0.0        # tail of busy_charged (prefill runs first)
    regen_s_charged: float = 0.0
    regen_tokens: float = 0.0


@dataclass
class TaskMetrics:
    task_id: str
    tenant: str
    arrival: float
    finish: float = -1.0
    ideal_s: float = 0.0               # no-queue no-regen time incl tools
    regen_tokens: float = 0.0
    migrations: int = 0
    steps: int = 0

    @property
    def tct(self) -> float:
        return self.finish - self.arrival


class ClusterSim:
    def __init__(self, tasks: Sequence[object], policy: SimPolicy,
                 n_workers: int = 16, perf: Optional[PerfModel] = None,
                 seed: int = 0,
                 fault_plan: Optional[Sequence[Tuple[float, str, int]]] = None,
                 straggler: Optional[object] = None,
                 straggler_slowdown: float = 4.0,
                 trace=None):
        # one submission API (repro.workflow): legacy Tasks compile to
        # scripted AgentPrograms (byte-identical execution), explicit
        # graph / dynamic programs resolve their branches as they run
        insts = [as_instance(t) for t in tasks]
        self.tasks: Dict[str, WorkflowInstance] = \
            {t.task_id: t for t in insts}
        self.policy = policy
        self.perf = perf or PerfModel()
        self.rng = random.Random(seed)
        self.n_workers = n_workers
        cap = self.perf.kv_pool_bytes
        self.co = GlobalCoordinator(policy.saga, n_workers, cap)
        self.workers = [WorkerState() for _ in range(n_workers)]
        self.metrics: Dict[str, TaskMetrics] = {}
        self.events: List[Tuple[float, int, str, tuple]] = []
        self._eid = itertools.count()
        self._seq = itertools.count()        # queue FIFO tie-break
        self._attempt = itertools.count()    # in-flight step attempt ids
        self.now = 0.0
        self.active_tasks = 0
        self.admission_queue: List[WorkflowInstance] = []
        self.mem_samples: List[Tuple[float, float]] = []   # (dt, util)
        self._last_mem_t = 0.0
        self._mem_min_dt = self.perf.epoch_s   # sampling granularity
        self.migrations = 0
        self.fault_plan = list(fault_plan or [])
        self.events_processed = 0
        # float-dust tolerance for KV-byte conservation checks (entries
        # are ~1e10 bytes; long runs accumulate rounding in the sums)
        self._kv_tol = 1e-6 * self.co.capacity
        # execution-lifecycle registries (see module docstring)
        self.inflight: Dict[str, InFlightStep] = {}
        self.migrating: Dict[str, int] = {}    # task_id -> dst worker
        self._orphans: List[StepJob] = []      # steps with no live worker
        # group routing: stable FNV-1a hash of the session prefix
        self._group_worker: Dict[str, int] = {}
        # incremental epoch-tick state (O(changes) instead of O(cluster)):
        #   _loadnum[w]   int active+queued steps, mirrored at every
        #                 slot/queue transition (ints: no float drift)
        #   _nonempty     indexed set of workers with pending queue work
        #                 (the stealer's victim candidates)
        #   _queue_views  persistent stealer-facing views (no per-epoch
        #                 list builds)
        #   _active_kv_total  running sum of in-flight KV reservations
        self._max_batch = self.perf.max_batch
        if np is not None:
            self._loadnum = np.zeros(n_workers, dtype=np.int64)
            self._alive_np = np.ones(n_workers, dtype=bool)
        self._alive_list = [True] * n_workers
        self._n_dead = 0
        self._nonempty: set = set()
        self._queue_views = [_QueueView(ws) for ws in self.workers]
        self._active_kv_total = 0.0
        # straggler injection: static injector (factor(w) >= 1 slows
        # worker w) composed with dynamic ("slow"/"heal") plan events
        self.straggler = straggler
        self.straggler_slowdown = straggler_slowdown
        self._slow: Dict[int, float] = {}
        # virtual-time span tracer + metrics registry (repro.obs):
        # read-only — a traced run's summarize() is byte-identical to
        # the untraced run and the trace bytes are byte-identical
        # across PYTHONHASHSEED (docs/OBSERVABILITY.md).  ``trace``
        # accepts True (fresh tracer) or a Tracer instance; the
        # simulator's own TaskMetrics dict keeps the ``metrics`` name.
        if trace is None:
            # sagalint: ok(det-env) trace toggles recording only, never a scheduling decision — replay is unaffected
            trace = os.environ.get("SAGA_TRACE", "") not in ("", "0")
        self.tracer = as_tracer(trace)
        self.obs_metrics = MetricsRegistry() if self.tracer is not None \
            else None
        # per-task open-span ids keyed by role ("session" / "step" /
        # "queue" / "pf" / "dec" / "gap" / "migr"); plain string keys,
        # never id() — part of the determinism contract
        self._tr_open: Dict[str, Dict[str, int]] = {}
        # metric sampling is decimated to every 10th epoch tick (1 s of
        # virtual time) with per-worker gauge handles cached — sampling
        # the full worker set at the 100 ms tick rate dominated traced
        # wall time (table7's trace-overhead row measures this)
        self._obs_tick = 0
        self._obs_worker_g: list = []
        self._started = False
        # all queues start empty: seed the indexed idle set at t=0
        for w in range(n_workers):
            self.co.on_worker_idle(w, 0.0)

    # -- event plumbing ----------------------------------------------------
    def _push(self, t: float, kind: str, args: tuple = ()) -> None:
        heapq.heappush(self.events, (t, next(self._eid), kind, args))

    def run(self, horizon_s: float = INF) -> Dict[str, TaskMetrics]:
        """Advance the event loop up to ``horizon_s``.  Resumable: an
        event past the horizon stays queued, so a later ``run`` call
        with a larger horizon continues where this one stopped."""
        if not self._started:
            self._started = True
            for task in self.tasks.values():
                self._push(task.arrival_s, "arrival", (task.task_id,))
            self._push(self.perf.epoch_s, "epoch")
            for t, kind, w in self.fault_plan:
                self._push(t, kind, (w,))
        elif self._all_done():
            # completed sim: the final break leaves one epoch event
            # queued; processing it here would shift now/mem_samples and
            # make resumed runs diverge from one-shot runs
            return self.metrics
        while self.events:
            if self.events[0][0] > horizon_s:
                break
            t, _, kind, args = heapq.heappop(self.events)
            self._sample_mem(t)
            self.now = t
            self.events_processed += 1
            getattr(self, f"_on_{kind}")(*args)
            if kind != "epoch" and self._all_done():
                break
        return self.metrics

    def _all_done(self) -> bool:
        return all(m.finish >= 0 for m in self.metrics.values()) and \
            len(self.metrics) == len(self.tasks) and not self.admission_queue

    def _sample_mem(self, t: float) -> None:
        # Throttled to the epoch period, and O(1): the coordinator keeps
        # a running total of cached pool bytes (``pools_used``) and the
        # sim a running total of in-flight KV reservations, so sampling
        # no longer sums over every worker (which re-dominated the event
        # loop at 256 workers once the epoch tick went incremental).
        dt = t - self._last_mem_t
        if dt < self._mem_min_dt - 1e-9:   # tolerance: epoch times are
            return                         # accumulated floats
        util = (self.co.pools_used + self._active_kv_total) / \
            (self.co.capacity * self.n_workers)
        self.mem_samples.append((dt, util))
        self._last_mem_t = t

    # -- helpers -------------------------------------------------------------
    def _loads(self):
        """Per-worker load vector.  With numpy: one C-level division of
        the incrementally-maintained integer slot+queue counts (exact —
        bit-identical to ``WorkerState.load``); dead workers masked to
        inf.  Fallback: the legacy python list comprehension."""
        if np is None:
            return [w.load(self._max_batch) for w in self.workers]
        loads = self._loadnum / self._max_batch
        if self._n_dead:
            loads[~self._alive_np] = INF
        return loads

    def _load_delta(self, w: int, delta: int) -> None:
        if np is not None:
            self._loadnum[w] += delta

    def _least_loaded(self, loads) -> int:
        """Deterministic least-loaded pick: seeded-RNG tie-break among
        exact-minimum workers (spreads equal-load ties without the
        per-candidate RNG draws the old ``min(key=...)`` made)."""
        if np is not None and isinstance(loads, np.ndarray):
            ties = np.flatnonzero(loads == loads.min())
            if len(ties) == 1:
                return int(ties[0])
            return int(ties[self.rng.randrange(len(ties))])
        lo = min(loads)
        ties = [i for i, l in enumerate(loads) if l == lo]
        if len(ties) == 1:
            return ties[0]
        return ties[self.rng.randrange(len(ties))]

    def _speed_factor(self, w: int) -> float:
        """Straggler slowdown for worker ``w`` (1.0 = healthy).  Static
        injector factors compose with dynamic slow/heal plan events."""
        f = self._slow.get(w, 1.0)
        if self.straggler is not None:
            f *= self.straggler.factor(w)
        return f

    # -- tracing helpers (no-ops when tracing is off) ---------------------
    def _tr_begin(self, tid: str, key: str, name: str,
                  parent_key: Optional[str] = None,
                  t: Optional[float] = None, **meta) -> None:
        if self.tracer is None:
            return
        o = self._tr_open.setdefault(tid, {})
        parent = o.get(parent_key, ROOT) if parent_key else ROOT
        o[key] = self.tracer.begin(f"session/{tid}", name,
                                   self.now if t is None else t,
                                   parent=parent, **meta)

    def _tr_end(self, tid: str, key: str, status: str = "ok",
                t: Optional[float] = None, **meta) -> None:
        if self.tracer is None:
            return
        o = self._tr_open.get(tid)
        if o is None or key not in o:
            return
        self.tracer.end(o.pop(key), self.now if t is None else t,
                        status=status, **meta)

    def _tr_instant(self, track: str, name: str, **meta) -> None:
        if self.tracer is not None:
            self.tracer.instant(track, name, self.now, **meta)

    # -- queue transitions (the indexed idle/victim bookkeeping) ----------
    def _queue_pop(self, w: int) -> Optional[StepJob]:
        job = self.workers[w].queue.pop()
        if job is not None:
            self._load_delta(w, -1)
            if not self.workers[w].queue:
                self._queue_went_empty(w)
        return job

    def _queue_remove(self, w: int, session_id: str) -> Optional[StepJob]:
        job = self.workers[w].queue.remove(session_id)
        if job is not None:
            self._load_delta(w, -1)
            if not self.workers[w].queue:
                self._queue_went_empty(w)
        return job

    def _queue_drain(self, w: int) -> List[StepJob]:
        jobs = self.workers[w].queue.drain()
        if jobs:
            self._load_delta(w, -len(jobs))
        self._nonempty.discard(w)
        # no idle-set entry: draining only happens on worker failure,
        # and the coordinator evicts dead workers from the idle set
        return jobs

    def _queue_went_empty(self, w: int) -> None:
        self._nonempty.discard(w)
        if self.workers[w].alive:
            self.co.on_worker_idle(w, self.now)

    def _route(self, task: WorkflowInstance) -> int:
        mode = self.policy.routing
        sid = task.task_id
        loads = self._loads()
        if mode == "least":
            return self._least_loaded(loads)
        if mode == "group":
            # PrefixCacheAffinityRouter: load-blind consistent hash of the
            # request prefix.  An agent session's prompt keeps its own
            # prefix, so the hash is stable per session — but the router
            # cannot rebalance (hotspots) and overflows when the preferred
            # worker saturates.
            if sid not in self._group_worker:
                self._group_worker[sid] = (_fnv1a(sid) * 2654435761) \
                    % self.n_workers
            w = self._group_worker[sid]
            if loads[w] < self.policy.saga.theta and self.workers[w].alive:
                return w
            return self._least_loaded(loads)
        if mode == "sticky":
            home = self.co.router.home.get(sid)
            if home is not None and self.workers[home].alive:
                return home
            w = self._least_loaded(loads)
            self.co.router.set_home(sid, w)
            return w
        return self.co.route(sid, loads, self.now)

    def _ideal_time(self, task: WorkflowInstance) -> float:
        """No-queue no-regen estimate over the workflow's nominal path
        (scripted: the actual steps, so legacy Tasks are unchanged;
        graph/dynamic: the expected path — branches resolve at run
        time, so this is an estimate by construction)."""
        t = 0.0
        for s in task.nominal_steps():
            t += self.perf.step_compute_s(0.0, s.new_prompt_tokens,
                                          s.out_tokens)
            t += s.tool_latency_s
        return t

    # -- events ----------------------------------------------------------------
    def _on_arrival(self, task_id: str) -> None:
        task = self.tasks[task_id]
        self.metrics[task_id] = TaskMetrics(
            task_id, task.tenant, task.arrival_s,
            ideal_s=self._ideal_time(task),
            steps=len(task.nominal_steps()))
        self._tr_begin(task_id, "session", "session", tenant=task.tenant)
        cap = self.policy.admission_max_tasks
        if cap is not None and self.active_tasks >= cap:
            self.admission_queue.append(task)
            return
        self._admit(task)

    def _admit(self, task: WorkflowInstance) -> None:
        self.active_tasks += 1
        work_est = self._ideal_time(task)
        deadline = self.now + 1.5 * work_est
        aeg = task.declared_aeg()
        step_cost = 0.0
        if aeg is not None:
            # mean GPU-seconds per step over the nominal path: the unit
            # Eq. 9's work_remaining_steps is priced in
            nom = task.nominal_steps()
            gpu = sum(self.perf.step_compute_s(0.0, s.new_prompt_tokens,
                                               s.out_tokens) for s in nom)
            step_cost = gpu / max(len(nom), 1)
        self.co.register_task(task.task_id, task.tenant, task.tools(),
                              deadline, work_est, self.now,
                              prefix_tokens=task.prefix_tokens,
                              aeg=aeg, step_cost_s=step_cost,
                              entry_node=task.path[0] if task.path else 0)
        self._enqueue_step(StepJob(task, 0, self.now))

    def _can_admit(self, w: int, job: StepJob) -> bool:
        """Slot AND memory admission: a decode starts only if its KV fits
        beside the running requests (idle cache is evictable)."""
        ws = self.workers[w]
        if not ws.alive or ws.active >= self.perf.max_batch:
            return False
        ctx_bytes = job.task.context_before(job.step_idx) * \
            self.perf.kv_bytes_per_token
        return ws.active_kv + ctx_bytes <= self.co.capacity

    def _queue_push(self, w: int, job: StepJob) -> None:
        """Insert a pending step in priority order.  One code path for
        every producer (enqueue, migration landing, fault requeue), so
        AFS ordering can't be bypassed with a hardcoded priority."""
        if self.policy.queue_discipline == "afs":
            prio = -self.co.afs.priority(job.task.tenant)
        else:
            prio = job.enqueued_at
        ws = self.workers[w]
        if not ws.queue:               # empty -> nonempty transition
            self._nonempty.add(w)
            self.co.on_worker_busy(w)
        ws.queue.push(prio, next(self._seq), job)
        self._load_delta(w, 1)

    def _enqueue_step(self, job: StepJob,
                      worker: Optional[int] = None) -> None:
        """Place a step on ``worker`` (or route it), starting it
        immediately when a slot + KV headroom are free.  A dead
        explicit target falls back to routing; if no worker is alive
        the step parks in the orphan buffer until recover/scale-up."""
        tid = job.task.task_id
        if self.tracer is not None \
                and "step" not in self._tr_open.get(tid, {}):
            # first placement of this step opens the step span; fault
            # requeues and migration landings re-enter with it open
            self._tr_begin(tid, "step", "step", parent_key="session",
                           step=job.step_idx)
        w = worker if worker is not None and self.workers[worker].alive \
            else self._route(job.task)
        if not self.workers[w].alive:
            self._orphans.append(job)
            # the whole cluster is down: the wait still counts as queue
            # time (worker=-1); a pre-existing queue span keeps running
            if self.tracer is not None \
                    and "queue" not in self._tr_open.get(tid, {}):
                self._tr_begin(tid, "queue", "queue_wait",
                               parent_key="step", worker=-1)
            return
        job.worker = w
        job.cancelled = False
        ws = self.workers[w]
        if self._can_admit(w, job):
            ws.active += 1
            self._load_delta(w, 1)
            self._start_step(job)
        else:
            # a re-enqueue (fault drain) closes the old wait first
            self._tr_end(tid, "queue", status="requeued")
            self._tr_begin(tid, "queue", "queue_wait",
                           parent_key="step", worker=w)
            self._queue_push(w, job)

    def _start_step(self, job: StepJob) -> None:
        task, i, w = job.task, job.step_idx, job.worker
        step = task.steps[i]
        ctx = task.context_before(i)
        ws = self.workers[w]
        self.co.ensure_headroom(w, ws.active_kv,
                                ctx * self.perf.kv_bytes_per_token, self.now)
        hit, pf_extra, bg_tokens = self.co.on_step_start(
            task.task_id, w, ctx, self.now)
        # straggler injection: a slow worker serves both phases at
        # rate / factor (§5.2 — stealing should drain it)
        factor = self._speed_factor(w)
        rate = self.perf.prefill_tokens_per_s / factor
        # prefill is compute-bound and serializes per worker; decode slots
        # run in parallel (continuous batching is memory-bound).
        pf_tokens = pf_extra if hit else pf_extra + step.new_prompt_tokens
        regen = 0.0 if hit else pf_extra
        if bg_tokens > 0.0:
            # speculative prefetch: the suffix regeneration ran during
            # the tool gap IF the prefill server had idle time; compute
            # is charged either way (speculation is never free work).
            bg_dur = bg_tokens / rate
            if ws.prefill_free_at + bg_dur <= self.now:
                ws.busy_s += bg_dur          # hidden off the critical path
            else:
                pf_tokens += bg_tokens       # server busy: regen on path
                regen += bg_tokens
        pf_start = max(self.now, ws.prefill_free_at)
        pf_dur = pf_tokens / rate
        ws.prefill_free_at = pf_start + pf_dur
        decode_dur = step.out_tokens * factor / self.perf.decode_tokens_per_s
        done = pf_start + pf_dur + decode_dur
        busy = pf_dur + decode_dur
        ws.busy_s += busy
        ws.regen_s += regen / rate
        kv_bytes = ctx * self.perf.kv_bytes_per_token
        ws.active_kv += kv_bytes
        self._active_kv_total += kv_bytes
        self.metrics[task.task_id].regen_tokens += regen
        attempt = next(self._attempt)
        self.inflight[task.task_id] = InFlightStep(
            job, attempt, w, self.now, done, kv_bytes, busy,
            decode_s=decode_dur, regen_s_charged=regen / rate,
            regen_tokens=regen)
        # the prefill span starts at admission and so absorbs the serial
        # prefill pipeline's backlog wait (pipeline_wait in meta) — that
        # wait is caused by prefill/regeneration load, which is where a
        # TCT decomposition should attribute it.  The decode span is
        # future-dated (pf end); a cancellation landing earlier clamps
        # to a zero-duration span rather than a negative one.
        self._tr_end(task.task_id, "queue")
        self._tr_begin(task.task_id, "pf",
                       "resume" if hit else "prefill", parent_key="step",
                       worker=w, attempt=attempt,
                       tokens=float(pf_tokens), regen=float(regen),
                       pipeline_wait=pf_start - self.now)
        self._tr_begin(task.task_id, "dec", "decode", parent_key="step",
                       t=pf_start + pf_dur, worker=w, attempt=attempt)
        if self.obs_metrics is not None:
            self.obs_metrics.histogram("prefill_s").observe(
                self.now, pf_dur)
        self._push(done, "llm_done", (task.task_id, i, w, attempt))

    def _on_llm_done(self, task_id: str, i: int, w: int,
                     attempt: int) -> None:
        rec = self.inflight.get(task_id)
        if rec is None or rec.attempt != attempt:
            return   # stale: the step was cancelled by a worker fault
        del self.inflight[task_id]
        self._tr_end(task_id, "pf", t=rec.finish - rec.decode_s)
        self._tr_end(task_id, "dec", first_token_t=rec.finish
                     - rec.decode_s)
        task = self.tasks[task_id]
        ws = self.workers[w]
        ws.active -= 1
        self._load_delta(w, -1)
        ws.active_kv -= rec.kv_bytes
        self._active_kv_total -= rec.kv_bytes
        if ws.active < 0 or ws.active_kv < -self._kv_tol:
            raise RuntimeError(
                f"conservation violated on worker {w}: "
                f"active={ws.active} active_kv={ws.active_kv}")
        ws.active_kv = max(0.0, ws.active_kv)   # float dust
        self._drain_queue(w)
        step = task.steps[i]
        ctx_after = task.context_after(i)
        # park boundary: resolve the taken edge (graph: seeded branch
        # draw; dynamic: client callback; scripted: next listed step).
        # Memoized, so fault-retried steps never re-roll the path.
        if task.resolve_next(i) is None:
            # terminal: the workflow's last action is "finish" — no
            # tool wait
            m = self.metrics[task_id]
            if m.finish >= 0:
                raise RuntimeError(f"task {task_id} finished twice")
            self.co.task_finished(task_id, self.now)
            m.finish = self.now
            m.steps = task.n_steps          # actual executed path length
            self.active_tasks -= 1
            self._tr_end(task_id, "step")
            self._tr_end(task_id, "session")
            self._tr_open.pop(task_id, None)
            if self.admission_queue:
                self._admit(self.admission_queue.pop(0))
            return
        # the tool observation has not arrived yet: the cached context
        # covers everything up to and including this step's output
        ctx_cached = ctx_after - step.obs_tokens
        entry_bytes = ctx_cached * self.perf.kv_bytes_per_token
        self.co.on_step_end(task_id, w, ctx_cached, entry_bytes,
                            step.tool, self.now,
                            next_node=task.next_node_hint(i + 1))
        self._tr_begin(task_id, "gap", "tool_gap", parent_key="step",
                       tool=step.tool)
        self._push(self.now + step.tool_latency_s, "tool_done",
                   (task_id, i, w))

    def _on_tool_done(self, task_id: str, i: int, w: int) -> None:
        task = self.tasks[task_id]
        step = task.steps[i]
        self.co.on_tool_done(task_id, step.tool, step.tool_latency_s,
                             step.obs_tokens, self.now)
        self._tr_end(task_id, "gap")
        self._tr_end(task_id, "step")
        self._enqueue_step(StepJob(task, i + 1, self.now))

    def _drain_queue(self, w: int) -> None:
        ws = self.workers[w]
        while True:
            job = ws.queue.peek()
            if job is None or not self._can_admit(w, job):
                break
            self._queue_pop(w)
            ws.active += 1
            self._load_delta(w, 1)
            self._start_step(job)

    # -- epoch: AFS + work stealing ------------------------------------------
    def _epoch_decide(self):
        """O(changes) epoch tick: the load vector is one C division of
        incrementally-maintained counts, the stealer consults the
        indexed idle set and the nonempty-queue index (no cluster-wide
        scans), queue views and the alive list are persistent, and the
        AFS recompute runs over persistent delta-updated columns.
        Overridable hook: ``benchmarks/scale_sweep.py`` swaps in the
        legacy O(n_workers) variant as the A/B baseline."""
        loads = self._loads()
        decision, _ = self.co.epoch_tick(
            self.now, loads, self._queue_views, alive=self._alive_list,
            victim_candidates=self._nonempty, scan_queues=False)
        return decision

    def _on_epoch(self) -> None:
        if self.obs_metrics is not None:
            if self._obs_tick % 10 == 0:
                self._obs_sample()
            self._obs_tick += 1
        decision = self._epoch_decide()
        if decision is not None:
            vq = self.workers[decision.victim].queue
            if self.co.stealer.accept(
                    decision, len(vq), self.now,
                    thief_alive=self.workers[decision.thief].alive):
                job = self._queue_remove(decision.victim,
                                         decision.session_id)
                if job is not None:
                    mig = self.perf.sample_migration_s(self.rng)
                    self.migrations += 1
                    self.metrics[job.task.task_id].migrations += 1
                    self._tr_end(job.task.task_id, "queue",
                                 status="stolen")
                    self._tr_begin(job.task.task_id, "migr", "migration",
                                   parent_key="step",
                                   src=decision.victim,
                                   dst=decision.thief)
                    self.migrating[job.task.task_id] = decision.thief
                    self._push(self.now + mig, "migr_done",
                               (job.task.task_id, job.step_idx,
                                decision.victim, decision.thief))
        if self.events or not self._all_done():
            if not self.events and not any(w.alive for w in self.workers):
                # every worker is dead and nothing is scheduled that
                # could revive one (no recover/scale-up left): ticking
                # forever cannot make progress, so let run() return —
                # unfinished tasks stay visible and
                # check_conservation() reports them
                return
            self._push(self.now + self.perf.epoch_s, "epoch")

    def _obs_sample(self) -> None:
        """Decimated epoch-tick metric sampling (traced runs only):
        per-worker queue depth, batch occupancy, in-flight KV bytes and
        cumulative regeneration seconds, plus cluster memory
        utilization (same formula as ``_sample_mem``), cached pool
        bytes, and per-tenant AFS service.  Read-only off structures
        the scheduler already maintains; the per-worker gauge handles
        are cached (grown lazily on scale-up) so the hot loop skips the
        registry's label-key construction."""
        m = self.obs_metrics
        now = self.now
        while len(self._obs_worker_g) < len(self.workers):
            w = len(self._obs_worker_g)
            self._obs_worker_g.append((
                m.gauge("queue_depth", worker=w),
                m.gauge("batch_occupancy", worker=w),
                m.gauge("kv_active_bytes", worker=w),
                m.gauge("regen_s", worker=w)))
        for w, ws in enumerate(self.workers):
            gq, gb, gk, gr = self._obs_worker_g[w]
            gq.set(now, len(ws.queue))
            gb.set(now, ws.active)
            gk.set(now, ws.active_kv)
            gr.set(now, ws.regen_s)
        m.gauge("pool_bytes_cached").set(now, self.co.pools_used)
        m.gauge("mem_util").set(
            now, (self.co.pools_used + self._active_kv_total)
            / (self.co.capacity * self.n_workers))
        for name in sorted(self.co.afs.tenants):
            m.gauge("afs_service_s", tenant=name).set(
                now, self.co.afs.tenants[name].service_s)

    def _on_migr_done(self, task_id: str, step_idx: int, src: int,
                      dst: int) -> None:
        """A stolen session's KV transfer completed.  Validates against
        live state: if the destination died while the transfer was in
        flight, the KV is dropped and the step re-routes to a live
        worker (it regenerates there — §3.1 accounting) instead of
        parking forever on the dead worker's queue."""
        self.migrating.pop(task_id, None)
        m = self.metrics.get(task_id)
        if m is None or m.finish >= 0:
            self._tr_end(task_id, "migr", status="stale")
            return
        job = StepJob(self.tasks[task_id], step_idx, self.now)
        if not self.workers[dst].alive:
            self._tr_end(task_id, "migr", status="dropped")
            self._enqueue_step(job)          # re-route, cache lost
            return
        self._tr_end(task_id, "migr")
        self.co.migrate_session(task_id, src, dst, self.now)
        self._enqueue_step(job, worker=dst)

    # -- faults / elasticity ---------------------------------------------------
    def _cancel_inflight_on(self, w: int) -> List[StepJob]:
        """Cancel every in-flight step on worker ``w``: refund the
        un-executed tail of the charged compute, release the KV
        reservation, and invalidate the pending ``llm_done`` events
        (their attempt ids no longer match the registry).  The refund
        is taken end-first — decode before prefill, since prefill
        (where regeneration runs) executes first — so regeneration
        time/tokens are only refunded for the prefill portion that
        never ran, keeping regen <= busy per worker while never
        un-charging regeneration that actually executed."""
        ws = self.workers[w]
        victims = sorted(tid for tid, rec in self.inflight.items()
                         if rec.worker == w)
        jobs: List[StepJob] = []
        for tid in victims:
            rec = self.inflight.pop(tid)
            self._tr_end(tid, "pf", status="cancelled")
            self._tr_end(tid, "dec", status="cancelled")
            self._tr_instant(f"worker/{w}", "cancel", task=tid,
                             attempt=rec.attempt)
            ws.active -= 1
            self._load_delta(w, -1)
            ws.active_kv -= rec.kv_bytes
            self._active_kv_total -= rec.kv_bytes
            refund = min(rec.busy_charged,
                         max(0.0, rec.finish - self.now))
            ws.busy_s -= refund
            pf_dur = rec.busy_charged - rec.decode_s
            into_prefill = max(0.0, refund - rec.decode_s)
            if pf_dur > 0.0 and into_prefill > 0.0 \
                    and rec.regen_s_charged > 0.0:
                frac = into_prefill / pf_dur
                ws.regen_s -= rec.regen_s_charged * frac
                self.metrics[tid].regen_tokens -= rec.regen_tokens * frac
            jobs.append(rec.job)
        return jobs

    def _on_fail(self, w: int) -> None:
        """Worker dies: cancel its in-flight steps, requeue them plus
        its queued steps on live workers, wipe its KV pool/affinities.
        Nothing completes on a dead node; retried steps pay cache-loss
        regeneration."""
        self._tr_instant("run", "fault", kind="fail", worker=w)
        ws = self.workers[w]
        if not ws.alive:
            return                           # already down
        ws.alive = False
        self._alive_list[w] = False
        self._n_dead += 1
        if np is not None:
            self._alive_np[w] = False
        self.co.worker_failed(w)
        requeue = self._queue_drain(w)
        requeue.extend(self._cancel_inflight_on(w))
        if ws.active != 0 or abs(ws.active_kv) > self._kv_tol:
            raise RuntimeError(
                f"worker {w} lifecycle leak at failure: "
                f"active={ws.active} active_kv={ws.active_kv}")
        self._active_kv_total -= ws.active_kv    # float dust parity
        ws.active = 0
        ws.active_kv = 0.0
        ws.prefill_free_at = 0.0             # prefill pipeline dies too
        for job in requeue:
            self._enqueue_step(StepJob(job.task, job.step_idx, self.now))

    def _on_recover(self, w: int) -> None:
        self._tr_instant("run", "fault", kind="recover", worker=w)
        if self.workers[w].alive:
            return                           # already up (storm overlap)
        self.workers[w].alive = True
        self._alive_list[w] = True
        self._n_dead -= 1
        if np is not None:
            self._alive_np[w] = True
        self.co.worker_recovered(w, self.now)
        self._readmit_orphans()

    def _on_scale_up(self, _unused: int = 0) -> None:
        self._tr_instant("run", "fault", kind="scale_up",
                         worker=_unused)
        self.co.add_worker(self.now)
        ws = WorkerState()
        self.workers.append(ws)
        self._alive_list.append(True)
        if np is not None:
            self._loadnum = np.append(self._loadnum, 0)
            self._alive_np = np.append(self._alive_np, True)
        self._queue_views.append(_QueueView(ws))
        self.n_workers += 1
        self._readmit_orphans()

    # -- straggler injection ---------------------------------------------------
    def _on_slow(self, w: int) -> None:
        """Plan event: worker ``w`` becomes a straggler (its service
        rates divide by ``straggler_slowdown``).  Steps already in
        flight keep their original finish times — slowdowns hit new
        admissions, like a thermal throttle between batches."""
        self._tr_instant("run", "fault", kind="slow", worker=w)
        self._slow[w] = self.straggler_slowdown

    def _on_heal(self, w: int) -> None:
        self._tr_instant("run", "fault", kind="heal", worker=w)
        self._slow.pop(w, None)

    def _readmit_orphans(self) -> None:
        orphans, self._orphans = self._orphans, []
        for job in orphans:
            self._enqueue_step(StepJob(job.task, job.step_idx, self.now))

    # -- invariants -------------------------------------------------------
    def check_conservation(self) -> None:
        """Check the workflow-atomic lifecycle invariants after a run:
        every admitted task finished exactly once (double finishes raise
        during the run), no step is still queued / in flight / mid-
        migration / orphaned, and per-worker slot and KV accounting
        returned to zero.  Raises RuntimeError listing every violation
        (explicit raises, not asserts, so ``python -O`` cannot compile
        the gate away).  Used by the fault tests and scale benchmark."""
        bad: List[str] = []
        unfinished = [t for t, m in self.metrics.items() if m.finish < 0]
        if unfinished:
            bad.append(f"tasks never finished: {unfinished[:5]}")
        if len(self.metrics) != len(self.tasks):
            bad.append("tasks never admitted")
        if self.admission_queue:
            bad.append("tasks stuck in admission")
        if self.active_tasks != 0:
            bad.append(f"active_tasks={self.active_tasks}")
        if self.inflight:
            bad.append(f"steps still in flight: {sorted(self.inflight)[:5]}")
        if self.migrating:
            bad.append(f"migrations in limbo: {sorted(self.migrating)[:5]}")
        if self._orphans:
            bad.append("orphaned steps never re-admitted")
        for w, ws in enumerate(self.workers):
            if len(ws.queue) != 0:
                bad.append(f"worker {w} queue not drained")
            if ws.active != 0:
                bad.append(f"worker {w} active={ws.active}")
            if abs(ws.active_kv) >= self._kv_tol:
                bad.append(f"worker {w} active_kv={ws.active_kv}")
            # incremental-index invariants: the O(1) mirrors must agree
            # with ground truth at quiescence
            if np is not None and \
                    self._loadnum[w] != ws.active + len(ws.queue):
                bad.append(f"worker {w} load index drifted: "
                           f"{self._loadnum[w]} != "
                           f"{ws.active + len(ws.queue)}")
            if (w in self._nonempty) != bool(ws.queue):
                bad.append(f"worker {w} nonempty index stale")
            if self._alive_list[w] != ws.alive:
                bad.append(f"worker {w} alive mirror stale")
        if abs(self._active_kv_total) >= self._kv_tol * self.n_workers:
            bad.append(f"active_kv_total={self._active_kv_total}")
        if bad:
            raise RuntimeError("conservation violated: " + "; ".join(bad))


# --- summary ----------------------------------------------------------------
def summarize(sim: ClusterSim) -> dict:
    ms = [m for m in sim.metrics.values() if m.finish >= 0]
    if not ms:
        return {}
    tcts = sorted(m.tct for m in ms)
    slo = sum(1 for m in ms if m.tct <= 1.5 * m.ideal_s) / len(ms)
    total_busy = sum(w.busy_s for w in sim.workers) or 1.0
    regen_frac = sum(w.regen_s for w in sim.workers) / total_busy
    mem_num = sum(dt * u for dt, u in sim.mem_samples)
    mem_den = sum(dt for dt, u in sim.mem_samples) or 1.0
    span = (max(m.finish for m in ms) - min(m.arrival for m in ms)) or 1.0
    pool = sim.co.pools[0]
    hits = sim.co.cache_hits
    miss = sim.co.cache_misses
    by_tenant: Dict[str, List[TaskMetrics]] = {}
    for m in ms:
        by_tenant.setdefault(m.tenant.split("-")[0], []).append(m)
    slo_by = {k: sum(1 for m in v if m.tct <= 1.5 * m.ideal_s) / len(v)
              for k, v in by_tenant.items()}
    evictions = sum(p.evictions for p in sim.co.pools)
    inserts = evictions + sum(len(p.entries) for p in sim.co.pools) + hits
    return {
        "n_tasks": len(ms),
        "tct_mean": sum(tcts) / len(tcts),
        "tct_p50": tcts[len(tcts) // 2],
        "tct_p99": tcts[min(len(tcts) - 1, int(0.99 * len(tcts)))],
        "ideal_mean": sum(m.ideal_s for m in ms) / len(ms),
        "slo_attainment": slo,
        "slo_by_tenant": slo_by,
        "mem_util": mem_num / mem_den,
        "regen_time_frac": regen_frac,
        "throughput_tasks_per_min": len(ms) / span * 60.0,
        "cache_hit_rate": hits / max(hits + miss, 1),
        "migrations_per_task": sim.migrations / len(ms),
        "evict_rate": evictions / max(inserts, 1),
        "regen_tokens_total": sum(m.regen_tokens for m in ms),
    }
