"""Fault-tolerance & elasticity scenarios, shared across BOTH execution
substrates: plans are plain ``(t_seconds, kind, worker)`` triples
consumed by ``ClusterSim(fault_plan=...)`` (discrete-event simulator)
and ``ServingRuntime(fault_plan=...)`` (real-inference runtime, where a
"worker" is an ``Engine`` and a fail cancels in-flight attempts through
the attempt-stamped registry, reclaims slot KV, and releases real pool
blocks):

  ("fail", w)     worker w dies: queue requeued, KV lost, affinity dropped
  ("recover", w)  worker returns empty-cached
  ("scale_up", 0) elastic scale-out: a fresh worker joins
  ("slow", w)     worker w becomes a straggler (rates / slowdown factor)
  ("heal", w)     straggler returns to full speed

Also provides straggler injection (a slow worker = reduced rates), which
exercises the paper's own mitigation (work stealing, §5.2), and
preemption storms (spot-reclamation-style simultaneous mass kills).
Both substrates keep their conservation invariants (admitted ==
finished, zero slot/KV leak) and byte-identical identical-seed replay
under every plan here.
"""
from __future__ import annotations

import random
from typing import List, Tuple

Plan = List[Tuple[float, str, int]]


def crash_recover_plan(n_workers: int, horizon_s: float, n_faults: int = 2,
                       downtime_s: float = 120.0, seed: int = 0) -> Plan:
    rng = random.Random(seed)
    plan: Plan = []
    for _ in range(n_faults):
        w = rng.randrange(n_workers)
        t = rng.uniform(0.2, 0.6) * horizon_s
        plan.append((t, "fail", w))
        plan.append((t + downtime_s, "recover", w))
    return sorted(plan)


def elastic_plan(horizon_s: float, n_new_workers: int = 2) -> Plan:
    return [(horizon_s * (0.3 + 0.2 * i), "scale_up", 0)
            for i in range(n_new_workers)]


def chaos_plan(n_workers: int, horizon_s: float, n_events: int = 20,
               seed: int = 0, p_fail: float = 0.5,
               p_recover: float = 0.35, min_alive: int = 1) -> Plan:
    """Randomized fail/recover/scale-up schedule for chaos testing.

    Tracks cluster membership so the plan is always executable: only
    live workers fail, only dead workers recover, at least ``min_alive``
    workers stay up at every instant (a fully-dead cluster can make no
    progress, and the conservation tests require forward progress).
    Deterministic for a given seed — every choice draws from one
    ``random.Random`` and iterates sorted sets.
    """
    rng = random.Random(seed)
    alive = set(range(n_workers))
    dead: set = set()
    next_id = n_workers
    plan: Plan = []
    t = 0.0
    for _ in range(n_events):
        t += rng.uniform(0.02, 0.08) * horizon_s
        if t >= horizon_s:
            break
        r = rng.random()
        if r < p_fail and len(alive) > min_alive:
            w = rng.choice(sorted(alive))
            alive.discard(w)
            dead.add(w)
            plan.append((t, "fail", w))
        elif r < p_fail + p_recover and dead:
            w = rng.choice(sorted(dead))
            dead.discard(w)
            alive.add(w)
            plan.append((t, "recover", w))
        else:
            plan.append((t, "scale_up", 0))
            alive.add(next_id)
            next_id += 1
    return plan


def straggler_plan(n_workers: int, horizon_s: float, n_stragglers: int = 2,
                   slow_for_s: float = 120.0, seed: int = 0) -> Plan:
    """Transient stragglers: each picked worker serves at reduced rates
    (``ClusterSim.straggler_slowdown``) for ``slow_for_s``, then heals.
    Work stealing (§5.2) should drain the slow worker's queue onto
    healthy peers, bounding p99 TCT."""
    rng = random.Random(seed)
    plan: Plan = []
    # distinct workers: overlapping slow windows on one worker would be
    # cancelled early by the first heal (the sim keeps one factor per
    # worker), silently weakening the injected pressure
    for w in rng.sample(range(n_workers), min(n_stragglers, n_workers)):
        t = rng.uniform(0.15, 0.6) * horizon_s
        plan.append((t, "slow", w))
        plan.append((t + slow_for_s, "heal", w))
    return sorted(plan)


def preemption_storm_plan(n_workers: int, horizon_s: float,
                          n_storms: int = 2, kill_frac: float = 0.5,
                          downtime_s: float = 60.0, seed: int = 0,
                          min_alive: int = 1) -> Plan:
    """Spot-reclamation storms: at each storm instant a random
    ``kill_frac`` of the live workers fail *simultaneously* (mass
    in-flight cancellation + requeue onto the survivors), then recover
    together after ``downtime_s``.  At least ``min_alive`` workers stay
    up so the cluster can absorb the displaced work.  Storms are spaced
    so a storm never fires while the previous one's victims are still
    down (plans stay executable: only live workers fail)."""
    rng = random.Random(seed)
    plan: Plan = []
    gap = max((horizon_s * 0.6) / max(n_storms, 1), downtime_s * 1.5)
    t = 0.2 * horizon_s
    for _ in range(n_storms):
        if t >= horizon_s:
            break
        workers = list(range(n_workers))
        rng.shuffle(workers)
        n_kill = min(int(n_workers * kill_frac), n_workers - min_alive)
        for w in workers[:n_kill]:
            plan.append((t, "fail", w))
            plan.append((t + downtime_s, "recover", w))
        t += gap
    return sorted(plan)


class StragglerInjector:
    """Marks workers as stragglers by scaling their service rates.

    The simulator consults ``factor(w)`` when computing step durations;
    work stealing should drain the straggler's queue onto healthy
    workers, bounding p99 TCT.
    """

    def __init__(self, slow_workers: dict):
        # {worker_id: slowdown_factor>1}
        self.slow = dict(slow_workers)

    def factor(self, w: int) -> float:
        return self.slow.get(w, 1.0)
