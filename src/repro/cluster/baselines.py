"""Baseline scheduler matrix (paper §9.1 "Baselines").

Each baseline = a SimPolicy capturing what that system can and cannot
see.  The differences mirror §9.1.1's comparison points: session vs
prefix affinity, tool-call TTL, task-level fairness.
"""
from __future__ import annotations

from repro.cluster.simulator import SimPolicy
from repro.core.coordinator import SAGAConfig


def vllm() -> SimPolicy:
    """vLLM v0.6.0: FCFS, request-level, LRU KV pool, no affinity."""
    return SimPolicy(
        name="vllm",
        saga=SAGAConfig(cache_policy="none", enable_affinity=False,
                        enable_stealing=False, enable_ttl=False,
                        enable_prefetch=False, enable_afs=False,
                        observability="none"),
        routing="least", queue_discipline="fcfs")


def vllm_apc() -> SimPolicy:
    """vLLM v0.15.1 + Automatic Prefix Caching + PrefixCacheAffinityRouter:
    prefix-level (not session-level) affinity; LRU over session suffixes;
    no tool TTL."""
    return SimPolicy(
        name="vllm_apc",
        saga=SAGAConfig(cache_policy="prefix", prefix_fraction=0.35,
                        enable_affinity=False, enable_stealing=False,
                        enable_ttl=False, enable_prefetch=False,
                        enable_afs=False, observability="none"),
        routing="group", queue_discipline="fcfs")


def sglang() -> SimPolicy:
    """SGLang v0.5.8: RadixAttention + cache-aware load balancing —
    session-level affinity emerges from the radix router, but no
    workflow TTL / stealing / task fairness."""
    return SimPolicy(
        name="sglang",
        saga=SAGAConfig(cache_policy="prefix", prefix_fraction=0.45,
                        enable_affinity=True, enable_stealing=False,
                        enable_ttl=False, enable_prefetch=False,
                        enable_afs=False, observability="none"),
        routing="session", queue_discipline="fcfs")


def llumnix() -> SimPolicy:
    """Llumnix v1.2: vLLM + reactive live migration for load balance;
    no workflow awareness."""
    return SimPolicy(
        name="llumnix",
        saga=SAGAConfig(cache_policy="none", enable_affinity=False,
                        enable_stealing=True, enable_ttl=False,
                        enable_prefetch=False, enable_afs=False,
                        observability="none"),
        routing="least", queue_discipline="fcfs")


def trt_scaffolding() -> SimPolicy:
    """TRT-LLM v1.1 + Scaffolding: multi-step aware on a single node
    (KV Cache Connector) — sticky sessions + prefix reuse, but no
    cluster-wide scheduling."""
    return SimPolicy(
        name="trt_scaffolding",
        saga=SAGAConfig(cache_policy="prefix", prefix_fraction=0.45,
                        enable_affinity=True, enable_stealing=False,
                        enable_ttl=False, enable_prefetch=False,
                        enable_afs=False, observability="none"),
        routing="sticky", queue_discipline="fcfs")


def kvflow() -> SimPolicy:
    """KVFlow (our reimplementation): workflow-aware eviction + tool TTL
    via agent step graphs, but no distributed scheduling / fairness."""
    return SimPolicy(
        name="kvflow",
        saga=SAGAConfig(cache_policy="walru", enable_affinity=True,
                        enable_stealing=False, enable_ttl=True,
                        enable_prefetch=False, enable_afs=False,
                        observability="hints"),
        routing="sticky", queue_discipline="fcfs")


def saga(observability: str = "hints") -> SimPolicy:
    """Full SAGA."""
    return SimPolicy(
        name=f"saga[{observability}]",
        saga=SAGAConfig(cache_policy="walru", observability=observability),
        routing="session", queue_discipline="afs")


def saga_ablation(drop: str) -> SimPolicy:
    """Table 4: full SAGA minus one component."""
    cfg = SAGAConfig(cache_policy="walru", observability="hints")
    pol = SimPolicy(name=f"saga-w/o-{drop}", saga=cfg, routing="session",
                    queue_discipline="afs")
    if drop == "walru":
        cfg.cache_policy = "lru"
    elif drop == "ttl":
        cfg.enable_ttl = False
    elif drop == "prefetch":
        cfg.enable_prefetch = False
    elif drop == "affinity":
        cfg.enable_affinity = False
        pol.routing = "least"
    elif drop == "stealing":
        cfg.enable_stealing = False
    elif drop == "afs":
        cfg.enable_afs = False
        pol.queue_discipline = "fcfs"
    else:
        raise ValueError(drop)
    return pol


def strategy(name: str) -> SimPolicy:
    """Table 8: Pure BFS / Pure DFS / Hybrid execution strategies."""
    base = saga()
    if name == "bfs":
        base.name = "pure_bfs"
        base.admission_max_tasks = None       # admit everything
        base.saga.enable_ttl = False          # throughput-first: no holds
        base.saga.cache_policy = "lru"
    elif name == "dfs":
        base.name = "pure_dfs"
        base.admission_max_tasks = 24         # few tasks run to completion
    elif name == "hybrid":
        base.name = "hybrid"
        base.admission_max_tasks = 160        # SAGA's operating point
    else:
        raise ValueError(name)
    return base


ALL_BASELINES = {
    "vllm": vllm, "vllm_apc": vllm_apc, "sglang": sglang,
    "llumnix": llumnix, "trt_scaffolding": trt_scaffolding,
    "kvflow": kvflow, "saga": saga,
}
