"""Distributed cluster runtime: discrete-event simulator, workload
generators (SWE-bench / WebArena / BurstGPT-like), baseline schedulers,
fault injection, metrics."""
