"""Virtual-time span tracer (the observability tentpole's core).

A ``Span`` is one interval on the deterministic virtual clock, living
on a named *track* (``session/<sid>``, ``engine/<w>``, ``run``) with an
optional parent — ``begin``/``end`` build the per-session tree, and
``instant`` marks zero-duration events (preemption decisions, parks,
prefetch landings, fault-plan events, attempt cancellations).

Determinism contract: span ids come from one monotone counter in event
order, every container is a list or an insertion-ordered dict keyed by
ints/strings (never ``id()``), and no wall clock is ever read — so two
identical-seed runs emit byte-identical ``canonical_bytes()`` even
across processes with different ``PYTHONHASHSEED``.  The tracer only
*records*; it never feeds a value back into scheduling, which is what
keeps a traced run's ``summarize()`` byte-identical to the untraced
run (asserted by the traced CI smoke leg).

Conservation: a well-hooked substrate closes every span it opens —
``check_closed()`` raises listing any still-open span, and the
trace-conservation test suite reconciles span counts against event
counts under chaos plans (a cancelled attempt must close its spans
with ``status="cancelled"``, not leak them).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from typing import Dict, List, Optional

ROOT = -1                       # parent_id of top-level spans


@dataclasses.dataclass
class Span:
    """One virtual-time interval (or instant) on a track.

    ``status`` is ``"open"`` until ``end`` stamps the outcome: ``"ok"``
    for the normal path, or an explicit abnormal exit — ``"cancelled"``
    (fault killed the attempt), ``"preempted"`` (AFS parked the decode
    mid-step), ``"stolen"`` (left the queue for migration),
    ``"requeued"`` (engine failure drained the queue).  Instants are
    born closed."""
    span_id: int
    parent_id: int
    track: str
    name: str
    t0: float
    t1: float = -1.0
    status: str = "open"
    kind: str = "span"          # "span" | "instant"
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return max(0.0, self.t1 - self.t0)

    @property
    def closed(self) -> bool:
        return self.status != "open"

    def to_json(self) -> dict:
        return {
            "span_id": self.span_id, "parent_id": self.parent_id,
            "track": self.track, "name": self.name,
            "t0": self.t0, "t1": self.t1, "status": self.status,
            "kind": self.kind, "meta": dict(self.meta),
        }


class Tracer:
    """Append-only span recorder on the virtual clock."""

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self._by_id: Dict[int, Span] = {}
        # insertion-ordered open-span registry (a dict, not a set: the
        # iteration order of check_closed's error message is part of
        # the determinism contract)
        self._open: Dict[int, None] = {}
        self._next = itertools.count()

    def __len__(self) -> int:
        return len(self.spans)

    # -- recording ------------------------------------------------------
    def begin(self, track: str, name: str, t: float,
              parent: int = ROOT, **meta) -> int:
        sp = Span(next(self._next), parent, track, name, float(t),
                  meta=dict(meta))
        self.spans.append(sp)
        self._by_id[sp.span_id] = sp
        self._open[sp.span_id] = None
        return sp.span_id

    def end(self, span_id: int, t: float, status: str = "ok",
            **meta) -> Span:
        sp = self._by_id[span_id]
        if sp.closed:
            raise ValueError(
                f"span {span_id} ({sp.track}/{sp.name}) ended twice: "
                f"already {sp.status!r}")
        # a cancellation can land before a future-dated phase would
        # have started (serialized prefill pipeline): clamp, never a
        # negative duration
        sp.t1 = max(float(t), sp.t0)
        sp.status = status
        sp.meta.update(meta)
        del self._open[span_id]
        return sp

    def instant(self, track: str, name: str, t: float,
                parent: int = ROOT, **meta) -> int:
        sp = Span(next(self._next), parent, track, name, float(t),
                  t1=float(t), status="ok", kind="instant",
                  meta=dict(meta))
        self.spans.append(sp)
        self._by_id[sp.span_id] = sp
        return sp.span_id

    def complete(self, track: str, name: str, t0: float, t1: float,
                 parent: int = ROOT, **meta) -> int:
        """Record an already-finished interval in one call (decode-round
        spans, whose bounds are both known at the round event)."""
        sid = self.begin(track, name, t0, parent=parent, **meta)
        self.end(sid, t1)
        return sid

    def note(self, span_id: int, **meta) -> None:
        """Attach late metadata to a live or closed span (e.g. the
        first-token time learned one decode round after the span
        began)."""
        self._by_id[span_id].meta.update(meta)

    # -- inspection -----------------------------------------------------
    def get(self, span_id: int) -> Span:
        return self._by_id[span_id]

    def open_spans(self) -> List[Span]:
        return [self._by_id[i] for i in self._open]

    def children(self) -> Dict[int, List[Span]]:
        """parent_id -> child spans, in span-id (= event) order."""
        out: Dict[int, List[Span]] = {}
        for sp in self.spans:
            out.setdefault(sp.parent_id, []).append(sp)
        return out

    def counts(self) -> Dict[str, int]:
        """Span count per name (instants included), name-sorted."""
        out: Dict[str, int] = {}
        for sp in self.spans:
            out[sp.name] = out.get(sp.name, 0) + 1
        return dict(sorted(out.items()))

    def counts_by_status(self, name: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for sp in self.spans:
            if sp.name == name:
                out[sp.status] = out.get(sp.status, 0) + 1
        return dict(sorted(out.items()))

    def check_closed(self) -> None:
        """Raise if any span is still open — the trace twin of
        ``check_conservation``: an open span at end-of-run is a leaked
        lifecycle, exactly like a leaked slot or KV block."""
        if self._open:
            leaked = [f"{sp.track}/{sp.name}#{sp.span_id}"
                      for sp in self.open_spans()]
            raise RuntimeError(
                f"{len(leaked)} span(s) never closed: {leaked[:8]}")

    # -- serialization --------------------------------------------------
    def to_json(self) -> dict:
        return {"spans": [sp.to_json() for sp in self.spans]}

    def canonical_bytes(self) -> bytes:
        """Byte-stable serialization (sorted keys, fixed separators):
        the cross-process / cross-PYTHONHASHSEED identity contract for
        trace content."""
        return json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":")).encode("utf-8")


def as_tracer(trace) -> Optional[Tracer]:
    """Normalize the ``trace=`` constructor knob: ``True`` builds a
    fresh tracer, a ``Tracer`` instance is used as-is (shared across an
    A/B pair if the caller wants one timeline), falsy disables."""
    if isinstance(trace, Tracer):
        return trace
    return Tracer() if trace else None
