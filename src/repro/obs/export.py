"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and the
per-run ``report()`` latency breakdown.

::

    PYTHONPATH=src python -m repro.obs.export trace.json

runs a small traced simulator demo (SAGA policy, SWE-bench-style mix),
writes a Perfetto-loadable trace to the given path, and prints the
per-phase breakdown — load the JSON at https://ui.perfetto.dev or
chrome://tracing.  Programmatic use: ``chrome_trace(tracer, metrics)``
returns the trace dict; ``report(tracer)`` returns the breakdown
(per-phase TCT decomposition, TTFT-on-resume, p50/p99 decode-round
latency) that ``fig1_breakdown.py`` and the workflow smoke consume.

Determinism: pids/tids are assigned in first-seen span order, events
are emitted in span-id order, and timestamps are virtual microseconds
— identical-seed runs export byte-identical traces.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import ROOT, Span, Tracer

# phases that decompose a session's TCT (disjoint by construction:
# queue_wait ends at admit, prefill/resume ends at the decode join,
# decode ends at the round that finishes the step, tool_gap spans the
# virtual tool latency, migration covers the steal transfer window).
# ``handoff`` (disaggregated prefill->decode transfer) is session-level
# and OVERLAPS the tool gap, so it is reported but never subtracted
# from the unattributed remainder.
PHASES = ("queue_wait", "prefill", "resume", "decode", "tool_gap",
          "migration", "handoff")


def percentile(xs: Sequence[float], p: float) -> float:
    """Sorted-index percentile with the repo's summarize() convention:
    ``xs_sorted[min(n-1, int(p * n))]`` — matches the committed
    fingerprint math exactly so traced reports and summaries agree."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    return float(xs[min(len(xs) - 1, int(p * len(xs)))])


def latency_summary(xs: Sequence[float]) -> dict:
    xs = sorted(float(x) for x in xs)
    if not xs:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0,
                "max": 0.0}
    return {
        "n": len(xs),
        "mean": sum(xs) / len(xs),
        "p50": percentile(xs, 0.50),
        "p99": percentile(xs, 0.99),
        "max": xs[-1],
    }


# -- Chrome/Perfetto trace_event --------------------------------------
def chrome_trace(tracer: Tracer,
                 metrics: Optional[MetricsRegistry] = None) -> dict:
    """Build a ``trace_event``-format dict (Perfetto / chrome://tracing
    loadable): complete ("X") events for spans, instant ("i") events,
    thread-name metadata per track, and counter ("C") events from the
    registry's gauge series."""
    tids: Dict[str, int] = {}
    events: List[dict] = []
    for sp in tracer.spans:
        tid = tids.get(sp.track)
        if tid is None:
            tid = len(tids) + 1
            tids[sp.track] = tid
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": sp.track}})
        args = dict(sp.meta)
        args["status"] = sp.status
        args["span_id"] = sp.span_id
        if sp.parent_id != ROOT:
            args["parent_id"] = sp.parent_id
        if sp.kind == "instant":
            events.append({"ph": "i", "name": sp.name, "pid": 1,
                           "tid": tid, "ts": sp.t0 * 1e6, "s": "t",
                           "args": args})
        else:
            events.append({"ph": "X", "name": sp.name, "pid": 1,
                           "tid": tid, "ts": sp.t0 * 1e6,
                           "dur": sp.dur * 1e6, "args": args})
    if metrics is not None:
        for name, m in sorted(metrics.to_json().items()):
            if m["type"] != "gauge":
                continue
            for labels, series in sorted(m["series"].items()):
                cname = name + ("" if labels == "{}" else " " + labels)
                for t, v in series:
                    events.append({"ph": "C", "name": cname, "pid": 1,
                                   "ts": t * 1e6,
                                   "args": {"value": v}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer: Tracer, path: str,
                       metrics: Optional[MetricsRegistry] = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer, metrics), f, indent=1)


# -- per-run latency breakdown ----------------------------------------
def report(tracer: Tracer) -> dict:
    """Per-phase TCT decomposition off the span tree.

    For every finished session span: TCT = span duration; each phase
    child's duration is attributed to its name (``resume`` is the
    cache-hit twin of ``prefill``); the unattributed remainder is
    ``other`` (event-grain slack, e.g. a round boundary the session
    waited on).  TTFT-on-resume is measured per resumed step: tool
    return (step span start) to the first decoded token of the resumed
    attempt.  Decode-round latency summarizes the engine-track round
    spans (runtime substrate only; the simulator models decode as one
    interval)."""
    kids = tracer.children()
    sessions = [sp for sp in tracer.spans
                if sp.name == "session" and sp.closed]
    tcts: List[float] = []
    phase_tot = {p: 0.0 for p in PHASES}
    per_session_other: List[float] = []
    ttft_resume: List[float] = []
    for ses in sessions:
        tcts.append(ses.dur)
        attributed = 0.0
        for step in kids.get(ses.span_id, ()):
            if step.name == "handoff":
                # disagg transfer window: runs concurrently with the
                # tool gap (off the critical path), so it contributes
                # to its own phase bucket without reducing ``other``
                if step.kind == "span":
                    phase_tot["handoff"] += step.dur
                continue
            phases = kids.get(step.span_id, ())
            for ph in phases:
                if ph.name in phase_tot and ph.kind == "span":
                    phase_tot[ph.name] += ph.dur
                    attributed += ph.dur
            resumed = [p for p in phases if p.name == "resume"]
            if resumed and resumed[-1].status == "ok":
                decodes = [p for p in phases if p.name == "decode"
                           and p.status == "ok"]
                if decodes:
                    first_tok = decodes[-1].meta.get("first_token_t",
                                                     decodes[-1].t0)
                    ttft_resume.append(float(first_tok) - step.t0)
        per_session_other.append(max(0.0, ses.dur - attributed))
    rounds = [sp.dur for sp in tracer.spans
              if sp.name == "round" and sp.closed]
    tct_total = sum(tcts)
    phase_tot["other"] = sum(per_session_other)
    denom = max(tct_total, 1e-12)
    cancelled = sum(1 for sp in tracer.spans
                    if sp.status == "cancelled")
    return {
        "n_sessions": len(sessions),
        "tct": latency_summary(tcts),
        "phase_totals_s": {k: v for k, v in sorted(phase_tot.items())},
        "phase_frac": {k: v / denom
                       for k, v in sorted(phase_tot.items())},
        "ttft_on_resume": latency_summary(ttft_resume),
        "round_latency": latency_summary(rounds),
        "span_counts": tracer.counts(),
        "cancelled_spans": cancelled,
    }


def format_report(rep: dict, title: str = "trace report") -> str:
    lines = [f"{title}: {rep['n_sessions']} sessions, "
             f"tct mean={rep['tct']['mean']:.3f}s "
             f"p99={rep['tct']['p99']:.3f}s"]
    for name, frac in rep["phase_frac"].items():
        tot = rep["phase_totals_s"][name]
        lines.append(f"  {name:<11s} {tot:9.3f}s  {100 * frac:5.1f}%")
    tr = rep["ttft_on_resume"]
    if tr["n"]:
        lines.append(f"  ttft-on-resume mean={tr['mean']:.3f}s "
                     f"p99={tr['p99']:.3f}s over {tr['n']} resumes")
    rl = rep["round_latency"]
    if rl["n"]:
        lines.append(f"  decode round p50={rl['p50'] * 1e3:.1f}ms "
                     f"p99={rl['p99'] * 1e3:.1f}ms over {rl['n']} rounds")
    if rep["cancelled_spans"]:
        lines.append(f"  {rep['cancelled_spans']} cancelled span(s) "
                     "(fault retries)")
    return "\n".join(lines)


def _demo(out_path: str) -> None:
    """Traced simulator demo for the CLI: a small SWE-bench-style run
    under the SAGA policy, exported to ``out_path``."""
    # imported lazily: the simulator imports this package's tracer
    from repro.cluster.baselines import saga
    from repro.cluster.simulator import ClusterSim
    from repro.cluster.workload import swebench_workload

    tasks = swebench_workload(n_tasks=40, rate_per_min=5.0, seed=0)
    sim = ClusterSim(tasks, saga(), n_workers=8, seed=0, trace=True)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    sim.tracer.check_closed()
    write_chrome_trace(sim.tracer, out_path, sim.obs_metrics)
    print(format_report(report(sim.tracer),
                        title="demo (40 swebench tasks, saga)"))
    print(f"wrote {out_path} — load it at https://ui.perfetto.dev")


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 1 or argv[0].startswith("-"):
        print("usage: python -m repro.obs.export <trace.json>\n"
              "runs a traced simulator demo and writes a Perfetto-"
              "loadable trace_event JSON", file=sys.stderr)
        return 2
    _demo(argv[0])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
