"""Deterministic metrics registry: counters, gauges (virtual-time
series) and virtual-time-bucketed histograms.

The registry is sampled at the coordinator's epoch tick (§6's 100 ms
cadence) by both substrates: per-engine queue depth, KV pool occupancy
split resident/parked/free, batch occupancy, AFS deviation and lag,
and cumulative regeneration bytes.  Histograms additionally bucket
their observations into fixed-width virtual-time windows so a latency
distribution can be read *over the run* (did p99 round latency spike
during the preemption storm?), not only in aggregate.

Determinism: metrics are keyed ``(name, sorted(labels))`` in an
insertion-ordered dict; exports sort by key; values are ints/floats
recorded off the virtual clock — ``to_prometheus()`` /
``canonical_bytes()`` output is byte-identical across processes and
``PYTHONHASHSEED`` for identical-seed runs.
"""
from __future__ import annotations

import bisect
import json
from typing import Dict, List, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    """Monotone counter."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def to_json(self):
        return self.value


class Gauge:
    """Virtual-time series of point samples; Prometheus export keeps
    the last value, JSON export keeps the whole series."""
    __slots__ = ("samples",)

    def __init__(self) -> None:
        self.samples: List[Tuple[float, float]] = []

    def set(self, t: float, v: float) -> None:
        self.samples.append((float(t), float(v)))

    @property
    def last(self) -> float:
        return self.samples[-1][1] if self.samples else 0.0

    def to_json(self):
        return [[t, v] for t, v in self.samples]


class Histogram:
    """Value-bucketed histogram whose observations carry a virtual
    timestamp: alongside the cumulative value buckets, each observation
    is assigned to a fixed-width virtual-time window (``window_s``) so
    per-window count/sum expose how the distribution evolved."""
    __slots__ = ("edges", "counts", "count", "sum", "window_s",
                 "windows")

    def __init__(self, edges, window_s: float = 1.0) -> None:
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.window_s = float(window_s)
        self.windows: Dict[int, List[float]] = {}   # win -> [n, sum]

    def observe(self, t: float, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        win = int(float(t) // self.window_s)
        cell = self.windows.setdefault(win, [0, 0.0])
        cell[0] += 1
        cell[1] += v

    def quantile(self, q: float) -> float:
        """Bucket-upper-bound quantile estimate (0 <= q <= 1)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target and c:
                return self.edges[i] if i < len(self.edges) \
                    else self.edges[-1] if self.edges else 0.0
        return self.edges[-1] if self.edges else 0.0

    def to_json(self):
        return {
            "edges": list(self.edges),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "window_s": self.window_s,
            "windows": {str(k): list(v)
                        for k, v in sorted(self.windows.items())},
        }


class MetricsRegistry:
    """Get-or-create registry of labelled metrics."""

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, LabelKey], object] = {}
        self._types: Dict[str, str] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, object],
             factory):
        prev = self._types.setdefault(name, kind)
        if prev != kind:
            raise ValueError(
                f"metric {name!r} registered as {prev}, requested as "
                f"{kind}")
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = factory()
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(self, name: str, edges=(0.01, 0.025, 0.05, 0.1,
                                          0.25, 0.5, 1.0, 2.5, 5.0),
                  window_s: float = 1.0, **labels) -> Histogram:
        return self._get("histogram", name, labels,
                         lambda: Histogram(edges, window_s=window_s))

    # -- export ---------------------------------------------------------
    def to_json(self) -> dict:
        out: Dict[str, dict] = {}
        for (name, key), m in sorted(self._metrics.items()):
            out.setdefault(name, {"type": self._types[name],
                                  "series": {}})
            out[name]["series"][_label_str(key) or "{}"] = m.to_json()
        return out

    def canonical_bytes(self) -> bytes:
        return json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":")).encode("utf-8")

    def to_prometheus(self) -> str:
        """Prometheus text exposition (last value for gauges,
        cumulative ``le`` buckets for histograms)."""
        lines: List[str] = []
        seen_type: Dict[str, bool] = {}
        for (name, key), m in sorted(self._metrics.items()):
            kind = self._types[name]
            if name not in seen_type:
                seen_type[name] = True
                lines.append(f"# TYPE {name} {kind}")
            ls = _label_str(key)
            if isinstance(m, Counter):
                lines.append(f"{name}{ls} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"{name}{ls} {m.last:g}")
            else:
                cum = 0
                for edge, c in zip(m.edges, m.counts):
                    cum += c
                    el = _label_str(key + (("le", f"{edge:g}"),))
                    lines.append(f"{name}_bucket{el} {cum}")
                el = _label_str(key + (("le", "+Inf"),))
                lines.append(f"{name}_bucket{el} {m.count}")
                lines.append(f"{name}_sum{ls} {m.sum:g}")
                lines.append(f"{name}_count{ls} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")
