"""Deterministic observability layer for both execution substrates.

Every headline claim in the paper is an observability claim — 1.64x
task-completion time, 1.22x memory utilization, 99.2% SLO attainment,
bounded AFS deviation (§6) — and this package is where those numbers
become inspectable while a run happens instead of a single
``summarize()`` dict after it:

  * ``tracer.Tracer`` — virtual-time span tracer.  The runtime and the
    simulator emit one span tree per session (session → step →
    queue_wait / prefill / resume / decode / tool_gap / migration, with
    engine-track decode-round spans and instants for preemption, park,
    prefetch, faults and cancellations), stamped with ``(step, attempt)``
    so fault retries and AFS preemptions are first-class visible events.
  * ``metrics.MetricsRegistry`` — counters, gauges (virtual-time
    series) and virtual-time-bucketed histograms sampled each epoch
    tick: per-engine queue depth, KV pool occupancy (resident / parked /
    free blocks), AFS deviation, batch occupancy, regeneration bytes.
    Prometheus-text and JSON export.
  * ``export`` — Chrome/Perfetto ``trace_event`` JSON
    (``python -m repro.obs.export trace.json``) and a per-run
    ``report()`` latency breakdown (per-phase TCT decomposition,
    TTFT-on-resume, p50/p99 decode-round latency).

Zero-perturbation contract (the sanitizer's contract, inherited):
tracing is read-only and gated (``SAGA_TRACE=1`` /
``ServingRuntime(trace=True)`` / ``ClusterSim(trace=True)``), uses only
virtual time and deterministic ordering — no wall clock, no
``id()``-keyed dicts, no iteration over sets — so a traced run's
``summarize()`` stays byte-identical to the untraced run and the trace
bytes themselves are byte-identical across processes and
``PYTHONHASHSEED``.  See ``docs/OBSERVABILITY.md``.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracer import Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
]
