"""Multi-worker in-process serving cluster under the SAGA coordinator.

Runs REAL inference (tiny zoo models on CPU; same code drives TPU pods)
for multi-step agent sessions: route (Eq. 7) -> resume-or-prefill ->
decode -> park with tool-TTL -> tool gap (virtual time) -> repeat.
Demonstrates and MEASURES the paper's central quantity: prefilled tokens
with and without workflow-atomic scheduling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.serving.engine import Engine


@dataclasses.dataclass
class AgentRequest:
    """One agent task: steps of (new prompt tokens, n decode tokens,
    tool type, tool gap seconds)."""
    session_id: str
    tenant: str
    steps: List[Tuple[List[int], int, str, float]]


class MultiWorkerServer:
    def __init__(self, cfg: ModelConfig, params, *, n_workers: int = 2,
                 saga: Optional[SAGAConfig] = None, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 48):
        self.cfg = cfg
        self.engines = [Engine(cfg, params, n_slots=n_slots,
                               max_len=max_len, pool_blocks=pool_blocks)
                        for _ in range(n_workers)]
        pool_bytes = self.engines[0].pool.num_blocks * \
            self.engines[0].pool.bytes_per_block
        self.co = GlobalCoordinator(saga or SAGAConfig(), n_workers,
                                    pool_bytes)
        self.clock = 0.0
        self.kv_bytes_per_token = (
            2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 2)

    def _loads(self) -> List[float]:
        return [1.0 - (e.free_slot() is not None) * 0.9
                for e in self.engines]

    def run_task(self, req: AgentRequest) -> Dict[str, float]:
        """Execute a whole agent task through the cluster; returns stats."""
        sid = req.session_id
        tools = [t for _, _, t, _ in req.steps]
        self.co.register_task(sid, req.tenant, tools,
                              deadline=self.clock + 3600.0,
                              work_est_s=60.0, now=self.clock,
                              prefix_tokens=0)
        ctx: List[int] = []
        regen = 0
        for (prompt, n_out, tool, gap_s) in req.steps:
            ctx = ctx + list(prompt)
            w = self.co.route(sid, self._loads(), self.clock)
            eng = self.engines[w]
            hit, _, _ = self.co.on_step_start(sid, w, len(ctx),
                                              self.clock)
            # the coordinator's hit means "the pool still holds it";
            # verify against the real block table
            real_hit = hit and eng.has_cache(sid)
            if not real_hit and eng.has_cache(sid):
                eng.evict_session(sid)      # policy said evict earlier
            slot = eng.start_session(sid, np.asarray(ctx, np.int32),
                                     cached_hit=real_hit)
            if not real_hit:
                regen += len(ctx)
            gen = eng.decode({slot: int(ctx[-1])}, n_steps=n_out)[slot]
            ctx = ctx + gen
            eng.park_session(sid)
            self.co.on_step_end(sid, w, len(ctx),
                                len(ctx) * self.kv_bytes_per_token, tool,
                                self.clock)
            # WA-LRU eviction decisions apply to the real pool:
            pool = self.co.pools[w]
            for cached_sid in list(eng.pool.tables):
                if cached_sid != sid and not pool.contains(cached_sid):
                    eng.evict_session(cached_sid)
            self.clock += gap_s
            self.co.on_tool_done(sid, tool, gap_s, len(prompt), self.clock)
        self.co.task_finished(sid, self.clock)
        for eng in self.engines:
            eng.evict_session(sid)
        return {"regen_tokens": regen, "ctx_tokens": len(ctx)}

    def stats(self) -> dict:
        return {
            "prefill_tokens": sum(e.prefill_tokens for e in self.engines),
            "regen_tokens": sum(e.regen_tokens for e in self.engines),
            "decode_steps": sum(e.decode_steps for e in self.engines),
            "coordinator_hits": self.co.cache_hits,
            "coordinator_misses": self.co.cache_misses,
        }
