"""Multi-worker in-process serving cluster under the SAGA coordinator.

Runs REAL inference (tiny zoo models on CPU; same code drives TPU pods)
for multi-step agent sessions: route (Eq. 7) -> resume-or-prefill ->
decode -> park with tool-TTL -> tool gap (virtual time) -> repeat.
Demonstrates and MEASURES the paper's central quantity: prefilled tokens
with and without workflow-atomic scheduling.

This is now a thin SERIAL wrapper over the event-driven
``repro.serving.runtime.ServingRuntime`` — one task submitted and run to
completion at a time, preserving the original blocking ``run_task`` API
(and its tests) while the runtime underneath is the same engine that
interleaves many concurrent sessions.  Load reporting is the runtime's
real queue-depth + slot-occupancy vector, not the old binary
free-slot hack.

DEPRECATED as a client surface: new code should submit through
``repro.serving.client.SagaClient`` (``SagaClient.for_server(server)``
wraps this object; ``run_task`` stays byte-identical for the golden
pins).  See docs/SERVING_API.md.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.coordinator import SAGAConfig
from repro.serving.runtime import AgentRequest, RuntimePerf, ServingRuntime

__all__ = ["AgentRequest", "MultiWorkerServer"]


class MultiWorkerServer:
    def __init__(self, cfg: ModelConfig, params, *, n_workers: int = 2,
                 saga: Optional[SAGAConfig] = None, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 48,
                 perf: Optional[RuntimePerf] = None, seed: int = 0):
        self.cfg = cfg
        self.runtime = ServingRuntime(cfg, params, n_workers=n_workers,
                                      saga=saga, n_slots=n_slots,
                                      max_len=max_len,
                                      pool_blocks=pool_blocks,
                                      perf=perf, seed=seed)
        self.engines = self.runtime.engines
        self.co = self.runtime.co
        self.kv_bytes_per_token = self.runtime.kv_bytes_per_token

    @property
    def clock(self) -> float:
        return self.runtime.ev.now

    def _loads(self) -> List[float]:
        """Real queue-depth + slot-occupancy loads, shared with the
        runtime's router and epoch tick."""
        return [float(x) for x in self.runtime.loads()]

    def run_task(self, req: AgentRequest) -> Dict[str, float]:
        """Execute a whole agent task through the cluster; returns stats.
        Serial: blocks until this task completes (the runtime's clock
        keeps advancing across calls, so TTLs and AFS state carry over)."""
        handle = self.runtime.submit(req, arrival=self.runtime.ev.now)
        self.runtime.run()
        if not handle.done:
            raise RuntimeError(
                f"task {handle.session_id} did not finish")
        ses = self.runtime.sessions[handle.session_id]
        return {"regen_tokens": float(ses.regen_tokens),
                "ctx_tokens": float(len(ses.ctx))}

    def stats(self) -> dict:
        return self.runtime.stats()
