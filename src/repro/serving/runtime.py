"""Event-driven concurrent serving runtime: whole agent workflows
interleaved on real engines (the paper's serving layer, §3-§6).

``ServingRuntime`` executes MANY concurrent multi-step agent sessions
across multiple real ``Engine``s (actual jitted JAX forward passes —
tiny zoo models on CPU, same code on TPU pods) under the same
``GlobalCoordinator`` that drives the discrete-event simulator:

  * **AFS-priority admission** — when an engine's decode slots are full,
    sessions wait in a per-engine ``SessionQueue`` ordered by tenant
    Agent-Fair-Share (§6), not FIFO.
  * **Continuous batching** — all decode-phase sessions co-resident on
    an engine advance together, one batched ``decode_step`` per virtual
    decode round; sessions join/leave the batch mid-flight as prefills
    complete and steps finish (per-slot rows are independent, so
    interleaving is token-for-token identical to serial execution).
  * **Park-on-tool with TTL** — a session entering a tool call parks its
    slot KV into the engine's paged pool; the coordinator stamps the
    entry with a tool-aware TTL (§4.2) and WA-LRU (§4.1) decides who
    survives memory pressure.  Eviction decisions propagate to the real
    block tables through an event-driven callback (the evicted-entry
    list from ``on_step_end``), never a per-step scan of all sessions.
  * **Resume with delta-only prefill** — a returning session that still
    holds pool KV prefills only the new tokens (tool observation + next
    user turn); a victim of eviction regenerates its whole context, the
    paper's central cost.
  * **Affinity routing + work stealing** — Eq. 7 routes a resuming
    session to its KV home unless overloaded; the 100 ms epoch tick
    (ported from the simulator's O(changes) incremental form: integer
    load vector, indexed idle set, nonempty-queue victim index) lets an
    idle engine steal a queued session, migrating its parked KV blocks
    pool-to-pool.
  * **Speculative prefetch with real copies** — during a tool gap the
    prefetcher (§4.3) predicts the next step; if the home engine looks
    overloaded for the resume, the parked KV is *replicated* to the
    likely overflow target so the resume still hits cache.  Copies are
    real block transfers that overlap the (virtual-time) tool gap.

Time is virtual (``repro.serving.events.EventLoop``): tool gaps cost
nothing on the wall clock, and identical-seed runs produce byte-identical
``summarize()`` output even across processes with different
``PYTHONHASHSEED`` — the same determinism contract as the simulator.
Real compute (prefill, decode, KV copies) runs eagerly as its event is
processed.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.serving.engine import Engine
from repro.serving.events import EventLoop, SessionQueue, _RuntimeQueueView
from repro.workflow.program import WorkflowInstance, as_instance

INF = float("inf")


@dataclasses.dataclass
class AgentRequest:
    """One agent task: steps of (new prompt tokens, n decode tokens,
    tool type, tool gap seconds).  ``arrival_s`` places the request on
    the runtime's virtual clock (0 = immediately).

    Backward-compat adapter format: ``submit`` compiles it to a
    scripted ``repro.workflow.AgentProgram`` (byte-identical execution);
    graph / dynamic programs are submitted directly."""
    session_id: str
    tenant: str
    steps: List[Tuple[List[int], int, str, float]]
    arrival_s: float = 0.0


@dataclasses.dataclass
class RuntimePerf:
    """Virtual-time service model (per engine).  Real compute runs
    eagerly; these rates only advance the deterministic clock, mirroring
    ``cluster.perf.PerfModel`` at serving granularity."""
    prefill_tokens_per_s: float = 8000.0
    decode_round_s: float = 0.025        # one batched decode step
    epoch_s: float = 0.100               # coordinator tick (§6)
    migration_mean_s: float = 0.230      # Llumnix-style KV move (Table 7)
    migration_p95_s: float = 0.890

    def sample_migration_s(self, rng: random.Random) -> float:
        mu = math.log(self.migration_mean_s) - 0.3
        sigma = math.log(self.migration_p95_s /
                         self.migration_mean_s) / 1.645 + 0.3
        return min(math.exp(mu + sigma * rng.gauss(0, 1)), 5.0)


@dataclasses.dataclass
class SessionState:
    """Mutable runtime record for one submitted agent session."""
    inst: WorkflowInstance
    session_id: str
    arrival: float
    ctx: List[int] = dataclasses.field(default_factory=list)
    step_idx: int = 0
    engine: int = -1                 # engine owning the current step
    slot: int = -1
    remaining: int = 0               # decode tokens left this step
    next_token: int = 0
    state: str = "new"               # queued|prefill|decode|tool|migrating|done
    cached_hit: bool = False         # admission's hit verdict (pinned)
    regen_tokens: int = 0
    finished_at: float = -1.0
    step_outputs: List[List[int]] = dataclasses.field(default_factory=list)

    @property
    def tct(self) -> float:
        return self.finished_at - self.arrival


@dataclasses.dataclass
class _QueueTicket:
    """One enqueue = one ticket.  The tombstone flag lives HERE, not on
    the shared SessionState: a stolen session that re-enqueues elsewhere
    must not resurrect its lazily-deleted entry in the victim's heap."""
    session_id: str
    cancelled: bool = False


class WorkflowHandle:
    """Client-facing handle for one submitted workflow (returned by
    ``ServingRuntime.submit``): inspect ``status`` / ``step_outputs`` /
    ``path`` while the runtime interleaves, or block on ``result()``."""

    def __init__(self, runtime: "ServingRuntime", ses: "SessionState"):
        self._rt = runtime
        self._ses = ses

    @property
    def session_id(self) -> str:
        return self._ses.session_id

    @property
    def status(self) -> str:
        """new|queued|prefill|decode|tool|migrating|done"""
        return self._ses.state

    @property
    def done(self) -> bool:
        return self._ses.finished_at >= 0

    @property
    def step_outputs(self) -> List[List[int]]:
        """Decoded token ids per executed step (so far)."""
        return [list(o) for o in self._ses.step_outputs]

    @property
    def path(self) -> List[int]:
        """AEG node ids of the executed steps (so far) — shows which
        branches / retry edges the workflow actually took."""
        return list(self._ses.inst.path)

    @property
    def truncated(self) -> bool:
        """True when the engine's context cap ended the workflow before
        its graph/callback did — the taken path is then a strict prefix
        of what an unconstrained substrate would execute."""
        return self._ses.inst.truncated

    @property
    def tct(self) -> float:
        if not self.done:
            raise RuntimeError(f"workflow {self.session_id} not finished")
        return self._ses.tct

    def result(self, horizon_s: float = INF) -> List[List[int]]:
        """Drive the runtime's virtual clock until this workflow
        finishes, then return its per-step decoded token ids.  Other
        concurrent sessions keep interleaving while we wait."""
        if not self.done:
            self._rt._run_until_done(self._ses.session_id, horizon_s)
        if not self.done:
            raise RuntimeError(
                f"workflow {self.session_id} did not finish "
                f"(state={self.status})")
        return self.step_outputs


class ServingRuntime:
    """Deterministic virtual-time event loop over ``n_workers`` real
    engines.  ``submit`` accepts ``AgentProgram``s (scripted / graph /
    dynamic) and legacy ``AgentRequest``s, then ``run`` to completion;
    the ``MultiWorkerServer`` wraps this serially for the legacy API."""

    def __init__(self, cfg: ModelConfig, params, *, n_workers: int = 2,
                 saga: Optional[SAGAConfig] = None, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 48,
                 perf: Optional[RuntimePerf] = None, seed: int = 0,
                 engines: Optional[List[Engine]] = None):
        self.cfg = cfg
        self.engines = engines if engines is not None else [
            Engine(cfg, params, n_slots=n_slots, max_len=max_len,
                   pool_blocks=pool_blocks) for _ in range(n_workers)]
        self.n_workers = len(self.engines)
        self.n_slots = self.engines[0].n_slots
        pool = self.engines[0].pool
        self.kv_bytes_per_token = pool.bytes_per_block / pool.block
        pool_bytes = pool.num_blocks * pool.bytes_per_block
        self.co = GlobalCoordinator(saga or SAGAConfig(), self.n_workers,
                                    pool_bytes)
        self.perf = perf or RuntimePerf()
        self.perf = dataclasses.replace(self.perf,
                                        epoch_s=self.co.cfg.epoch_s)
        self.rng = random.Random(seed)
        self.ev = EventLoop()
        self.sessions: Dict[str, SessionState] = {}
        self.n_done = 0
        # per-engine scheduling state (incremental epoch-tick structures
        # ported from the simulator: integer load counts, nonempty-queue
        # victim index, persistent stealer queue views)
        self.queues: List[SessionQueue] = [SessionQueue()
                                           for _ in range(self.n_workers)]
        self._queue_views = [_RuntimeQueueView(lambda w=w: self.queues[w])
                             for w in range(self.n_workers)]
        self._active: List[set] = [set() for _ in range(self.n_workers)]
        self._resident = [0] * self.n_workers    # prefill + decode sessions
        self._round_live = [False] * self.n_workers
        self._loadnum = np.zeros(self.n_workers, dtype=np.int64)
        self._nonempty: set = set()
        self._alive = [True] * self.n_workers
        self._epoch_live = False
        self.migrating: Dict[str, Tuple[int, int]] = {}
        # instrumentation
        self.migrations = 0
        self.prefetch_copies = 0
        self.prefetch_copy_bytes = 0.0
        for w in range(self.n_workers):
            self.co.on_worker_idle(w, 0.0)

    # -- load reporting (shared with MultiWorkerServer._loads) ----------
    def loads(self) -> np.ndarray:
        """Queue-depth + slot-occupancy load vector in slot units: one
        C-level division of the incrementally-maintained integer counts
        (replaces the old binary free-slot hack)."""
        return self._loadnum / self.n_slots

    def _load_delta(self, w: int, d: int) -> None:
        self._loadnum[w] += d

    # -- submission -----------------------------------------------------
    def submit(self, req,
               arrival: Optional[float] = None) -> "WorkflowHandle":
        """Submit a workflow: an ``AgentProgram`` (scripted / graph /
        dynamic) or a legacy ``AgentRequest`` (compiled to a scripted
        program, byte-identical execution).  Graph and dynamic programs
        resolve their branches at park/resume boundaries on the virtual
        clock; unspecified prompt ids are realized deterministically
        from the program's seed against this model's vocab.  Returns a
        ``WorkflowHandle`` (``result()`` / ``step_outputs`` /
        ``status``)."""
        inst = as_instance(req, vocab=self.cfg.vocab,
                           max_ctx_tokens=self.engines[0].max_len)
        sid = inst.task_id
        if sid in self.sessions:
            raise ValueError(f"duplicate session id {sid!r}")
        t = max(self.ev.now,
                inst.arrival_s if arrival is None else arrival)
        ses = SessionState(inst, sid, t)
        self.sessions[sid] = ses
        self.ev.schedule(t, "arrival", (sid,))
        if not self._epoch_live:
            self._epoch_live = True
            self.ev.schedule(self.ev.now + self.perf.epoch_s, "epoch")
        return WorkflowHandle(self, ses)

    def run(self, horizon_s: float = INF) -> Dict[str, SessionState]:
        """Advance the virtual clock until every submitted session has
        finished (or ``horizon_s``).  Resumable: later submits + runs
        continue on the same clock."""
        while self.ev:
            if self.ev.peek_time() > horizon_s:
                break
            _, kind, args = self.ev.pop()
            getattr(self, "_on_" + kind)(*args)
            if kind != "epoch" and self.n_done == len(self.sessions):
                break
        return self.sessions

    def _run_until_done(self, sid: str, horizon_s: float = INF) -> None:
        """Advance the clock until session ``sid`` finishes (the
        ``WorkflowHandle.result`` path) — other sessions keep
        interleaving normally."""
        ses = self.sessions[sid]
        while ses.finished_at < 0 and self.ev:
            if self.ev.peek_time() > horizon_s:
                break
            _, kind, args = self.ev.pop()
            getattr(self, "_on_" + kind)(*args)

    # -- step lifecycle -------------------------------------------------
    def _on_arrival(self, sid: str) -> None:
        ses = self.sessions[sid]
        inst = ses.inst
        counts = inst.nominal_rt_counts()
        tools = [t for _, _, t in counts]
        work_est = sum(np_ / self.perf.prefill_tokens_per_s
                       + n * self.perf.decode_round_s
                       for np_, n, _ in counts)
        aeg = inst.declared_aeg()
        step_cost = work_est / max(len(counts), 1) \
            if aeg is not None else 0.0
        self.co.register_task(sid, inst.tenant, tools,
                              deadline=self.ev.now + 3600.0,
                              work_est_s=work_est, now=self.ev.now,
                              prefix_tokens=0, aeg=aeg,
                              step_cost_s=step_cost,
                              entry_node=inst.path[0] if inst.path else 0)
        self._begin_step(sid)

    def _begin_step(self, sid: str) -> None:
        ses = self.sessions[sid]
        prompt = ses.inst.rt_step(ses.step_idx)[0]
        ses.ctx.extend(int(t) for t in prompt)
        w = self.co.route(sid, self.loads(), self.ev.now)
        self._dispatch_to(sid, w)

    def _dispatch_to(self, sid: str, w: int) -> None:
        if self._resident[w] < self.n_slots and not self.queues[w]:
            self._admit(sid, w)
        else:
            self._enqueue(sid, w)

    def _enqueue(self, sid: str, w: int) -> None:
        ses = self.sessions[sid]
        ses.state = "queued"
        ses.engine = w
        prio = -self.co.afs.priority(ses.inst.tenant)
        if not self.queues[w]:           # empty -> nonempty transition
            self._nonempty.add(w)
            self.co.on_worker_busy(w)
        self.queues[w].push(prio, self.ev.now, _QueueTicket(sid))
        self._load_delta(w, 1)

    def _queue_pop(self, w: int) -> Optional[SessionState]:
        ticket = self.queues[w].pop()
        if ticket is not None:
            self._load_delta(w, -1)
            if not self.queues[w]:
                self._queue_went_empty(w)
            return self.sessions[ticket.session_id]
        return None

    def _queue_remove(self, w: int, sid: str) -> Optional[SessionState]:
        ticket = self.queues[w].remove(sid)
        if ticket is not None:
            self._load_delta(w, -1)
            if not self.queues[w]:
                self._queue_went_empty(w)
            return self.sessions[sid]
        return None

    def _queue_went_empty(self, w: int) -> None:
        self._nonempty.discard(w)
        self.co.on_worker_idle(w, self.ev.now)

    def _drain_queue(self, w: int) -> None:
        while self.queues[w] and self._resident[w] < self.n_slots:
            ses = self._queue_pop(w)
            if ses is not None:
                self._admit(ses.session_id, w)

    def _admit(self, sid: str, w: int) -> None:
        """Slot admission: resolve cache hit vs regeneration against both
        the coordinator's policy view and the engine's real block table,
        then schedule the decode-phase join for when the (virtual)
        prefill completes.  The REAL prefill + slot write happen at that
        event — a written slot is immediately part of every decode round,
        so no round can touch a half-admitted session's cache
        (``decode_step`` writes KV for every batch row)."""
        ses = self.sessions[sid]
        eng = self.engines[w]
        ctx_len = len(ses.ctx)
        hit, pf_tokens, bg_tokens = self.co.on_step_start(
            sid, w, float(ctx_len), self.ev.now)
        real_hit = hit and eng.has_cache(sid)
        if hit and not real_hit:
            # policy says cached but the blocks are gone (force-freed
            # making room for a park): heal the metadata
            self.co.drop_entry(sid, w, count_eviction=False)
        if not hit and eng.has_cache(sid):
            eng.evict_session(sid)           # policy evicted it earlier
        if real_hit:
            virt_prefill = float(pf_tokens)
        else:
            ses.regen_tokens += ctx_len
            # a correct, warm speculative prefetch regenerated
            # ``bg_tokens`` during the tool gap — off the critical path
            virt_prefill = float(ctx_len) - float(bg_tokens)
        ses.state = "prefill"
        ses.engine = w
        ses.slot = -1                        # assigned at prefill_done
        ses.cached_hit = real_hit
        self._resident[w] += 1
        self._load_delta(w, 1)
        done = self.ev.now + max(0.0, virt_prefill) \
            / self.perf.prefill_tokens_per_s
        self.ev.schedule(done, "prefill_done", (sid,))

    def _on_prefill_done(self, sid: str) -> None:
        ses = self.sessions[sid]
        w = ses.engine
        slot = self.engines[w].start_session(
            sid, np.asarray(ses.ctx, np.int32), cached_hit=ses.cached_hit)
        if slot is None:                     # _resident bounds admissions
            raise RuntimeError(f"engine {w} slot accounting drifted")
        ses.slot = slot
        ses.state = "decode"
        ses.remaining = int(ses.inst.rt_step(ses.step_idx)[1])
        ses.next_token = int(ses.ctx[-1])
        ses.step_outputs.append([])
        self._active[w].add(sid)
        if not self._round_live[w]:
            self._round_live[w] = True
            self.ev.schedule(self.ev.now + self.perf.decode_round_s,
                             "round", (w,))

    def _on_round(self, w: int) -> None:
        """One continuous-batching decode round: every decode-phase
        session on engine ``w`` advances one token in a single batched
        forward pass.  Sessions whose step completed leave the batch
        (their slot frees, the queue drains into it) while the rest keep
        decoding — no barrier between sessions."""
        active = sorted(self._active[w],
                        key=lambda s: self.sessions[s].slot)
        if not active:
            self._round_live[w] = False
            return
        eng = self.engines[w]
        slot_tokens = {self.sessions[s].slot: self.sessions[s].next_token
                       for s in active}
        out = eng.decode(slot_tokens, n_steps=1)
        finished: List[str] = []
        for sid in active:
            ses = self.sessions[sid]
            tok = int(out[ses.slot][0])
            ses.ctx.append(tok)
            ses.step_outputs[-1].append(tok)
            ses.next_token = tok
            ses.remaining -= 1
            if ses.remaining == 0:
                finished.append(sid)
        for sid in finished:
            self._active[w].discard(sid)
            self._finish_decode(sid)
        if self._active[w]:
            self.ev.schedule(self.ev.now + self.perf.decode_round_s,
                             "round", (w,))
        else:
            self._round_live[w] = False
        self._drain_queue(w)

    def _finish_decode(self, sid: str) -> None:
        ses = self.sessions[sid]
        w = ses.engine
        eng = self.engines[w]
        prompt, n_out, tool, gap_s = ses.inst.rt_step(ses.step_idx)
        self.co.afs.note_progress(
            sid, len(prompt) / self.perf.prefill_tokens_per_s
            + n_out * self.perf.decode_round_s)
        # park boundary: resolve the taken edge / dynamic callback (the
        # callback sees the real decoded token ids).  Deterministic on
        # the virtual clock; memoized per step index.
        if ses.inst.resolve_next(ses.step_idx,
                                 outputs=ses.step_outputs) is None:
            self._finish_task(sid)
            return
        ctx_len = len(ses.ctx)
        entry_bytes = ctx_len * self.kv_bytes_per_token
        evicted = self.co.on_step_end(
            sid, w, float(ctx_len), entry_bytes, tool, self.ev.now,
            next_node=ses.inst.next_node_hint(ses.step_idx + 1))
        # event-driven WA-LRU reconciliation: only the victims the policy
        # actually picked lose their real blocks (the old server rescanned
        # every cached session per step)
        for evd in evicted:
            eng.evict_session(evd.session_id)
        if self.co.pools[w].contains(sid):
            if not self._park_real(sid, w):
                self.co.drop_entry(sid, w, count_eviction=False)
                eng.release_session(sid)
        else:
            eng.release_session(sid)
        ses.slot = -1
        self._resident[w] -= 1
        self._load_delta(w, -1)
        ses.state = "tool"
        job = self.co.prefetcher.inflight.get(sid)
        if job is not None and job.issued_at == self.ev.now:
            self.ev.schedule(job.ready_at, "prefetch", (sid, w))
        self.ev.schedule(self.ev.now + float(gap_s), "tool_done", (sid,))

    def _park_real(self, sid: str, w: int) -> bool:
        """Move the session's slot KV into the engine pool, evicting
        WA-LRU victims (policy + real blocks together) until it fits."""
        eng = self.engines[w]
        n = len(self.sessions[sid].ctx)
        while not eng.pool.can_fit(n):
            victim = self.co.pools[w].select_victim(self.ev.now)
            if victim is None or victim.session_id == sid:
                return False
            self.co.drop_entry(victim.session_id, w)
            eng.evict_session(victim.session_id)
        return eng.park_session(sid)

    def _finish_task(self, sid: str) -> None:
        ses = self.sessions[sid]
        w = ses.engine
        self.engines[w].release_session(sid)
        ses.slot = -1
        self._resident[w] -= 1
        self._load_delta(w, -1)
        sites = self.co.cached_sites(sid)
        self.co.task_finished(sid, self.ev.now)
        for site in sites:                   # replicas included
            self.engines[site].evict_session(sid)
        ses.state = "done"
        ses.finished_at = self.ev.now
        self.n_done += 1
        self._drain_queue(w)

    def _on_tool_done(self, sid: str) -> None:
        ses = self.sessions[sid]
        if ses.state != "tool":
            return
        prompt, _, tool, gap_s = ses.inst.rt_step(ses.step_idx)
        self.co.on_tool_done(sid, tool, float(gap_s), float(len(prompt)),
                             self.ev.now)
        ses.step_idx += 1
        self._begin_step(sid)

    # -- epoch tick: AFS shares + work stealing -------------------------
    def _on_epoch(self) -> None:
        decision, _ = self.co.epoch_tick(
            self.ev.now, self.loads(), self._queue_views,
            alive=self._alive, victim_candidates=self._nonempty,
            scan_queues=False)
        if decision is not None and self.co.stealer.accept(
                decision, len(self.queues[decision.victim]), self.ev.now):
            ses = self._queue_remove(decision.victim, decision.session_id)
            if ses is not None:
                ses.state = "migrating"
                self.migrating[ses.session_id] = (decision.victim,
                                                  decision.thief)
                self.migrations += 1
                mig = self.perf.sample_migration_s(self.rng)
                self.ev.schedule(self.ev.now + mig, "migr_done",
                                 (ses.session_id, decision.victim,
                                  decision.thief))
        if self.n_done < len(self.sessions):
            self.ev.schedule(self.ev.now + self.perf.epoch_s, "epoch")
        else:
            self._epoch_live = False

    def _copy_kv(self, sid: str, src: int, dst: int) -> bool:
        """Real pool-to-pool block copy (export, make room, import)."""
        kv = self.engines[src].export_kv(sid)
        if kv is None:
            return False
        k, v, n = kv
        dst_eng = self.engines[dst]
        while not dst_eng.pool.can_fit(n):
            victim = self.co.pools[dst].select_victim(self.ev.now)
            if victim is None or victim.session_id == sid:
                return False
            self.co.drop_entry(victim.session_id, dst)
            dst_eng.evict_session(victim.session_id)
        return dst_eng.import_kv(sid, k, v, n)

    def _on_migr_done(self, sid: str, src: int, dst: int) -> None:
        """A stolen session's KV transfer window elapsed: move the real
        blocks and the cache entry (TTL state travels with it, §3.1),
        then admit on the thief."""
        if self.migrating.pop(sid, None) is None:
            return
        ses = self.sessions[sid]
        if ses.state != "migrating":
            return
        if self.engines[src].has_cache(sid):
            if self._copy_kv(sid, src, dst):
                self.engines[src].evict_session(sid)
                _, evicted = self.co.migrate_session(sid, src, dst,
                                                     self.ev.now)
                for evd in evicted:
                    self.engines[dst].evict_session(evd.session_id)
                if not self.co.pools[dst].contains(sid):
                    # metadata didn't land (only pinned victims at the
                    # thief): the imported blocks must not outlive it
                    self.engines[dst].evict_session(sid)
            # else: no room at the thief — the entry (and its blocks)
            # stay home; this step runs on the thief and regenerates
            # (§3.1), later steps may still resume the intact home copy
        else:
            self.co.router.set_home(sid, dst)
        self._dispatch_to(sid, dst)

    def _on_prefetch(self, sid: str, src: int) -> None:
        """Speculative prefetch landing (§4.3): the bandwidth-delayed
        copy window elapsed mid-tool-gap.  If the home engine looks too
        loaded to take the resume (Eq. 7 would divert), replicate the
        parked KV to the likely overflow target so the diverted resume
        still hits cache."""
        ses = self.sessions.get(sid)
        if ses is None or ses.state != "tool":
            return
        if sid not in self.co.prefetcher.inflight:
            return                            # superseded or resolved
        loads = self.loads()
        if float(loads[src]) < self.co.cfg.theta:
            return                            # home will take the resume
        masked = loads.astype(float).copy()
        masked[src] = INF
        dst = int(masked.argmin())
        if dst == src or not self.engines[src].has_cache(sid):
            return
        inserted, evicted = self.co.replicate_entry(sid, src, dst,
                                                    self.ev.now)
        for evd in evicted:
            self.engines[dst].evict_session(evd.session_id)
        if not inserted:
            return
        if self._copy_kv(sid, src, dst):
            self.prefetch_copies += 1
            self.prefetch_copy_bytes += \
                len(ses.ctx) * self.kv_bytes_per_token
        else:
            self.co.drop_entry(sid, dst, count_eviction=False)

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        return {
            "prefill_tokens": sum(e.prefill_tokens for e in self.engines),
            "regen_tokens": sum(e.regen_tokens for e in self.engines),
            "decode_steps": sum(e.decode_steps for e in self.engines),
            "coordinator_hits": self.co.cache_hits,
            "coordinator_misses": self.co.cache_misses,
        }

    def summarize(self) -> dict:
        """Deterministic run summary (the cross-process byte-identity
        contract covers this dict's ``repr``)."""
        done = [s for s in self.sessions.values() if s.finished_at >= 0]
        tcts = sorted(s.tct for s in done)
        n = len(tcts)
        st = self.stats()
        return {
            "n_sessions": len(self.sessions),
            "n_done": n,
            "tct_mean": float(sum(tcts) / n) if n else 0.0,
            "tct_p50": float(tcts[n // 2]) if n else 0.0,
            "tct_p99": float(tcts[min(n - 1, int(0.99 * n))]) if n else 0.0,
            "makespan": float(max((s.finished_at for s in done),
                                  default=0.0)),
            "prefill_tokens": int(st["prefill_tokens"]),
            "regen_tokens": int(st["regen_tokens"]),
            "decode_rounds": int(st["decode_steps"]),
            "decoded_tokens": int(sum(len(o) for s in self.sessions.values()
                                      for o in s.step_outputs)),
            "cache_hits": int(self.co.cache_hits),
            "cache_misses": int(self.co.cache_misses),
            "steals": int(self.co.stealer.steals),
            "migrations": int(self.migrations),
            "prefetch_issued": int(self.co.prefetcher.issued),
            "prefetch_correct": int(self.co.prefetcher.correct),
            "prefetch_copies": int(self.prefetch_copies),
            "prefetch_wasted_bytes": float(self.co.prefetcher.wasted_bytes),
        }

    # -- invariants -----------------------------------------------------
    def check_conservation(self) -> None:
        """Post-run lifecycle invariants: every submitted session
        finished, no session stuck queued/migrating, every engine's
        slots and pool blocks returned to free, the incremental load /
        nonempty indices agree with ground truth, and the coordinator's
        pool metadata mirrors the real block tables.  Raises listing
        every violation."""
        bad: List[str] = []
        unfinished = sorted(s for s, st in self.sessions.items()
                            if st.finished_at < 0)
        if unfinished:
            bad.append(f"sessions never finished: {unfinished[:5]}")
        if self.n_done != len(self.sessions):
            bad.append(f"n_done={self.n_done} != {len(self.sessions)}")
        if self.migrating:
            bad.append(f"migrations in limbo: {sorted(self.migrating)[:5]}")
        for w, eng in enumerate(self.engines):
            if self.queues[w]:
                bad.append(f"engine {w} queue not drained")
            if self._active[w]:
                bad.append(f"engine {w} decode set not empty")
            if eng.used_slots() != 0:
                bad.append(f"engine {w} leaked {eng.used_slots()} slots")
            if self._resident[w] != 0:
                bad.append(f"engine {w} resident count "
                           f"{self._resident[w]} != 0")
            if self._loadnum[w] != 0:
                bad.append(f"engine {w} load index drifted: "
                           f"{self._loadnum[w]}")
            if (w in self._nonempty):
                bad.append(f"engine {w} nonempty index stale")
            if eng.pool.tables:
                bad.append(f"engine {w} leaked blocks for "
                           f"{sorted(eng.pool.tables)[:5]}")
            if len(set(eng.pool.free)) != eng.pool.num_blocks:
                bad.append(f"engine {w} free list corrupt")
            if self.co.pools[w].entries:
                bad.append(f"engine {w} pool metadata not empty")
        if abs(self.co.pools_used) > 1e-6:
            bad.append(f"pools_used={self.co.pools_used}")
        if bad:
            raise RuntimeError("runtime conservation violated: "
                               + "; ".join(bad))

    def verify_pool_mirrors(self) -> None:
        """Mid-run cross-check: every engine's real parked sessions must
        be a subset of the coordinator's pool entries (a metadata entry
        may transiently outlive its blocks during a resume, never the
        reverse)."""
        for w, eng in enumerate(self.engines):
            extra = set(eng.pool.tables) - set(self.co.pools[w].entries)
            if extra:
                raise RuntimeError(
                    f"engine {w} holds blocks with no pool entry: "
                    f"{sorted(extra)[:5]}")
