"""Event-driven concurrent serving runtime: whole agent workflows
interleaved on real engines (the paper's serving layer, §3-§6).

``ServingRuntime`` executes MANY concurrent multi-step agent sessions
across multiple real ``Engine``s (actual jitted JAX forward passes —
tiny zoo models on CPU, same code on TPU pods) under the same
``GlobalCoordinator`` that drives the discrete-event simulator:

  * **AFS-priority admission** — when an engine's decode slots are full,
    sessions wait in a per-engine ``SessionQueue`` ordered by tenant
    Agent-Fair-Share (§6), not FIFO.
  * **Continuous batching** — all decode-phase sessions co-resident on
    an engine advance together, one batched ``decode_step`` per virtual
    decode round; sessions join/leave the batch mid-flight as prefills
    complete and steps finish (per-slot rows are independent, so
    interleaving is token-for-token identical to serial execution).
  * **Park-on-tool with TTL** — a session entering a tool call parks its
    slot KV into the engine's paged pool; the coordinator stamps the
    entry with a tool-aware TTL (§4.2) and WA-LRU (§4.1) decides who
    survives memory pressure.  Eviction decisions propagate to the real
    block tables through an event-driven callback (the evicted-entry
    list from ``on_step_end``), never a per-step scan of all sessions.
  * **Resume with delta-only prefill** — a returning session that still
    holds pool KV prefills only the new tokens (tool observation + next
    user turn); a victim of eviction regenerates its whole context, the
    paper's central cost.
  * **Affinity routing + work stealing** — Eq. 7 routes a resuming
    session to its KV home unless overloaded; the 100 ms epoch tick
    (ported from the simulator's O(changes) incremental form: integer
    load vector, indexed idle set, nonempty-queue victim index) lets an
    idle engine steal a queued session, migrating its parked KV blocks
    pool-to-pool.
  * **Speculative prefetch with real copies** — during a tool gap the
    prefetcher (§4.3) predicts the next step; if the home engine looks
    overloaded for the resume, the parked KV is *replicated* to the
    likely overflow target so the resume still hits cache.  Copies are
    real block transfers that overlap the (virtual-time) tool gap.
  * **Disaggregated prefill/decode pools** (opt-in via
    ``SAGAConfig.disaggregate``; ``repro.serving.disagg``) — engines
    split into prefill/decode roles: new-session and tool-resume
    prefills run on the prefill pool (speculatively, overlapping the
    tool gap) and the staged KV hands off block-granularly to the
    Eq. 7-routed decode engine, so decode rounds run prefill-free.

Fault tolerance and preemption (the simulator's lifecycle, on real
engines):

  * **Engine fault injection** — ``cluster.faults`` plans (chaos /
    straggler / preemption storms; ("fail"|"recover"|"scale_up"|"slow"|
    "heal", worker) events) drive the runtime through virtual-time
    events.  Every admitted step lives in an attempt-stamped in-flight
    registry; a ``fail`` cancels the dead engine's attempts (stale
    ``prefill_done``/``round`` events are dropped by attempt/generation
    stamps), reclaims slot KV, releases pool blocks, refunds partially-
    charged AFS work, and re-dispatches each session to a live engine,
    which regenerates from its last parked prefix (§3.1).  If every
    engine is down, sessions park in an orphan buffer until a recover /
    scale-up.
  * **AFS preemption of running decodes** (§6.2) — admission ordering
    alone cannot enforce Theorem 2's bounded deviation once a victim
    holds a slot, so when a queued session's fair-share deficit against
    the lowest-priority running decode exceeds ``preempt_deficit`` for
    longer than ``preempt_block_s`` (hysteresis), the victim is parked
    at the next batched-decode round boundary: slot KV exported to the
    pool with a TTL entry, the starved session admitted, and the victim
    later resumed with a delta-only prefill mid-step — token-for-token
    identical to an unpreempted run while the parked copy survives.

Time is virtual (``repro.serving.events.EventLoop``): tool gaps cost
nothing on the wall clock, and identical-seed runs produce byte-identical
``summarize()`` output even across processes with different
``PYTHONHASHSEED`` — the same determinism contract as the simulator,
preserved under fault plans and preemption.  Real compute (prefill,
decode, KV copies) runs eagerly as its event is processed.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import os
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import ROOT, as_tracer
from repro.serving.disagg import (HandoffJob, PrefillScheduler,
                                  ROLE_DECODE, ROLE_PREFILL, ROLE_UNIFIED,
                                  default_roles)
from repro.serving.engine import Engine
from repro.serving.events import EventLoop, SessionQueue, _RuntimeQueueView
from repro.serving.sanitizer import RuntimeSanitizer
from repro.workflow.program import WorkflowInstance, as_instance

INF = float("inf")


@dataclasses.dataclass
class AgentRequest:
    """One agent task: steps of (new prompt tokens, n decode tokens,
    tool type, tool gap seconds).  ``arrival_s`` places the request on
    the runtime's virtual clock (0 = immediately).

    Backward-compat adapter format: ``submit`` compiles it to a
    scripted ``repro.workflow.AgentProgram`` (byte-identical execution);
    graph / dynamic programs are submitted directly."""
    session_id: str
    tenant: str
    steps: List[Tuple[List[int], int, str, float]]
    arrival_s: float = 0.0


@dataclasses.dataclass
class RuntimePerf:
    """Virtual-time service model (per engine).  Real compute runs
    eagerly; these rates only advance the deterministic clock, mirroring
    ``cluster.perf.PerfModel`` at serving granularity."""
    prefill_tokens_per_s: float = 8000.0
    decode_round_s: float = 0.025        # one batched decode step
    epoch_s: float = 0.100               # coordinator tick (§6)
    migration_mean_s: float = 0.230      # Llumnix-style KV move (Table 7)
    migration_p95_s: float = 0.890
    # prefill/decode interference: each in-flight prefill on an engine
    # stretches its concurrent batched decode rounds by this fraction
    # (chunked-prefill contention — the cost disaggregation removes).
    # 0.0 keeps every committed fingerprint byte-identical.
    prefill_round_interference: float = 0.0
    # the symmetric half of chunked-prefill contention: a prefill
    # admitted to an engine already running decode rounds is itself
    # chunked into the round schedule, stretching by this fraction per
    # active decode slot.  Dedicated prefill engines have no decode
    # slots, so the disaggregated pool runs prefill at full rate —
    # the capacity argument for disaggregation.  Default 0.0 keeps
    # every committed fingerprint byte-identical.
    prefill_decode_interference: float = 0.0
    # disaggregated handoff transport (prefill -> decode pool): a
    # deterministic bandwidth + latency-floor window, like migration but
    # RNG-free so disagg summaries stay byte-identical across processes
    handoff_bytes_per_s: float = 8.0e9
    handoff_latency_s: float = 0.002

    def sample_migration_s(self, rng: random.Random) -> float:
        mu = math.log(self.migration_mean_s) - 0.3
        sigma = math.log(self.migration_p95_s /
                         self.migration_mean_s) / 1.645 + 0.3
        return min(math.exp(mu + sigma * rng.gauss(0, 1)), 5.0)


@dataclasses.dataclass
class SessionState:
    """Mutable runtime record for one submitted agent session."""
    inst: WorkflowInstance
    session_id: str
    arrival: float
    ctx: List[int] = dataclasses.field(default_factory=list)
    step_idx: int = 0
    engine: int = -1                 # engine owning the current step
    slot: int = -1
    remaining: int = 0               # decode tokens left this step
    next_token: int = 0
    state: str = "new"               # queued|prefill|decode|tool|migrating|done
    cached_hit: bool = False         # admission's hit verdict (pinned)
    regen_tokens: int = 0
    finished_at: float = -1.0
    step_outputs: List[List[int]] = dataclasses.field(default_factory=list)
    # fault/preemption lifecycle: the ctx length at step start (prompt
    # included) so a cancelled attempt can roll the decoded tail back,
    # whether the session is mid-step (preempted: remaining survives the
    # park), and the AFS progress already charged for the current step
    # (refunded if a fault forces a full retry)
    attempt: int = -1
    step_start_len: int = 0
    mid_step: bool = False
    work_charged: float = 0.0
    # disaggregated handoff rendezvous (serving/disagg.py): the step's
    # prefilled KV landed on decode engine ``handoff_dst`` and admission
    # there needs zero critical-path prefill; ``handoff_lost`` marks a
    # fault/capacity casualty that must regenerate on the decode engine
    # WITHOUT re-counting the step's hit/miss verdict
    handoff_ready: bool = False
    handoff_dst: int = -1
    handoff_lost: bool = False
    # front-end extensions (serving/frontend, SagaClient): a one-shot
    # engine preference consumed on the session's FIRST dispatch only
    # (later steps follow Eq. 7 affinity as usual), and an explicit SLO
    # deadline offset registered with the coordinator.  Both default
    # off, so virtual-time byte-pins never see them.
    route_hint: Optional[int] = None
    slo_s: Optional[float] = None

    @property
    def tct(self) -> float:
        return self.finished_at - self.arrival


@dataclasses.dataclass
class _QueueTicket:
    """One enqueue = one ticket.  The tombstone flag lives HERE, not on
    the shared SessionState: a stolen session that re-enqueues elsewhere
    must not resurrect its lazily-deleted entry in the victim's heap."""
    session_id: str
    cancelled: bool = False


class WorkflowHandle:
    """Client-facing handle for one submitted workflow (returned by
    ``ServingRuntime.submit``): inspect ``status`` / ``step_outputs`` /
    ``path`` while the runtime interleaves, or block on ``result()``."""

    def __init__(self, runtime: "ServingRuntime", ses: "SessionState"):
        self._rt = runtime
        self._ses = ses

    @property
    def session_id(self) -> str:
        return self._ses.session_id

    @property
    def status(self) -> str:
        """new|queued|prefill|decode|tool|migrating|done"""
        return self._ses.state

    @property
    def done(self) -> bool:
        return self._ses.finished_at >= 0

    @property
    def step_outputs(self) -> List[List[int]]:
        """Decoded token ids per executed step (so far)."""
        return [list(o) for o in self._ses.step_outputs]

    @property
    def path(self) -> List[int]:
        """AEG node ids of the executed steps (so far) — shows which
        branches / retry edges the workflow actually took."""
        return list(self._ses.inst.path)

    @property
    def truncated(self) -> bool:
        """True when the engine's context cap ended the workflow before
        its graph/callback did — the taken path is then a strict prefix
        of what an unconstrained substrate would execute."""
        return self._ses.inst.truncated

    @property
    def tct(self) -> float:
        if not self.done:
            raise RuntimeError(f"workflow {self.session_id} not finished")
        return self._ses.tct

    def result(self, horizon_s: float = INF) -> List[List[int]]:
        """Drive the runtime's virtual clock until this workflow
        finishes, then return its per-step decoded token ids.  Other
        concurrent sessions keep interleaving while we wait."""
        if not self.done:
            self._rt._run_until_done(self._ses.session_id, horizon_s)
        if not self.done:
            raise RuntimeError(
                f"workflow {self.session_id} did not finish "
                f"(state={self.status})")
        return self.step_outputs


class ServingRuntime:
    """Deterministic virtual-time event loop over ``n_workers`` real
    engines.  ``submit`` accepts ``AgentProgram``s (scripted / graph /
    dynamic) and legacy ``AgentRequest``s, then ``run`` to completion;
    the ``MultiWorkerServer`` wraps this serially for the legacy API."""

    def __init__(self, cfg: ModelConfig, params, *, n_workers: int = 2,
                 saga: Optional[SAGAConfig] = None, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 48,
                 perf: Optional[RuntimePerf] = None, seed: int = 0,
                 engines: Optional[List[Engine]] = None,
                 fault_plan: Optional[Sequence[Tuple[float, str,
                                                     int]]] = None,
                 straggler_slowdown: float = 4.0,
                 sanitize: Optional[bool] = None,
                 paged: bool = True,
                 roles: Optional[Sequence[str]] = None,
                 trace=None):
        self.cfg = cfg
        self.params = params
        self.engines = engines if engines is not None else [
            Engine(cfg, params, n_slots=n_slots, max_len=max_len,
                   pool_blocks=pool_blocks, paged=paged)
            for _ in range(n_workers)]
        self.n_workers = len(self.engines)
        self.n_slots = self.engines[0].n_slots
        pool = self.engines[0].pool
        self.kv_bytes_per_token = pool.bytes_per_block / pool.block
        pool_bytes = pool.num_blocks * pool.bytes_per_block
        self.co = GlobalCoordinator(saga or SAGAConfig(), self.n_workers,
                                    pool_bytes)
        # disaggregated prefill/decode pools (serving/disagg.py):
        # opt-in via SAGAConfig.disaggregate — the unified pool stays
        # the default so every committed fingerprint is unchanged
        self.disagg = bool(self.co.cfg.disaggregate)
        if self.disagg:
            if roles is None:
                roles = default_roles(self.n_workers)
            assert all(self.engines[w].paged
                       for w in range(self.n_workers)
                       if roles[w] == ROLE_PREFILL), \
                "disaggregation needs paged engines (block handoff)"
        self.roles: List[str] = list(roles) if roles is not None \
            else [ROLE_UNIFIED] * self.n_workers
        # role/disagg coherence lives with every other config check in
        # SAGAConfig.validate (the GlobalCoordinator ctor above already
        # validated the role-free invariants)
        self.co.cfg.validate(roles=self.roles, n_workers=self.n_workers)
        self._prefill_ids = [w for w, r in enumerate(self.roles)
                             if r == ROLE_PREFILL]
        for w in self._prefill_ids:
            self.co.set_worker_role(w, ROLE_PREFILL)
        self._pf = PrefillScheduler(self._prefill_ids)
        self.perf = perf or RuntimePerf()
        self.perf = dataclasses.replace(self.perf,
                                        epoch_s=self.co.cfg.epoch_s)
        self.rng = random.Random(seed)
        self.ev = EventLoop()
        self.sessions: Dict[str, SessionState] = {}
        self.n_done = 0
        # per-engine scheduling state (incremental epoch-tick structures
        # ported from the simulator: integer load counts, nonempty-queue
        # victim index, persistent stealer queue views)
        self.queues: List[SessionQueue] = [SessionQueue()
                                           for _ in range(self.n_workers)]
        self._queue_views = [_RuntimeQueueView(lambda w=w: self.queues[w])
                             for w in range(self.n_workers)]
        self._active: List[set] = [set() for _ in range(self.n_workers)]
        self._resident = [0] * self.n_workers    # prefill + decode sessions
        self._round_live = [False] * self.n_workers
        self._loadnum = np.zeros(self.n_workers, dtype=np.int64)
        self._nonempty: set = set()
        self._alive = [True] * self.n_workers
        self._epoch_live = False
        self.migrating: Dict[str, Tuple[int, int]] = {}
        # fault-correct lifecycle (the simulator's registry, runtime
        # twin): sid -> (engine, attempt) for every admitted step; the
        # matching prefill_done event carries the attempt and a mismatch
        # at delivery means a fault cancelled the step in the meantime.
        # Round events are generation-stamped per engine the same way.
        self.inflight: Dict[str, Tuple[int, int]] = {}
        self._attempt = itertools.count()
        self._gen = [0] * self.n_workers
        self._slow: Dict[int, float] = {}
        self.straggler_slowdown = straggler_slowdown
        self._orphans: List[str] = []
        self.fault_plan = list(fault_plan or [])
        for t, kind, w in self.fault_plan:
            self.ev.schedule(t, "fault", (kind, w))
        # AFS preemption of running decodes (§6.2): decided at the epoch
        # tick, executed at the next round boundary.  Thm. 2 deviation is
        # measured against constant workload-proportional fair rates
        # (mu_i ∝ W_i, the lyapunov_v convention), so per-tenant
        # submitted work is accumulated at registration.
        self._preempt_pending: Dict[int, str] = {}
        self._last_preempt = [-INF] * self.n_workers
        self._tenant_workload: Dict[str, float] = {}
        # per-event conservation audit (repro.serving.sanitizer):
        # read-only, so summaries are byte-identical with it on or off.
        # The env gate is a debug opt-in that never alters scheduling.
        if sanitize is None:
            # sagalint: ok(det-env) sanitize toggles assertions only, never a scheduling decision — replay is unaffected
            sanitize = os.environ.get("SAGA_SANITIZE", "") not in ("",
                                                                   "0")
        self._san = RuntimeSanitizer(self) if sanitize else None
        # virtual-time span tracer + metrics registry (repro.obs):
        # read-only like the sanitizer — a traced run's summarize() is
        # byte-identical to the untraced run, and the trace bytes are
        # byte-identical across PYTHONHASHSEED (docs/OBSERVABILITY.md).
        # ``trace`` accepts True (fresh tracer) or a Tracer instance.
        if trace is None:
            # sagalint: ok(det-env) trace toggles recording only, never a scheduling decision — replay is unaffected
            trace = os.environ.get("SAGA_TRACE", "") not in ("", "0")
        self.tracer = as_tracer(trace)
        self.obs_metrics = MetricsRegistry() if self.tracer is not None \
            else None
        # per-session open-span ids keyed by role ("session" / "step" /
        # "queue" / "phase" / "gap" / "migr"); plain string keys, never
        # id() — part of the determinism contract
        self._tr_open: Dict[str, Dict[str, int]] = {}
        # metric sampling is decimated to every 10th epoch tick (1 s of
        # virtual time) with per-engine gauge handles cached — same
        # rationale as the simulator (table7's trace-overhead row)
        self._obs_tick = 0
        self._obs_engine_g: List[tuple] = []
        # instrumentation
        self.migrations = 0
        self.prefetch_copies = 0
        self.prefetch_copy_bytes = 0.0
        self.faults_injected = 0
        self.cancelled_attempts = 0
        self.preempted = 0
        self.afs_dev_max = 0.0
        # disaggregated-handoff instrumentation (stats / summarize; the
        # obs counters kv_handoff_bytes / handoff_count mirror these on
        # traced runs)
        self.handoffs = 0
        self.kv_handoff_bytes = 0
        self.handoffs_cancelled = 0
        self.prefetch_role_rejected = 0
        for w in range(self.n_workers):
            self.co.on_worker_idle(w, 0.0)

    # -- load reporting (shared with MultiWorkerServer._loads) ----------
    def loads(self) -> np.ndarray:
        """Queue-depth + slot-occupancy load vector in slot units: one
        C-level division of the incrementally-maintained integer counts
        (replaces the old binary free-slot hack)."""
        return self._loadnum / self.n_slots

    def _load_delta(self, w: int, d: int) -> None:
        self._loadnum[w] += d

    # -- tracing helpers (no-ops when tracing is off) -------------------
    def _tr_begin(self, sid: str, key: str, name: str,
                  parent_key: Optional[str] = None,
                  t: Optional[float] = None, **meta) -> None:
        if self.tracer is None:
            return
        o = self._tr_open.setdefault(sid, {})
        parent = o.get(parent_key, ROOT) if parent_key else ROOT
        o[key] = self.tracer.begin(f"session/{sid}", name,
                                   self.ev.now if t is None else t,
                                   parent=parent, **meta)

    def _tr_end(self, sid: str, key: str, status: str = "ok",
                t: Optional[float] = None, **meta) -> None:
        if self.tracer is None:
            return
        o = self._tr_open.get(sid)
        if o is None or key not in o:
            return
        self.tracer.end(o.pop(key), self.ev.now if t is None else t,
                        status=status, **meta)

    def _tr_instant(self, track: str, name: str, **meta) -> None:
        if self.tracer is not None:
            self.tracer.instant(track, name, self.ev.now, **meta)

    # -- submission -----------------------------------------------------
    def submit(self, req,
               arrival: Optional[float] = None, *,
               route_hint: Optional[int] = None,
               slo_s: Optional[float] = None) -> "WorkflowHandle":
        """Submit a workflow: an ``AgentProgram`` (scripted / graph /
        dynamic) or a legacy ``AgentRequest`` (compiled to a scripted
        program, byte-identical execution).  Graph and dynamic programs
        resolve their branches at park/resume boundaries on the virtual
        clock; unspecified prompt ids are realized deterministically
        from the program's seed against this model's vocab.  Returns a
        ``WorkflowHandle`` (``result()`` / ``step_outputs`` /
        ``status``)."""
        inst = as_instance(req, vocab=self.cfg.vocab,
                           max_ctx_tokens=self.engines[0].max_len)
        sid = inst.task_id
        if sid in self.sessions:
            raise ValueError(f"duplicate session id {sid!r}")
        t = max(self.ev.now,
                inst.arrival_s if arrival is None else arrival)
        ses = SessionState(inst, sid, t)
        ses.route_hint = route_hint
        ses.slo_s = slo_s
        self.sessions[sid] = ses
        self.ev.schedule(t, "arrival", (sid,))
        if not self._epoch_live:
            self._epoch_live = True
            self.ev.schedule(self.ev.now + self.perf.epoch_s, "epoch")
        return WorkflowHandle(self, ses)

    def run(self, horizon_s: float = INF) -> Dict[str, SessionState]:
        """Advance the virtual clock until every submitted session has
        finished (or ``horizon_s``).  Resumable: later submits + runs
        continue on the same clock."""
        while self.ev:
            if self.ev.peek_time() > horizon_s:
                break
            t, kind, args = self.ev.pop()
            getattr(self, "_on_" + kind)(*args)
            if self._san is not None:
                self._san.after_event(t, kind, args)
            if kind != "epoch" and self.n_done == len(self.sessions):
                break
        return self.sessions

    def _run_until_done(self, sid: str, horizon_s: float = INF) -> None:
        """Advance the clock until session ``sid`` finishes (the
        ``WorkflowHandle.result`` path) — other sessions keep
        interleaving normally."""
        ses = self.sessions[sid]
        while ses.finished_at < 0 and self.ev:
            if self.ev.peek_time() > horizon_s:
                break
            t, kind, args = self.ev.pop()
            getattr(self, "_on_" + kind)(*args)
            if self._san is not None:
                self._san.after_event(t, kind, args)

    # -- step lifecycle -------------------------------------------------
    def _on_arrival(self, sid: str) -> None:
        ses = self.sessions[sid]
        inst = ses.inst
        counts = inst.nominal_rt_counts()
        tools = [t for _, _, t in counts]
        work_est = sum(np_ / self.perf.prefill_tokens_per_s
                       + n * self.perf.decode_round_s
                       for np_, n, _ in counts)
        aeg = inst.declared_aeg()
        self._tenant_workload[inst.tenant] = \
            self._tenant_workload.get(inst.tenant, 0.0) + work_est
        step_cost = work_est / max(len(counts), 1) \
            if aeg is not None else 0.0
        slo = ses.slo_s if ses.slo_s is not None else 3600.0
        self.co.register_task(sid, inst.tenant, tools,
                              deadline=self.ev.now + slo,
                              work_est_s=work_est, now=self.ev.now,
                              prefix_tokens=0, aeg=aeg,
                              step_cost_s=step_cost,
                              entry_node=inst.path[0] if inst.path else 0)
        self._tr_begin(sid, "session", "session", tenant=inst.tenant)
        self._begin_step(sid)

    def _begin_step(self, sid: str) -> None:
        ses = self.sessions[sid]
        prompt = ses.inst.rt_step(ses.step_idx)[0]
        ses.ctx.extend(int(t) for t in prompt)
        ses.step_start_len = len(ses.ctx)
        self._tr_begin(sid, "step", "step", parent_key="session",
                       step=ses.step_idx)
        self._redispatch(sid)

    def _decode_alive(self) -> bool:
        """Any engine that can hold decode slots up?  (Prefill-role
        engines cannot: a cluster where only they survive is DOWN for
        dispatch purposes — ``route`` masks them, so routing with no
        live decode engine would orphan sessions onto index 0.)"""
        return any(self._alive[w] for w in range(self.n_workers)
                   if self.roles[w] != ROLE_PREFILL)

    def _redispatch(self, sid: str) -> None:
        """Route to a live engine, or park in the orphan buffer when the
        whole cluster is down (readmitted on the next recover/scale-up,
        same as the simulator).  Disaggregated mode first checks the
        handoff rendezvous: landed KV dispatches straight to its decode
        engine, an in-flight job flips to ``waiting`` (the handoff event
        dispatches the session the moment the blocks arrive), and a
        fresh step submits to the prefill pool."""
        ses = self.sessions[sid]
        if not (self._decode_alive() if self.disagg
                else any(self._alive)):
            ses.state = "queued"
            self._orphans.append(sid)
            # the whole cluster is down: the wait still counts as queue
            # time (engine=-1); a pre-existing queue span keeps running
            if self.tracer is not None \
                    and "queue" not in self._tr_open.get(sid, {}):
                self._tr_begin(sid, "queue", "queue_wait",
                               parent_key="step", engine=-1)
            return
        if self.disagg and not ses.mid_step:
            d = ses.handoff_dst
            if ses.handoff_ready and 0 <= d < self.n_workers \
                    and self._alive[d] and self.engines[d].has_cache(sid):
                self._dispatch_to(sid, d)
                return
            job = self._pf.jobs.get(sid)
            if job is not None:
                # tool gap ended before the staged KV landed: wait at
                # the rendezvous (no decode queue slot consumed)
                ses.state = "queued"
                ses.engine = -1
                ses.slot = -1
                if self.tracer is not None \
                        and "queue" not in self._tr_open.get(sid, {}):
                    self._tr_begin(sid, "queue", "queue_wait",
                                   parent_key="step", engine=-1)
                job.waiting = True
                return
            if not ses.handoff_ready and not ses.handoff_lost:
                self._begin_prefill(sid)
                return
            # stale rendezvous (dst died, or the import lost the
            # capacity race): classic decode-pool dispatch below — the
            # target engine regenerates (§3.1); _admit sees the
            # handoff_lost flag and skips re-counting the verdict
            ses.handoff_ready = False
            ses.handoff_dst = -1
            ses.handoff_lost = True
        hint, ses.route_hint = ses.route_hint, None   # one-shot
        if hint is not None and 0 <= hint < self.n_workers \
                and self._alive[hint] \
                and self.roles[hint] != ROLE_PREFILL:
            # the hint bypasses co.route, so record the placement as the
            # session's home or Eq. 7 affinity can never find it on resume
            self.co.router.set_home(sid, hint)
            self._dispatch_to(sid, hint)
            return
        w = self.co.route(sid, self.loads(), self.ev.now)
        self._dispatch_to(sid, w)

    def _readmit_orphans(self) -> None:
        orphans, self._orphans = self._orphans, []
        for sid in orphans:
            self._redispatch(sid)
        if orphans and not self._epoch_live \
                and self.n_done < len(self.sessions):
            self._epoch_live = True
            self.ev.schedule(self.ev.now + self.perf.epoch_s, "epoch")

    def _dispatch_to(self, sid: str, w: int) -> None:
        if not self._alive[w]:
            self._redispatch(sid)
        elif self._resident[w] < self.n_slots and not self.queues[w]:
            self._admit(sid, w)
        else:
            self._enqueue(sid, w)

    def _enqueue(self, sid: str, w: int) -> None:
        ses = self.sessions[sid]
        ses.state = "queued"
        ses.engine = w
        # a re-enqueue (fault drain, preemption) closes the old wait
        # before opening the new one; first enqueues no-op the end
        self._tr_end(sid, "queue", status="requeued")
        self._tr_begin(sid, "queue", "queue_wait", parent_key="step",
                       engine=w)
        prio = -self.co.afs.priority(ses.inst.tenant)
        if not self.queues[w]:           # empty -> nonempty transition
            self._nonempty.add(w)
            self.co.on_worker_busy(w)
        self.queues[w].push(prio, self.ev.now, _QueueTicket(sid))
        self._load_delta(w, 1)
        self.co.afs.note_blocked(sid, self.ev.now)

    def _queue_pop(self, w: int) -> Optional[SessionState]:
        ticket = self.queues[w].pop()
        if ticket is not None:
            self._load_delta(w, -1)
            if not self.queues[w]:
                self._queue_went_empty(w)
            return self.sessions[ticket.session_id]
        return None

    def _queue_remove(self, w: int, sid: str) -> Optional[SessionState]:
        ticket = self.queues[w].remove(sid)
        if ticket is not None:
            self._load_delta(w, -1)
            if not self.queues[w]:
                self._queue_went_empty(w)
            return self.sessions[sid]
        return None

    def _queue_went_empty(self, w: int) -> None:
        self._nonempty.discard(w)
        self.co.on_worker_idle(w, self.ev.now)

    def _drain_queue(self, w: int) -> None:
        if not self._alive[w]:
            return
        while self.queues[w] and self._resident[w] < self.n_slots:
            ses = self._queue_pop(w)
            if ses is not None:
                self._admit(ses.session_id, w)

    def _admit(self, sid: str, w: int) -> None:
        """Slot admission: resolve cache hit vs regeneration against both
        the coordinator's policy view and the engine's real block table,
        then schedule the decode-phase join for when the (virtual)
        prefill completes.  The REAL prefill + slot write happen at that
        event — a written slot is immediately part of every decode round,
        so no round can touch a half-admitted session's cache
        (``decode_step`` writes KV for every batch row)."""
        ses = self.sessions[sid]
        eng = self.engines[w]
        ctx_len = len(ses.ctx)
        self.co.afs.note_unblocked(sid)
        if self.disagg and ses.handoff_ready and eng.has_cache(sid) \
                and int(eng.pool.lens.get(sid, -1)) == ctx_len:
            # the step's KV already landed via the prefill pool: the
            # hit/miss verdict was counted when the handoff job was
            # created, so admission here is a zero-prefill slot join
            # (mark_resident + empty delta)
            real_hit = True
            virt_prefill = 0.0
        elif self.disagg and (ses.handoff_ready or ses.handoff_lost):
            # rendezvous went stale between landing and admission (dst
            # died / import lost the capacity race): regenerate the
            # missing suffix here WITHOUT re-counting the step's verdict
            real_hit = eng.has_cache(sid)
            n_have = int(eng.pool.lens.get(sid, 0)) if real_hit else 0
            if not real_hit:
                ses.regen_tokens += ctx_len
            virt_prefill = float(ctx_len - n_have)
        else:
            hit, pf_tokens, bg_tokens = self.co.on_step_start(
                sid, w, float(ctx_len), self.ev.now)
            real_hit = hit and eng.has_cache(sid)
            if hit and not real_hit:
                # policy says cached but the blocks are gone (force-freed
                # making room for a park): heal the metadata
                self.co.drop_entry(sid, w, count_eviction=False)
            if not hit and eng.has_cache(sid):
                eng.evict_session(sid)       # policy evicted it earlier
            if real_hit:
                virt_prefill = float(pf_tokens)
            else:
                ses.regen_tokens += ctx_len
                # a correct, warm speculative prefetch regenerated
                # ``bg_tokens`` during the tool gap — off the critical
                # path
                virt_prefill = float(ctx_len) - float(bg_tokens)
        ses.handoff_ready = False
        ses.handoff_dst = -1
        ses.handoff_lost = False
        ses.state = "prefill"
        ses.engine = w
        ses.slot = -1                        # assigned at prefill_done
        ses.cached_hit = real_hit
        ses.attempt = next(self._attempt)
        self.inflight[sid] = (w, ses.attempt)
        self._resident[w] += 1
        self._load_delta(w, 1)
        pf_s = max(0.0, virt_prefill) * self._speed_factor(w) \
            / self.perf.prefill_tokens_per_s \
            * (1.0 + self.perf.prefill_decode_interference
               * len(self._active[w]))
        self._tr_end(sid, "queue")
        # span naming: "resume" is reserved for resumed steps so the
        # report's TTFT-on-resume counts the same population in unified
        # and disagg runs — a first-step admission whose KV landed via
        # the prefill pool is still an (off-engine) prefill, not a resume
        self._tr_begin(sid, "phase",
                       "resume" if real_hit and ses.step_idx > 0
                       else "prefill",
                       parent_key="step", engine=w, attempt=ses.attempt)
        if self.obs_metrics is not None:
            self.obs_metrics.histogram("prefill_s").observe(
                self.ev.now, pf_s)
        # service accrues as GPU time is actually consumed (prefill here,
        # decode per round) so Thm. 2 deviation sees starvation while it
        # is happening, not at completion granularity
        self.co.afs.note_service(ses.inst.tenant, pf_s)
        self.ev.schedule(self.ev.now + pf_s, "prefill_done",
                         (sid, ses.attempt))

    def _speed_factor(self, w: int) -> float:
        """Straggler slowdown factor for engine ``w`` (>1 = slow)."""
        return self._slow.get(w, 1.0)

    def _round_s(self, w: int) -> float:
        """Duration of the next batched decode round on ``w``: base rate
        x straggler factor x chunked-prefill interference — each session
        in prefill phase on the engine (``_resident`` minus the decode
        set) stretches the round by ``prefill_round_interference``.  The
        default coefficient 0.0 keeps every committed fingerprint
        byte-identical; the disagg A/B turns it on in BOTH arms, and the
        prefill pool wins exactly because its decode engines run
        (nearly) prefill-free rounds."""
        stretch = 1.0 + self.perf.prefill_round_interference \
            * max(0, self._resident[w] - len(self._active[w]))
        return self.perf.decode_round_s * self._speed_factor(w) * stretch

    def _on_prefill_done(self, sid: str, attempt: int = -1) -> None:
        rec = self.inflight.get(sid)
        if rec is None or rec[1] != attempt:
            return       # stale: the attempt was cancelled by a fault
        ses = self.sessions[sid]
        w = ses.engine
        slot = self.engines[w].start_session(
            sid, np.asarray(ses.ctx, np.int32), cached_hit=ses.cached_hit)
        if slot is None:                     # _resident bounds admissions
            raise RuntimeError(f"engine {w} slot accounting drifted")
        ses.slot = slot
        ses.state = "decode"
        if ses.mid_step:
            # resuming a preempted decode: ``remaining`` tokens of the
            # interrupted step are still owed; its partial output list
            # is already in place
            ses.mid_step = False
        else:
            ses.remaining = int(ses.inst.rt_step(ses.step_idx)[1])
            ses.step_outputs.append([])
        ses.next_token = int(ses.ctx[-1])
        self._tr_end(sid, "phase")
        self._tr_begin(sid, "phase", "decode", parent_key="step",
                       engine=w, attempt=attempt)
        if self.tracer is not None:
            # flag key alongside span ids: the next round stamps the
            # first decoded token's time onto the decode span (TTFT)
            self._tr_open[sid]["ttft_pending"] = 1
        self._active[w].add(sid)
        if not self._round_live[w]:
            self._round_live[w] = True
            dur = self._round_s(w)
            self.ev.schedule(self.ev.now + dur, "round",
                             (w, self._gen[w], dur))

    def _on_round(self, w: int, gen: int = 0, dur: float = -1.0) -> None:
        """One continuous-batching decode round: every decode-phase
        session on engine ``w`` advances one token in a single batched
        forward pass.  Sessions whose step completed leave the batch
        (their slot frees, the queue drains into it) while the rest keep
        decoding — no barrier between sessions.  ``gen`` stamps the
        engine incarnation: a round scheduled before a failure must not
        touch the recovered engine's fresh batch.  The round boundary is
        also where a pending AFS preemption parks its victim — never
        mid-forward-pass, so the decode batch stays internally
        consistent."""
        if gen != self._gen[w]:
            return                   # stale: engine died since scheduling
        active = sorted(self._active[w],
                        key=lambda s: (self.sessions[s].slot, s))
        if not active:
            self._round_live[w] = False
            return
        eng = self.engines[w]
        slot_tokens = {self.sessions[s].slot: self.sessions[s].next_token
                       for s in active}
        out = eng.decode(slot_tokens, n_steps=1)
        # the round's duration was fixed at schedule time (interference
        # snapshot); the legacy fallback covers replayed two-arg events
        round_s = dur if dur > 0.0 \
            else self.perf.decode_round_s * self._speed_factor(w)
        finished: List[str] = []
        for sid in active:
            ses = self.sessions[sid]
            tok = int(out[ses.slot][0])
            ses.ctx.append(tok)
            ses.step_outputs[-1].append(tok)
            ses.next_token = tok
            ses.remaining -= 1
            self.co.afs.note_service(ses.inst.tenant, round_s)
            if ses.remaining == 0:
                finished.append(sid)
        if self.tracer is not None:
            for sid in active:
                o = self._tr_open.get(sid)
                if o is not None \
                        and o.pop("ttft_pending", None) is not None \
                        and "phase" in o:
                    self.tracer.note(o["phase"],
                                     first_token_t=self.ev.now)
            self.tracer.complete(f"engine/{w}", "round",
                                 self.ev.now - round_s, self.ev.now,
                                 engine=w, batch=len(active),
                                 finished=len(finished))
            self.obs_metrics.histogram("decode_round_s").observe(
                self.ev.now, round_s)
        for sid in finished:
            self._active[w].discard(sid)
            self._finish_decode(sid)
        victim = self._preempt_pending.pop(w, None)
        if victim is not None and victim in self._active[w]:
            self._preempt_now(victim, w)
        if self._active[w]:
            nxt = self._round_s(w)
            self.ev.schedule(self.ev.now + nxt, "round",
                             (w, self._gen[w], nxt))
        else:
            self._round_live[w] = False
        self._drain_queue(w)

    def _step_work_s(self, prompt_len: int, n_out: int) -> float:
        """Nominal GPU-seconds of one step (Eq. 9 granularity): virtual
        prefill + one decode round per token.  Straggler factors are
        deliberately excluded so AFS charges demand, not slowness."""
        return prompt_len / self.perf.prefill_tokens_per_s \
            + n_out * self.perf.decode_round_s

    def _finish_decode(self, sid: str) -> None:
        ses = self.sessions[sid]
        w = ses.engine
        eng = self.engines[w]
        self.inflight.pop(sid, None)
        self._tr_end(sid, "phase")
        prompt, n_out, tool, gap_s = ses.inst.rt_step(ses.step_idx)
        work = self._step_work_s(len(prompt), n_out)
        # a preemption park part-charged this step already; charge only
        # the tail so the step's total AFS progress is exact
        self.co.afs.note_progress(sid, max(0.0, work - ses.work_charged))
        ses.work_charged = 0.0
        # park boundary: resolve the taken edge / dynamic callback (the
        # callback sees the real decoded token ids).  Deterministic on
        # the virtual clock; memoized per step index.
        if ses.inst.resolve_next(ses.step_idx,
                                 outputs=ses.step_outputs) is None:
            self._finish_task(sid)
            return
        ctx_len = len(ses.ctx)
        entry_bytes = ctx_len * self.kv_bytes_per_token
        evicted = self.co.on_step_end(
            sid, w, float(ctx_len), entry_bytes, tool, self.ev.now,
            next_node=ses.inst.next_node_hint(ses.step_idx + 1))
        # event-driven WA-LRU reconciliation: only the victims the policy
        # actually picked lose their real blocks (the old server rescanned
        # every cached session per step)
        for evd in evicted:
            eng.evict_session(evd.session_id)
        if self.co.pools[w].contains(sid):
            if not self._park_real(sid, w):
                self.co.drop_entry(sid, w, count_eviction=False)
                eng.release_session(sid)
        else:
            eng.release_session(sid)
        ses.slot = -1
        self._resident[w] -= 1
        self._load_delta(w, -1)
        ses.state = "tool"
        self._tr_begin(sid, "gap", "tool_gap", parent_key="step",
                       tool=tool, parked=self.co.pools[w].contains(sid))
        job = self.co.prefetcher.inflight.get(sid)
        if job is not None and job.issued_at == self.ev.now:
            self.ev.schedule(job.ready_at, "prefetch", (sid, w))
        self.ev.schedule(self.ev.now + float(gap_s), "tool_done", (sid,))
        if self.disagg:
            # speculative PREFILL: the park boundary just resolved the
            # next step (``resolve_next`` above), so its prompt is known
            # — submit the prefill job now and overlap compute + handoff
            # with the tool gap (generalizes speculative prefetch)
            self._begin_prefill(sid, speculative=True)

    def _park_real(self, sid: str, w: int) -> bool:
        """Move the session's slot KV into the engine pool, evicting
        WA-LRU victims (policy + real blocks together) until it fits."""
        eng = self.engines[w]
        n = len(self.sessions[sid].ctx)
        while not eng.pool.can_fit(n):
            victim = self.co.pools[w].select_victim(self.ev.now)
            if victim is None or victim.session_id == sid:
                return False
            self.co.drop_entry(victim.session_id, w)
            eng.evict_session(victim.session_id)
        return eng.park_session(sid)

    def _preempt_now(self, sid: str, w: int) -> None:
        """Execute a pending AFS preemption at the round boundary: park
        the victim's slot KV into the pool mid-step (TTL entry via
        ``preempt_park`` — the AEG cursor does not advance) and requeue
        it AFS-ordered behind the starved session, which the round's
        trailing ``_drain_queue`` admits into the freed slot.  The
        victim resumes later with a delta-only prefill and finishes the
        interrupted step token-for-token identically."""
        ses = self.sessions[sid]
        eng = self.engines[w]
        self._active[w].discard(sid)
        self.inflight.pop(sid, None)
        self._tr_end(sid, "phase", status="preempted")
        self._tr_instant(f"engine/{w}", "preempt", sid=sid)
        # charge the executed part of the step now (prompt prefill +
        # decoded rounds); _finish_decode later charges only the tail
        prompt = ses.inst.rt_step(ses.step_idx)[0]
        decoded = len(ses.ctx) - ses.step_start_len
        done_work = self._step_work_s(len(prompt), decoded)
        self.co.afs.note_progress(
            sid, max(0.0, done_work - ses.work_charged))
        ses.work_charged = done_work
        ctx_len = len(ses.ctx)
        evicted = self.co.preempt_park(
            sid, w, float(ctx_len), ctx_len * self.kv_bytes_per_token,
            self.ev.now)
        for evd in evicted:
            eng.evict_session(evd.session_id)
        if self.co.pools[w].contains(sid):
            if not self._park_real(sid, w):
                self.co.drop_entry(sid, w, count_eviction=False)
                eng.release_session(sid)
        else:
            eng.release_session(sid)
        ses.slot = -1
        ses.mid_step = True
        self._resident[w] -= 1
        self._load_delta(w, -1)
        self.preempted += 1
        self._last_preempt[w] = self.ev.now
        # admit the starved queue head into the freed slot FIRST, then
        # requeue the victim behind it — queue priorities are stamped at
        # push time, so re-enqueueing the victim before the admission
        # could let a stale (pre-recompute) priority re-admit the victim
        # straight back into the slot it was just parked from
        starved = self._queue_pop(w)
        self._enqueue(sid, w)
        if starved is not None:
            self._admit(starved.session_id, w)

    def _finish_task(self, sid: str) -> None:
        ses = self.sessions[sid]
        w = ses.engine
        self.inflight.pop(sid, None)
        self.engines[w].release_session(sid)
        ses.slot = -1
        self._resident[w] -= 1
        self._load_delta(w, -1)
        sites = self.co.cached_sites(sid)
        self.co.task_finished(sid, self.ev.now)
        for site in sites:                   # replicas included
            self.engines[site].evict_session(sid)
        ses.state = "done"
        ses.finished_at = self.ev.now
        self.n_done += 1
        self._tr_end(sid, "step")
        self._tr_end(sid, "session")
        self._tr_open.pop(sid, None)
        self._drain_queue(w)

    def _on_tool_done(self, sid: str) -> None:
        ses = self.sessions[sid]
        if ses.state != "tool":
            return
        prompt, _, tool, gap_s = ses.inst.rt_step(ses.step_idx)
        self.co.on_tool_done(sid, tool, float(gap_s), float(len(prompt)),
                             self.ev.now)
        self._tr_end(sid, "gap")
        self._tr_end(sid, "step")
        ses.step_idx += 1
        self._begin_step(sid)

    # -- disaggregated prefill pool (serving/disagg.py) -----------------
    def _begin_prefill(self, sid: str, speculative: bool = False) -> None:
        """Submit one step's prefill to the prefill pool.  Speculative
        (park boundary): the next step's prompt is already resolved, so
        the job covers ctx + next prompt and the compute + handoff
        overlap the tool gap.  Non-speculative (gap over, nothing in
        flight): the session waits at the rendezvous while the pool
        computes.  The Eq. 7 route taken HERE is the step's decode
        placement; the hit/miss verdict is counted once, now.  Falls
        back to classic decode-pool dispatch when the prefill pool is
        down or the context cannot fit any staging pool."""
        ses = self.sessions[sid]
        if speculative:
            if sid in self._pf.jobs:
                return                        # already in flight
            nxt = ses.inst.rt_step(ses.step_idx + 1)[0]
            tokens = list(ses.ctx) + [int(t) for t in nxt]
        else:
            tokens = list(ses.ctx)
        pools = [e.pool for e in self.engines]
        fits = any(self._alive[p] and pools[p]._blocks_for(len(tokens))
                   <= pools[p].num_blocks for p in self._prefill_ids)
        if not fits:
            # whole prefill pool down (or context larger than every
            # staging pool): unified-style dispatch keeps sessions
            # moving instead of stalling on the rendezvous
            if not speculative:
                w = self.co.route(sid, self.loads(), self.ev.now)
                self._dispatch_to(sid, w)
            return
        d = self.co.route(sid, self.loads(), self.ev.now)
        self.co.afs.note_unblocked(sid)
        hit, pf_tokens, bg_tokens = self.co.on_step_start(
            sid, d, float(len(tokens)), self.ev.now)
        eng_d = self.engines[d]
        real_hit = hit and eng_d.has_cache(sid)
        if hit and not real_hit:
            self.co.drop_entry(sid, d, count_eviction=False)
        if not hit and eng_d.has_cache(sid):
            eng_d.evict_session(sid)
        if real_hit:
            start = int(eng_d.pool.lens[sid])
            virt = float(pf_tokens)
        else:
            start = 0
            ses.regen_tokens += len(tokens)
            virt = float(len(tokens)) - float(bg_tokens)
        job = HandoffJob(session_id=sid, attempt=next(self._attempt),
                         d_engine=d, start=start, tokens=tokens,
                         pf_tokens=max(0.0, virt),
                         speculative=speculative,
                         waiting=not speculative)
        self._pf.submit(job)
        if not speculative:
            ses.state = "queued"
            ses.engine = -1
            ses.slot = -1
            if self.tracer is not None \
                    and "queue" not in self._tr_open.get(sid, {}):
                self._tr_begin(sid, "queue", "queue_wait",
                               parent_key="step", engine=-1)
        self._pf_place(job)

    def _pf_place(self, job: HandoffJob) -> None:
        got = self._pf.place(job, self.ev.now,
                             [e.pool for e in self.engines], self._alive)
        if got is None:
            self._pf.defer(job)       # retried as staged blocks release
            return
        self._pf_launch(job, got[0], got[1])

    def _pf_launch(self, job: HandoffJob, p: int, t0: float) -> None:
        """Open the (virtual) prefill compute window on engine ``p``;
        the REAL forward pass runs when ``pf_done`` is processed, so a
        fault before then loses no staged blocks."""
        ses = self.sessions[job.session_id]
        pf_s = job.pf_tokens * self._speed_factor(p) \
            / self.perf.prefill_tokens_per_s
        self._pf.note_busy_until(p, t0 + pf_s)
        self.co.afs.note_service(ses.inst.tenant, pf_s)
        self.ev.schedule(t0 + pf_s, "pf_done",
                         (job.session_id, job.attempt))

    def _pf_drain(self) -> None:
        """Re-try deferred prefill jobs (staged blocks released, or a
        prefill engine recovered) — FIFO, deterministic."""
        for job, p, t0 in self._pf.drain(self.ev.now,
                                         [e.pool for e in self.engines],
                                         self._alive):
            self._pf_launch(job, p, t0)

    def _on_pf_done(self, sid: str, attempt: int = -1) -> None:
        """The prefill compute window elapsed: run the REAL delta
        prefill on the prefill engine, stage the blocks in its pool, and
        open the deterministic transfer window to the decode engine."""
        job = self._pf.jobs.get(sid)
        if job is None or job.attempt != attempt:
            return       # stale: cancelled by a fault in the meantime
        p = job.p_engine
        if not self.engines[p].stage_prefill(
                sid, np.asarray(job.tokens, np.int32), job.start):
            raise RuntimeError(
                f"staging pool reservation drifted on engine {p}")
        self._pf.staged(job, [e.pool for e in self.engines])
        tr_s = job.n_stage * self.kv_bytes_per_token \
            / self.perf.handoff_bytes_per_s + self.perf.handoff_latency_s
        self._tr_begin(sid, "handoff", "handoff", parent_key="session",
                       src=p, dst=job.d_engine, tokens=job.n_stage)
        self.ev.schedule(self.ev.now + tr_s, "handoff_done",
                         (sid, attempt))

    def _handoff_abort(self, job: HandoffJob, status: str) -> None:
        """Reclaim both sides of a dead handoff attempt: staged blocks
        on a live prefill engine free through its pool (a dead one's
        were already wiped by ``Engine.fail``), an unstaged job returns
        its block reservation, and the registry forgets the attempt so
        its pending pf_done/handoff_done events go stale."""
        sid = job.session_id
        if job.state == "staged" and 0 <= job.p_engine < self.n_workers \
                and self._alive[job.p_engine] \
                and self.engines[job.p_engine].has_cache(sid):
            self.engines[job.p_engine].evict_session(sid)
        self._pf.unreserve(job, [e.pool for e in self.engines])
        self._pf.pop(sid)
        self.handoffs_cancelled += 1
        self._tr_end(sid, "handoff", status=status)

    def _on_handoff_done(self, sid: str, attempt: int = -1) -> None:
        """The transfer window elapsed: move the staged blocks into the
        decode engine's pool (evicting WA-LRU victims to make room) and
        arm the rendezvous — or unwind the attempt if the decode side
        changed underneath it."""
        job = self._pf.jobs.get(sid)
        if job is None or job.attempt != attempt:
            return       # stale: cancelled by a fault in the meantime
        ses = self.sessions[sid]
        p = job.p_engine
        d = job.d_engine
        if not self._alive[d]:
            if job.start == 0 and self._decode_alive():
                # full-context KV is placement-free: land it on a live
                # decode engine instead (Eq. 7 re-route)
                d = job.d_engine = self.co.route(sid, self.loads(),
                                                 self.ev.now)
            else:
                # the delta's prefix died with its decode engine (or no
                # decode engine survives): reclaim both sides; a waiting
                # session re-prefills on a live engine via _redispatch
                self._handoff_abort(job, "cancelled")
                self._pf_drain()
                if job.waiting:
                    self._redispatch(sid)
                return
        eng_d = self.engines[d]
        append = job.start > 0
        if append and int(eng_d.pool.lens.get(sid, -1)) != job.start:
            # the parked prefix this delta extends was evicted mid-
            # flight: the staged KV no longer lines up — re-prefill
            self._handoff_abort(job, "cancelled")
            self._pf_drain()
            if job.waiting:
                self._redispatch(sid)
            return
        k, v, n = self.engines[p].export_kv(sid)
        while not eng_d.import_handoff(sid, k, v, n, append=append):
            victim = self.co.pools[d].select_victim(self.ev.now)
            if victim is None or victim.session_id == sid:
                # no evictable room at the decode engine: drop the
                # attempt, the session regenerates there (§3.1)
                self._handoff_abort(job, "lost")
                ses.handoff_lost = True
                self._pf_drain()
                if job.waiting:
                    self._dispatch_to(sid, d)
                return
            self.co.drop_entry(victim.session_id, d)
            eng_d.evict_session(victim.session_id)
        self.engines[p].evict_session(sid)    # release the source side
        if not append:
            # miss-path landing: create the decode-side TTL entry (hit
            # landings extend the existing pinned entry's blocks)
            inserted, evicted = self.co.handoff_land(
                sid, d, float(len(job.tokens)),
                len(job.tokens) * self.kv_bytes_per_token, self.ev.now)
            for evd in evicted:
                eng_d.evict_session(evd.session_id)
            if not inserted:
                # only pinned victims at d: the landed blocks must not
                # outlive their metadata (the migration-landing rule)
                eng_d.evict_session(sid)
                self._handoff_abort(job, "lost")
                ses.handoff_lost = True
                self._pf_drain()
                if job.waiting:
                    self._dispatch_to(sid, d)
                return
        hbytes = n * self.kv_bytes_per_token
        self.handoffs += 1
        self.kv_handoff_bytes += hbytes
        if self.obs_metrics is not None:
            self.obs_metrics.counter("handoff_count").inc(1)
            self.obs_metrics.counter("kv_handoff_bytes").inc(hbytes)
        self._tr_end(sid, "handoff", tokens=n)
        self._pf.pop(sid)
        ses.handoff_ready = True
        ses.handoff_dst = d
        self._pf_drain()
        if job.waiting:
            self._dispatch_to(sid, d)

    def _pf_fail_engine(self, w: int) -> None:
        """A dead engine's side of the handoff lifecycle: every job
        computing on or staged on ``w`` is cancelled (``Engine.fail``
        already freed the blocks; the attempt-stamped registry makes the
        pending pf_done/handoff_done events stale) and waiting sessions
        re-prefill on a live engine.  Jobs whose DECODE side is ``w``
        are resolved lazily at handoff_done (re-route or cancel)."""
        waiting: List[str] = []
        for job in self._pf.jobs_touching(w):
            if job.p_engine != w:
                continue
            self._handoff_abort(job, "cancelled")
            if job.waiting:
                waiting.append(job.session_id)
        for sid in sorted(waiting):
            self._redispatch(sid)
        self._pf_drain()

    def _handoff_staged(self, w: int) -> set:
        """Sessions whose in-transit handoff blocks live on engine ``w``
        (staged in the prefill pool — deliberately carrying no
        coordinator pool metadata): the sanitizer / mirror-check
        exemption set."""
        return self._pf.staged_on(w) if self.disagg else set()

    # -- epoch tick: AFS shares + work stealing + preemption ------------
    def _on_epoch(self) -> None:
        if self.obs_metrics is not None:
            if self._obs_tick % 10 == 0:
                self._obs_sample()
            self._obs_tick += 1
        decision, shares = self.co.epoch_tick(
            self.ev.now, self.loads(), self._queue_views,
            alive=self._alive, victim_candidates=self._nonempty,
            scan_queues=False)
        if decision is not None and self.co.stealer.accept(
                decision, len(self.queues[decision.victim]), self.ev.now,
                thief_alive=self._alive[decision.thief]):
            ses = self._queue_remove(decision.victim, decision.session_id)
            if ses is not None:
                ses.state = "migrating"
                self._tr_end(ses.session_id, "queue", status="stolen")
                self._tr_begin(ses.session_id, "migr", "migration",
                               parent_key="step", src=decision.victim,
                               dst=decision.thief)
                self.migrating[ses.session_id] = (decision.victim,
                                                  decision.thief)
                self.migrations += 1
                mig = self.perf.sample_migration_s(self.rng)
                self.ev.schedule(self.ev.now + mig, "migr_done",
                                 (ses.session_id, decision.victim,
                                  decision.thief))
        if self.co.cfg.enable_preemption:
            self._preempt_scan()
        if shares:
            self._note_afs_deviation()
        if self.n_done < len(self.sessions):
            if any(self._alive) or self.ev:
                self.ev.schedule(self.ev.now + self.perf.epoch_s, "epoch")
            else:
                # whole cluster dead and nothing scheduled could revive
                # it: stop ticking so run() returns and conservation
                # reports the stranded sessions (simulator semantics)
                self._epoch_live = False
        else:
            self._epoch_live = False

    def _fair_targets(self) -> Optional[List[Tuple[str, float, float]]]:
        """(tenant, service_s, fair_target_s) rows under the Thm. 2
        convention: each tenant's fair target is its share of TOTAL
        submitted workload (mu_i ∝ W_i, constant — ``lyapunov_v``'s
        weights) scaled by the service actually delivered so far, so
        targets track realized throughput and converge to W_i exactly
        when everything completes."""
        w_tot = sum(self._tenant_workload.values())
        if w_tot <= 0.0:
            return None
        tens = self.co.afs.tenants
        tot = sum(t.service_s for t in tens.values())
        if tot <= 0.0:
            return None
        return [(name, tens[name].service_s if name in tens else 0.0,
                 w / w_tot * tot)
                for name, w in sorted(self._tenant_workload.items())]

    def _preempt_scan(self) -> None:
        """§6.2 step 4 on the serving path: for every engine whose slots
        are full while sessions queue, preempt the lowest-priority
        running decode iff (a) the queue head's fair-share deficit
        exceeds the configured threshold, (b) it has been blocked longer
        than ``preempt_block_s``, and (c) the blocked tenant is actually
        UNDER-served and the victim OVER-served against their
        workload-proportional fair targets — (c) is the Thm. 2
        restoring-force condition and the anti-flap hysteresis: once
        service ratios cross their fair rates, preemption stops instead
        of starving the former hog in turn.  A per-engine cooldown of
        ``preempt_block_s`` adds rate-limiting.  The decision is made
        here; the park happens at the engine's next round boundary."""
        cfg = self.co.cfg
        now = self.ev.now
        targets = self._fair_targets()
        lag = {name: tgt - srv
               for name, srv, tgt in (targets or ())}
        for w in sorted(self._nonempty):
            if not self._alive[w] or w in self._preempt_pending:
                continue
            if self._resident[w] < self.n_slots or not self._active[w]:
                continue
            if now - self._last_preempt[w] < cfg.preempt_block_s:
                continue
            head = self.queues[w].peek()
            if head is None:
                continue
            blocked = head.session_id
            b_ten = self.sessions[blocked].inst.tenant
            victim = min(self._active[w], key=lambda s: (
                self.co.afs.priority(self.sessions[s].inst.tenant), s))
            v_ten = self.sessions[victim].inst.tenant
            if self.co.afs.deficit(b_ten, v_ten) <= cfg.preempt_deficit:
                continue
            if targets is not None and not (lag.get(b_ten, 0.0) > 0.0
                                            and lag.get(v_ten, 0.0) < 0.0):
                continue
            if not self.co.afs.should_preempt(blocked, victim, now):
                continue
            self._preempt_pending[w] = victim

    def _note_afs_deviation(self) -> None:
        """Track the max fair-share deviation max_i |S_i - mu_i| under
        the workload-proportional Thm. 2 targets.  Preemption should
        keep this strictly tighter than admission-only ordering — the
        serve-bench preemption gate asserts exactly that."""
        targets = self._fair_targets()
        if targets is None or len(targets) < 2:
            return
        dev = max(abs(srv - tgt) for _, srv, tgt in targets)
        if dev > self.afs_dev_max:
            self.afs_dev_max = dev

    def _obs_sample(self) -> None:
        """Decimated epoch-tick metric sampling (traced runs only):
        per-engine queue depth, batch occupancy, KV pool occupancy
        split parked/resident/free, cumulative regeneration bytes, and
        the Thm. 2 fair-share deviation/lag.  Read-only off structures
        the scheduler already maintains; per-engine gauge handles are
        cached (grown lazily on scale-up) so the hot loop skips the
        registry's label-key construction."""
        m = self.obs_metrics
        now = self.ev.now
        while len(self._obs_engine_g) < len(self.engines):
            w = len(self._obs_engine_g)
            self._obs_engine_g.append((
                m.gauge("queue_depth", engine=w),
                m.gauge("batch_occupancy", engine=w),
                m.gauge("kv_blocks", engine=w, state="parked"),
                m.gauge("kv_blocks", engine=w, state="resident"),
                m.gauge("kv_blocks", engine=w, state="free"),
                m.gauge("regen_bytes", engine=w)))
        for w, eng in enumerate(self.engines):
            gq, gb, gp, gr_, gf, gg = self._obs_engine_g[w]
            gq.set(now, len(self.queues[w]))
            gb.set(now, len(self._active[w]))
            parked = eng.pool.used_blocks()
            gp.set(now, parked)
            gr_.set(now, eng.pool.physical_used_blocks() - parked)
            gf.set(now, len(eng.pool.free))
            gg.set(now, eng.regen_tokens * self.kv_bytes_per_token)
        targets = self._fair_targets()
        if targets is not None:
            m.gauge("afs_deviation_max").set(
                now, max(abs(srv - tgt) for _, srv, tgt in targets))
            for name, srv, tgt in targets:
                m.gauge("afs_lag_s", tenant=name).set(now, tgt - srv)

    def _copy_kv(self, sid: str, src: int, dst: int) -> bool:
        """Real pool-to-pool block copy (export, make room, import)."""
        kv = self.engines[src].export_kv(sid)
        if kv is None:
            return False
        k, v, n = kv
        dst_eng = self.engines[dst]
        while not dst_eng.pool.can_fit(n):
            victim = self.co.pools[dst].select_victim(self.ev.now)
            if victim is None or victim.session_id == sid:
                return False
            self.co.drop_entry(victim.session_id, dst)
            dst_eng.evict_session(victim.session_id)
        return dst_eng.import_kv(sid, k, v, n)

    def _on_migr_done(self, sid: str, src: int, dst: int) -> None:
        """A stolen session's KV transfer window elapsed: move the real
        blocks and the cache entry (TTL state travels with it, §3.1),
        then admit on the thief."""
        if self.migrating.pop(sid, None) is None:
            return
        ses = self.sessions[sid]
        if ses.state != "migrating":
            self._tr_end(sid, "migr", status="stale")
            return
        if not self._alive[dst]:
            # thief died while the KV was in transit: drop the copy and
            # re-route to a live engine (the home entry, if the source
            # survives, is still intact for a later resume)
            self._tr_end(sid, "migr", status="dropped")
            self._redispatch(sid)
            return
        if self.engines[src].has_cache(sid):
            if self._copy_kv(sid, src, dst):
                self.engines[src].evict_session(sid)
                _, evicted = self.co.migrate_session(sid, src, dst,
                                                     self.ev.now)
                for evd in evicted:
                    self.engines[dst].evict_session(evd.session_id)
                if not self.co.pools[dst].contains(sid):
                    # metadata didn't land (only pinned victims at the
                    # thief): the imported blocks must not outlive it
                    self.engines[dst].evict_session(sid)
            # else: no room at the thief — the entry (and its blocks)
            # stay home; this step runs on the thief and regenerates
            # (§3.1), later steps may still resume the intact home copy
        else:
            self.co.router.set_home(sid, dst)
        self._tr_end(sid, "migr")
        self._dispatch_to(sid, dst)

    def _on_prefetch(self, sid: str, src: int) -> None:
        """Speculative prefetch landing (§4.3): the bandwidth-delayed
        copy window elapsed mid-tool-gap.  If the home engine looks too
        loaded to take the resume (Eq. 7 would divert), replicate the
        parked KV to the likely overflow target so the diverted resume
        still hits cache."""
        ses = self.sessions.get(sid)
        if ses is None or ses.state != "tool":
            return
        if sid not in self.co.prefetcher.inflight:
            return                            # superseded or resolved
        if not self._alive[src]:
            return                            # source died mid-gap
        loads = self.loads()
        if float(loads[src]) < self.co.cfg.theta:
            return                            # home will take the resume
        masked = loads.astype(float).copy()
        masked[src] = INF
        for i, alive in enumerate(self._alive):
            if not alive:                     # a dead engine's zero load
                masked[i] = INF               # must not attract replicas
        if self.disagg and self._prefill_ids:
            # decode-pool KV must never replicate into a prefill
            # engine's staging pool — and prefill engines idle at load 0
            # would otherwise win every argmin below
            had_live = math.isfinite(float(masked.min()))
            for i in self._prefill_ids:
                masked[i] = INF
            if not math.isfinite(float(masked.min())):
                if had_live:
                    # the only overflow candidates were prefill engines:
                    # the prediction is unusable — count it as waste
                    self.co.prefetcher.cancel(sid)
                    self.prefetch_role_rejected += 1
                return
        if not math.isfinite(float(masked.min())):
            return
        dst = int(masked.argmin())
        if dst == src or not self.engines[src].has_cache(sid):
            return
        inserted, evicted = self.co.replicate_entry(sid, src, dst,
                                                    self.ev.now)
        for evd in evicted:
            self.engines[dst].evict_session(evd.session_id)
        if not inserted:
            return
        if self._copy_kv(sid, src, dst):
            self.prefetch_copies += 1
            self.prefetch_copy_bytes += \
                len(ses.ctx) * self.kv_bytes_per_token
            self._tr_instant(f"engine/{src}", "prefetch", sid=sid,
                             dst=dst)
        else:
            self.co.drop_entry(sid, dst, count_eviction=False)

    # -- faults / elasticity (cluster.faults plans, runtime twin) -------
    def _on_fault(self, kind: str, w: int) -> None:
        """One ``cluster.faults`` plan event on the virtual clock.  The
        same plans drive both substrates: (t, "fail"|"recover"|
        "scale_up"|"slow"|"heal", worker)."""
        self._tr_instant("run", "fault", kind=kind, engine=w)
        if kind == "fail":
            self._fail_engine(w)
        elif kind == "recover":
            self._recover_engine(w)
        elif kind == "scale_up":
            self._scale_up()
        elif kind == "slow":
            self._slow[w] = self.straggler_slowdown
        elif kind == "heal":
            self._slow.pop(w, None)
        else:
            raise ValueError(f"unknown fault event {kind!r}")

    def _fail_engine(self, w: int) -> None:
        """Engine dies mid-decode: cancel its in-flight attempts via the
        attempt-stamped registry (stale prefill_done/round events no
        longer match), reclaim slots, release pool blocks, requeue its
        pending queue on live engines, and wipe policy state
        (coordinator pool metadata, affinities, idle-set membership).
        Cancelled sessions retry from their last parked prefix —
        regenerating if the prefix died with this engine (§3.1)."""
        if w >= self.n_workers or not self._alive[w]:
            return                           # already down
        self._alive[w] = False
        self.faults_injected += 1
        self._gen[w] += 1                    # invalidate pending rounds
        self._round_live[w] = False
        self._preempt_pending.pop(w, None)
        self.co.worker_failed(w)
        # real replication copies sourced from the dead pool die with it
        self.co.prefetcher.cancel_worker(w)
        self.engines[w].fail()
        if self.disagg:
            # handoff jobs computing/staged on the dead engine cancel,
            # reclaim both sides, and re-prefill on a live engine
            self._pf_fail_engine(w)
        tickets = self.queues[w].drain()
        if tickets:
            self._load_delta(w, -len(tickets))
            self._queue_went_empty(w)
        victims = sorted(sid for sid, (ew, _) in self.inflight.items()
                         if ew == w)
        for sid in victims:
            self._cancel_attempt(sid, w)
        if self._resident[w] != 0:
            raise RuntimeError(
                f"engine {w} lifecycle leak at failure: "
                f"resident={self._resident[w]}")
        for t in tickets:
            self._redispatch(t.session_id)

    def _cancel_attempt(self, sid: str, w: int) -> None:
        """Cancel one in-flight step attempt on a dead engine: roll the
        context back to the step start (the decoded tail's KV died with
        the slots), refund any partially-charged AFS progress so the
        full retry is owed again, and re-dispatch."""
        ses = self.sessions[sid]
        del self.inflight[sid]
        self.cancelled_attempts += 1
        self._active[w].discard(sid)
        self._tr_end(sid, "phase", status="cancelled")
        self._tr_instant(f"engine/{w}", "cancel", sid=sid)
        # decode rounds that executed before the crash were real service
        # and stay charged (per-round note_service already saw them —
        # sim semantics: work lost to a crash was still work), but any
        # partially-charged Eq. 9 progress is refunded: the retry runs
        # the whole step again
        if ses.work_charged > 0.0:
            self.co.afs.refund_work(sid, ses.work_charged)
            ses.work_charged = 0.0
        del ses.ctx[ses.step_start_len:]
        if len(ses.step_outputs) > ses.step_idx:
            ses.step_outputs.pop()
        ses.mid_step = False
        ses.slot = -1
        self._resident[w] -= 1
        self._load_delta(w, -1)
        self._redispatch(sid)

    def _recover_engine(self, w: int) -> None:
        if w >= self.n_workers or self._alive[w]:
            return                           # already up (storm overlap)
        self._alive[w] = True
        self.co.worker_recovered(w, self.ev.now)
        if self.disagg:
            self._pf_drain()     # deferred jobs may fit the pool again
        self._readmit_orphans()

    def _scale_up(self) -> None:
        """Elastic scale-out: a fresh engine joins, sharing the zoo
        model's jitted functions (module ``_JIT_CACHE``) so joining
        costs no recompilation."""
        ref = self.engines[0]
        eng = Engine(self.cfg, self.params, n_slots=ref.n_slots,
                     max_len=ref.max_len,
                     pool_blocks=ref.pool.num_blocks,
                     block_size=ref.pool.block, env=ref.env,
                     paged=ref.paged)
        self.engines.append(eng)
        # elastic capacity always joins the DECODE side: prefill-pool
        # sizing is a deployment-time choice (roles at construction)
        self.roles.append(ROLE_DECODE if self.disagg else ROLE_UNIFIED)
        w = self.co.add_worker(self.ev.now)
        self.queues.append(SessionQueue())
        self._queue_views.append(
            _RuntimeQueueView(lambda w=w: self.queues[w]))
        self._active.append(set())
        self._resident.append(0)
        self._round_live.append(False)
        self._gen.append(0)
        self._loadnum = np.append(self._loadnum, 0)
        self._alive.append(True)
        self._last_preempt.append(-INF)
        self.n_workers += 1
        self._readmit_orphans()

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        return {
            "prefill_tokens": sum(e.prefill_tokens for e in self.engines),
            "regen_tokens": sum(e.regen_tokens for e in self.engines),
            "decode_steps": sum(e.decode_steps for e in self.engines),
            "coordinator_hits": self.co.cache_hits,
            "coordinator_misses": self.co.cache_misses,
            # device bytes moved by park/resume/migration; paged mode's
            # park/resume are metadata-only so the first two stay 0.
            # (stats-only: summarize() stays byte-pinned either way)
            "park_copy_bytes": sum(e.park_copy_bytes
                                   for e in self.engines),
            "resume_copy_bytes": sum(e.resume_copy_bytes
                                     for e in self.engines),
            "migration_copy_bytes": sum(e.migration_copy_bytes
                                        for e in self.engines),
            # lifecycle counters (steal/migration, prefetch, faults,
            # preemption) so server.stats() surfaces them per worker —
            # additive keys only: every consumer reads by name
            "steals": int(self.co.stealer.steals),
            "migrations": int(self.migrations),
            "prefetch_copies": int(self.prefetch_copies),
            "faults_injected": int(self.faults_injected),
            "cancelled_attempts": int(self.cancelled_attempts),
            "preemptions": int(self.preempted),
            "afs_dev_max": float(self.afs_dev_max),
            # disaggregated prefill/decode handoff (0s in unified mode)
            "kv_handoff_bytes": int(sum(e.handoff_copy_bytes
                                        for e in self.engines)),
            "handoff_count": int(self.handoffs),
            "handoffs_cancelled": int(self.handoffs_cancelled),
            "prefetch_role_rejected": int(self.prefetch_role_rejected),
        }

    def summarize(self) -> dict:
        """Deterministic run summary (the cross-process byte-identity
        contract covers this dict's ``repr``)."""
        done = [s for s in self.sessions.values() if s.finished_at >= 0]
        tcts = sorted(s.tct for s in done)
        n = len(tcts)
        st = self.stats()
        out = {
            "n_sessions": len(self.sessions),
            "n_done": n,
            "tct_mean": float(sum(tcts) / n) if n else 0.0,
            "tct_p50": float(tcts[n // 2]) if n else 0.0,
            "tct_p99": float(tcts[min(n - 1, int(0.99 * n))]) if n else 0.0,
            "makespan": float(max((s.finished_at for s in done),
                                  default=0.0)),
            "prefill_tokens": int(st["prefill_tokens"]),
            "regen_tokens": int(st["regen_tokens"]),
            "decode_rounds": int(st["decode_steps"]),
            "decoded_tokens": int(sum(len(o) for s in self.sessions.values()
                                      for o in s.step_outputs)),
            "cache_hits": int(self.co.cache_hits),
            "cache_misses": int(self.co.cache_misses),
            "steals": int(self.co.stealer.steals),
            "migrations": int(self.migrations),
            "prefetch_issued": int(self.co.prefetcher.issued),
            "prefetch_correct": int(self.co.prefetcher.correct),
            "prefetch_copies": int(self.prefetch_copies),
            "prefetch_wasted_bytes": float(self.co.prefetcher.wasted_bytes),
        }
        if self.fault_plan or self.co.cfg.enable_preemption:
            # fault/preemption keys only when those modes are active, so
            # every pre-existing golden byte-pin of the default summary
            # stays valid
            out["faults_injected"] = int(self.faults_injected)
            out["cancelled_attempts"] = int(self.cancelled_attempts)
            out["preemptions"] = int(self.preempted)
            out["afs_dev_max"] = float(self.afs_dev_max)
        if self.disagg:
            # disagg keys only in disagg mode (same rule as above): the
            # unified summary's byte-pins stay valid
            out["handoffs"] = int(self.handoffs)
            out["handoff_bytes"] = float(self.kv_handoff_bytes)
            out["handoffs_cancelled"] = int(self.handoffs_cancelled)
            out["prefill_jobs"] = int(self._pf.submitted)
            out["speculative_prefills"] = int(self._pf.speculative)
            out["prefill_deferred"] = int(self._pf.deferred)
            out["prefetch_role_rejected"] = \
                int(self.prefetch_role_rejected)
        return out

    # -- invariants -----------------------------------------------------
    def check_conservation(self) -> None:
        """Post-run lifecycle invariants: every submitted session
        finished, no session stuck queued/migrating, every engine's
        slots and pool blocks returned to free, the incremental load /
        nonempty indices agree with ground truth, and the coordinator's
        pool metadata mirrors the real block tables.  Raises listing
        every violation."""
        bad: List[str] = []
        unfinished = sorted(s for s, st in self.sessions.items()
                            if st.finished_at < 0)
        if unfinished:
            bad.append(f"sessions never finished: {unfinished[:5]}")
        if self.n_done != len(self.sessions):
            bad.append(f"n_done={self.n_done} != {len(self.sessions)}")
        if self.migrating:
            bad.append(f"migrations in limbo: {sorted(self.migrating)[:5]}")
        if self.inflight:
            bad.append(f"attempts still in flight: "
                       f"{sorted(self.inflight)[:5]}")
        if self._orphans:
            bad.append(f"orphaned sessions never re-admitted: "
                       f"{sorted(self._orphans)[:5]}")
        if self._preempt_pending:
            bad.append(f"preemptions never executed: "
                       f"{sorted(self._preempt_pending.items())[:5]}")
        for w, eng in enumerate(self.engines):
            if self.queues[w]:
                bad.append(f"engine {w} queue not drained")
            if self._active[w]:
                bad.append(f"engine {w} decode set not empty")
            if eng.used_slots() != 0:
                bad.append(f"engine {w} leaked {eng.used_slots()} slots")
            if self._resident[w] != 0:
                bad.append(f"engine {w} resident count "
                           f"{self._resident[w]} != 0")
            if self._loadnum[w] != 0:
                bad.append(f"engine {w} load index drifted: "
                           f"{self._loadnum[w]}")
            if (w in self._nonempty):
                bad.append(f"engine {w} nonempty index stale")
            if eng.pool.tables:
                bad.append(f"engine {w} leaked blocks for "
                           f"{sorted(eng.pool.tables)[:5]}")
            if len(set(eng.pool.free)) != eng.pool.total_blocks:
                bad.append(f"engine {w} free list corrupt")
            if self.co.pools[w].entries:
                bad.append(f"engine {w} pool metadata not empty")
        if abs(self.co.pools_used) > 1e-6:
            bad.append(f"pools_used={self.co.pools_used}")
        if self.disagg:
            if self._pf.jobs:
                bad.append(f"handoff jobs in limbo: "
                           f"{sorted(self._pf.jobs)[:5]}")
            if self._pf.pending:
                bad.append(f"prefill jobs never placed: "
                           f"{self._pf.pending[:5]}")
            resv = {p: r for p, r in sorted(self._pf.reserved.items())
                    if r}
            if resv:
                bad.append(f"staging reservations leaked: {resv}")
            stuck = sorted(s for s, st in self.sessions.items()
                           if st.handoff_ready or st.handoff_lost)
            if stuck:
                bad.append(f"handoff flags never consumed: {stuck[:5]}")
        if bad:
            raise RuntimeError("runtime conservation violated: "
                               + "; ".join(bad))

    def verify_pool_mirrors(self) -> None:
        """Mid-run cross-check: every engine's real parked sessions must
        be a subset of the coordinator's pool entries (a metadata entry
        may transiently outlive its blocks during a resume, never the
        reverse).  Resident sessions are exempt: a cache-miss admit
        holds blocks from admit to finish with no coordinator entry
        until its first park.  In-transit handoff blocks staged on a
        prefill engine are likewise exempt — the cross-pool transfer
        deliberately carries no coordinator metadata until it lands."""
        for w, eng in enumerate(self.engines):
            extra = (set(eng.pool.tables) - set(self.co.pools[w].entries)
                     - eng.pool.resident - self._handoff_staged(w))
            if extra:
                raise RuntimeError(
                    f"engine {w} holds blocks with no pool entry: "
                    f"{sorted(extra)[:5]}")
