"""Per-request lifecycle tracking for the HTTP front end.

``TrackedRequest`` mirrors one submitted workflow through the proxy's
phase vocabulary — queued → prefill → decode → parked → done — with
cumulative WALL seconds per phase (the runtime's own spans are virtual
time; operators of a live deployment care about real latency).  The
tracker is a pure observer: it diff-scans session states after each
dispatched event (driver listener) and never touches the runtime.

Runtime states map onto proxy phases as:
  new/queued/migrating → queued, prefill → prefill, decode → decode,
  tool → parked, done → done.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

PHASES = ("queued", "prefill", "decode", "parked", "done")

_STATE_TO_PHASE = {
    "new": "queued", "queued": "queued", "migrating": "queued",
    "prefill": "prefill", "decode": "decode", "tool": "parked",
    "done": "done",
}


@dataclass
class TrackedRequest:
    """One proxied request's lifecycle record (wall-clock seconds)."""
    request_id: str
    session_id: str          # runtime session (unique per request)
    client_session: str      # X-Session-Id (spans many requests)
    task_id: str             # X-Task-Id
    program_id: str          # X-Program-Id
    tenant: str
    created_wall: float
    phase: str = "queued"
    phase_since: float = 0.0
    phase_wall_s: Dict[str, float] = field(default_factory=dict)
    first_token_wall: Optional[float] = None
    finished_wall: Optional[float] = None
    engine: int = -1
    n_tokens: int = 0

    def to_dict(self) -> dict:
        return {
            "request_id": self.request_id,
            "session_id": self.session_id,
            "client_session": self.client_session,
            "task_id": self.task_id,
            "program_id": self.program_id,
            "tenant": self.tenant,
            "phase": self.phase,
            "engine": self.engine,
            "n_tokens": self.n_tokens,
            "created_wall": self.created_wall,
            "first_token_wall": self.first_token_wall,
            "finished_wall": self.finished_wall,
            "phase_wall_s": {p: round(v, 6)
                             for p, v in sorted(self.phase_wall_s.items())},
        }


class RequestTracker:
    """Tracks live requests against a runtime; read-only observer."""

    def __init__(self, wall_now: Callable[[], float]) -> None:
        self._wall = wall_now
        self.live: Dict[str, TrackedRequest] = {}      # keyed by session_id
        self.finished: List[TrackedRequest] = []
        self.max_finished = 4096                       # ring for soak runs

    def track(self, *, request_id: str, session_id: str,
              client_session: str, task_id: str, program_id: str,
              tenant: str) -> TrackedRequest:
        now = self._wall()
        tr = TrackedRequest(request_id, session_id, client_session,
                            task_id, program_id, tenant,
                            created_wall=now, phase_since=now)
        self.live[session_id] = tr
        return tr

    def observe(self, runtime) -> None:
        """Diff-scan tracked sessions; called after every dispatched
        event.  Finished entries migrate to the ``finished`` ring."""
        now = self._wall()
        done: List[str] = []
        for sid, tr in self.live.items():
            ses = runtime.sessions.get(sid)
            if ses is None:
                continue
            phase = _STATE_TO_PHASE.get(ses.state, "queued")
            if ses.engine >= 0:
                tr.engine = ses.engine
            n_tok = sum(len(o) for o in ses.step_outputs)
            if n_tok and not tr.n_tokens and tr.first_token_wall is None:
                tr.first_token_wall = now
            tr.n_tokens = n_tok
            if phase != tr.phase:
                tr.phase_wall_s[tr.phase] = \
                    tr.phase_wall_s.get(tr.phase, 0.0) + (now - tr.phase_since)
                tr.phase = phase
                tr.phase_since = now
                if phase == "done":
                    tr.finished_wall = now
                    done.append(sid)
        for sid in done:
            self.finished.append(self.live.pop(sid))
        if len(self.finished) > self.max_finished:
            del self.finished[:len(self.finished) - self.max_finished]

    def get(self, session_id: str) -> Optional[TrackedRequest]:
        tr = self.live.get(session_id)
        if tr is not None:
            return tr
        for t in reversed(self.finished):
            if t.session_id == session_id:
                return t
        return None

    def phase_counts(self) -> Dict[str, int]:
        out = {p: 0 for p in PHASES}
        for tr in self.live.values():
            out[tr.phase] += 1
        out["done"] = len(self.finished)
        return out
