"""OpenAI-compatible HTTP proxy over the asyncio serving driver.

Stdlib only (the container pins its dependency set): a hand-rolled
HTTP/1.1 server on ``asyncio.start_server`` — keep-alive, chunked
transfer for SSE streaming, no TLS.  Endpoints:

  POST /v1/chat/completions   OpenAI chat completions.  ``stream: true``
                              returns SSE ``chat.completion.chunk``
                              events (chunked encoding).  Headers:
                                X-Session-Id  sticky client session —
                                              later requests are hinted
                                              to the engine whose pool
                                              holds the session's KV
                                X-Task-Id     runtime session id
                                              (generated if absent)
                                X-Program-Id  AgentProgram identity for
                                              AEG pattern stats
                                X-Tenant      AFS tenant (or body
                                              ``user``, or "default")
                              Body extension ``saga``: {"tool_gap_s":
                              float, "step_tokens": int, "slo_s": float}
                              — multi-turn bodies become multi-step
                              programs that park on tool gaps between
                              user turns.
  GET  /v1/requests/{sid}     TrackedRequest lifecycle JSON.
  GET  /metrics               Prometheus text: per-engine queue depth,
                              KV pool occupancy, handoff bytes, AFS
                              deviation + runtime counters, via the
                              ``repro.obs`` registry (merged with the
                              runtime's own traced registry when on).
  GET  /healthz               liveness + phase counts.

Prompts are tokenized with the same FNV-1a fold the workflow layer uses
for deterministic prompt realization; completions detokenize to
``tok<id>`` words.  The model is the repo's micro LM — the surface is
the point, not the prose.
"""
from __future__ import annotations

import asyncio
import itertools
import json
from typing import Dict, Optional, Tuple

from repro.obs.metrics import MetricsRegistry
from repro.serving.frontend.strategies import Strategy, get_strategy
from repro.serving.frontend.tracker import RequestTracker
from repro.workflow.program import AgentProgram, StepSpec, _fnv1a

_MAX_BODY = 4 << 20
_MAX_HEADERS = 64


def tokenize(text: str, vocab: int) -> list:
    """Deterministic word → token-id fold (FNV-1a, id in [1, vocab))."""
    return [1 + _fnv1a(w) % (vocab - 1) for w in text.split()]


def detokenize(ids) -> str:
    return " ".join(f"tok{int(i)}" for i in ids)


def program_from_body(body: dict, *, program_id: str, tenant: str,
                      vocab: int, seed: int = 0) -> AgentProgram:
    """Compile an OpenAI chat body to a scripted ``AgentProgram``.

    Each ``user`` turn opens a workflow step whose prompt is every
    message since the previous step; steps are separated by a tool gap
    (``saga.tool_gap_s``) so a multi-turn body exercises park/resume.
    Intermediate steps decode ``saga.step_tokens`` tokens, the final
    step ``max_tokens``."""
    msgs = body.get("messages") or []
    saga = body.get("saga") or {}
    max_tokens = int(body.get("max_tokens") or 16)
    gap_s = float(saga.get("tool_gap_s", 0.05))
    step_tokens = int(saga.get("step_tokens", min(8, max_tokens)))
    prompts, buf = [], []
    for m in msgs:
        buf.extend(tokenize(str(m.get("content", "")), vocab))
        if m.get("role") == "user":
            prompts.append(buf)
            buf = []
    if buf:
        if prompts:
            prompts[-1] = prompts[-1] + buf
        else:
            prompts.append(buf)
    if not prompts:
        prompts = [[1]]
    steps = [StepSpec(tool="http", prompt_ids=p or [1],
                      n_out=(max_tokens if i == len(prompts) - 1
                             else step_tokens),
                      tool_latency_s=(0.0 if i == len(prompts) - 1
                                      else gap_s))
             for i, p in enumerate(prompts)]
    return AgentProgram.scripted(program_id, tenant, steps, seed=seed)


# -- minimal HTTP/1.1 plumbing ------------------------------------------

class _HTTPError(Exception):
    def __init__(self, status: int, msg: str) -> None:
        super().__init__(msg)
        self.status = status


async def _read_request(reader) -> Optional[Tuple[str, str, Dict[str, str],
                                                  bytes]]:
    """One request off a keep-alive connection; None on clean EOF."""
    line = await reader.readline()
    if not line:
        return None
    try:
        method, target, _ = line.decode("latin-1").split(None, 2)
    except ValueError:
        raise _HTTPError(400, "malformed request line")
    headers: Dict[str, str] = {}
    for _ in range(_MAX_HEADERS):
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        if b":" not in raw:
            raise _HTTPError(400, "malformed header")
        k, v = raw.decode("latin-1").split(":", 1)
        headers[k.strip().lower()] = v.strip()
    else:
        raise _HTTPError(431, "too many headers")
    n = int(headers.get("content-length", 0) or 0)
    if n > _MAX_BODY:
        raise _HTTPError(413, "body too large")
    body = await reader.readexactly(n) if n else b""
    return method, target, headers, body


_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            431: "Request Header Fields Too Large",
            500: "Internal Server Error", 503: "Service Unavailable"}


def _response_bytes(status: int, body: bytes, ctype: str,
                    extra: Optional[Dict[str, str]] = None,
                    *, keep_alive: bool = True) -> bytes:
    lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}",
             f"Content-Type: {ctype}",
             f"Content-Length: {len(body)}",
             "Connection: " + ("keep-alive" if keep_alive else "close")]
    for k, v in (extra or {}).items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + body


class SagaHTTPProxy:
    """Serves OpenAI-compatible traffic into an ``AsyncServingDriver``.

    ``strategy`` names a registered load balancer (or pass a
    ``Strategy`` instance).  Known ``X-Session-Id``s override the
    strategy with a hint to the session's KV home engine, so a sticky
    client session parks and resumes where its cache lives."""

    def __init__(self, driver, *, strategy="saga-affinity",
                 host: str = "127.0.0.1", port: int = 0,
                 model_name: str = "saga-micro",
                 stream_poll_s: float = 0.01) -> None:
        self.driver = driver
        self.strategy: Strategy = (get_strategy(strategy)
                                   if isinstance(strategy, str)
                                   else strategy)
        self.host, self.port = host, port
        self.model_name = model_name
        self.stream_poll_s = stream_poll_s
        self.tracker = RequestTracker(driver.wall_now)
        driver.add_listener(self._on_event)
        self.metrics = MetricsRegistry()
        self.homes: Dict[str, int] = {}        # X-Session-Id -> engine
        self._seq = itertools.count()
        self._server: Optional[asyncio.AbstractServer] = None

    # -- lifecycle -------------------------------------------------------
    async def start(self) -> "SagaHTTPProxy":
        self._server = await asyncio.start_server(self._handle_conn,
                                                  self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    @property
    def base_url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # -- driver listener -------------------------------------------------
    def _on_event(self, t: float, kind: str, args: tuple) -> None:
        self.tracker.observe(self.driver.rt)
        # remember each client session's KV home as soon as it lands
        for tr in self.tracker.live.values():
            if tr.engine >= 0 and tr.client_session:
                self.homes[tr.client_session] = tr.engine

    # -- connection handling ---------------------------------------------
    async def _handle_conn(self, reader, writer) -> None:
        try:
            while True:
                try:
                    req = await _read_request(reader)
                except _HTTPError as e:
                    writer.write(_response_bytes(
                        e.status, json.dumps({"error": str(e)}).encode(),
                        "application/json", keep_alive=False))
                    await writer.drain()
                    break
                if req is None:
                    break
                method, target, headers, body = req
                keep = headers.get("connection", "").lower() != "close"
                try:
                    await self._route(method, target, headers, body,
                                      writer, keep)
                except (ConnectionError, asyncio.IncompleteReadError):
                    raise
                except Exception as e:          # surface, don't kill conn
                    writer.write(_response_bytes(
                        500, json.dumps({"error": repr(e)}).encode(),
                        "application/json", keep_alive=False))
                    await writer.drain()
                    break
                if not keep:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, method, target, headers, body, writer,
                     keep) -> None:
        path = target.split("?", 1)[0]
        if method == "POST" and path == "/v1/chat/completions":
            await self._chat(headers, body, writer, keep)
        elif method == "GET" and path == "/metrics":
            writer.write(_response_bytes(
                200, self._metrics_text().encode(),
                "text/plain; version=0.0.4", keep_alive=keep))
            await writer.drain()
        elif method == "GET" and path == "/healthz":
            out = {"status": "ok", "engines": self.driver.rt.n_workers,
                   "phases": self.tracker.phase_counts()}
            writer.write(_response_bytes(
                200, json.dumps(out).encode(), "application/json",
                keep_alive=keep))
            await writer.drain()
        elif method == "GET" and path.startswith("/v1/requests/"):
            sid = path[len("/v1/requests/"):]
            tr = self.tracker.get(sid)
            status, out = (200, tr.to_dict()) if tr is not None else \
                (404, {"error": f"unknown request {sid!r}"})
            writer.write(_response_bytes(
                status, json.dumps(out).encode(), "application/json",
                keep_alive=keep))
            await writer.drain()
        else:
            writer.write(_response_bytes(
                404 if method in ("GET", "POST") else 405,
                json.dumps({"error": f"no route {method} {path}"}).encode(),
                "application/json", keep_alive=keep))
            await writer.drain()

    # -- chat completions ------------------------------------------------
    async def _chat(self, headers, raw, writer, keep) -> None:
        try:
            body = json.loads(raw.decode() or "{}")
        except (ValueError, UnicodeDecodeError):
            raise _HTTPError(400, "body is not JSON")
        n = next(self._seq)
        client_session = headers.get("x-session-id", "")
        task_id = headers.get("x-task-id") or \
            (f"{client_session}.{n}" if client_session else f"req{n}")
        program_id = headers.get("x-program-id") or f"chat:{task_id}"
        tenant = headers.get("x-tenant") or \
            str(body.get("user") or "default")
        rt = self.driver.rt
        # the runtime keys sessions by the program id, so the program
        # carries the unique X-Task-Id; X-Program-Id seeds realization
        # (identical program ids realize identical unspecified prompts)
        # and rides in the tracker for client-side correlation
        prog = program_from_body(body, program_id=task_id,
                                 tenant=tenant, vocab=rt.cfg.vocab,
                                 seed=_fnv1a(program_id) & 0xFFFFFFFF)
        hint = self.homes.get(client_session) if client_session else None
        if hint is None:
            hint = self.strategy.pick(
                client_session or task_id, [float(x) for x in rt.loads()],
                rt._alive, rt.roles)
        slo = (body.get("saga") or {}).get("slo_s")
        handle = self.driver.submit(
            prog, route_hint=hint,
            slo_s=float(slo) if slo is not None else None)
        tr = self.tracker.track(
            request_id=f"chatcmpl-{n}", session_id=handle.session_id,
            client_session=client_session, task_id=task_id,
            program_id=program_id, tenant=tenant)
        self.metrics.counter("saga_http_requests",
                             endpoint="chat.completions").inc()
        if body.get("stream"):
            await self._chat_stream(handle, tr, body, writer)
        else:
            await handle.wait()
            writer.write(_response_bytes(
                200, json.dumps(self._completion_json(handle, tr,
                                                      body)).encode(),
                "application/json",
                extra=self._echo_headers(tr), keep_alive=keep))
            await writer.drain()

    def _echo_headers(self, tr) -> Dict[str, str]:
        return {"X-Session-Id": tr.client_session or tr.session_id,
                "X-Task-Id": tr.task_id,
                "X-Program-Id": tr.program_id,
                "X-Engine": str(tr.engine)}

    def _completion_json(self, handle, tr, body) -> dict:
        outs = handle.step_outputs
        prompt_toks = sum(len(tokenize(str(m.get("content", "")),
                                       self.driver.rt.cfg.vocab))
                          for m in body.get("messages") or [])
        completion_toks = sum(len(o) for o in outs)
        return {
            "id": tr.request_id,
            "object": "chat.completion",
            "created": int(self.driver.wall_now()),
            "model": body.get("model") or self.model_name,
            "choices": [{
                "index": 0,
                "message": {"role": "assistant",
                            "content": detokenize(outs[-1] if outs
                                                  else [])},
                "finish_reason": "stop",
            }],
            "usage": {"prompt_tokens": prompt_toks,
                      "completion_tokens": completion_toks,
                      "total_tokens": prompt_toks + completion_toks},
            "saga": {"session_id": tr.session_id,
                     "engine": tr.engine,
                     "steps": len(outs),
                     "path": handle.path},
        }

    async def _chat_stream(self, handle, tr, body, writer) -> None:
        """SSE streaming via chunked transfer: poll decoded tokens and
        emit ``chat.completion.chunk`` deltas until the workflow ends."""
        head = ("HTTP/1.1 200 OK\r\n"
                "Content-Type: text/event-stream\r\n"
                "Cache-Control: no-cache\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n")
        for k, v in self._echo_headers(tr).items():
            head += f"{k}: {v}\r\n"
        writer.write((head + "\r\n").encode("latin-1"))

        def chunk(data: str) -> bytes:
            payload = f"data: {data}\n\n".encode()
            return f"{len(payload):x}\r\n".encode() + payload + b"\r\n"

        def delta(content, finish=None) -> str:
            return json.dumps({
                "id": tr.request_id, "object": "chat.completion.chunk",
                "created": int(self.driver.wall_now()),
                "model": body.get("model") or self.model_name,
                "choices": [{"index": 0,
                             "delta": ({"content": content}
                                       if content is not None else {}),
                             "finish_reason": finish}]})

        writer.write(chunk(delta("")))       # role-less prologue chunk
        sent = 0
        while True:
            toks = self._decoded_so_far(handle.session_id)
            if len(toks) > sent:
                writer.write(chunk(delta(
                    ("" if sent == 0 else " ") +
                    detokenize(toks[sent:]))))
                sent = len(toks)
                await writer.drain()
            if handle.done:
                break
            await asyncio.sleep(self.stream_poll_s)
        writer.write(chunk(delta(None, finish="stop")))
        writer.write(chunk("[DONE]"))
        writer.write(b"0\r\n\r\n")
        await writer.drain()

    def _decoded_so_far(self, sid: str) -> list:
        """All tokens decoded so far (finished steps + the in-flight
        step's tail), read under the driver lock."""
        with self.driver._lock:
            ses = self.driver.rt.sessions[sid]
            toks = [t for out in ses.step_outputs for t in out]
            if ses.state == "decode" and ses.mid_step is False \
                    and len(ses.ctx) > ses.step_start_len:
                toks.extend(ses.ctx[ses.step_start_len:])
            return toks

    # -- metrics ---------------------------------------------------------
    def _metrics_text(self) -> str:
        """Sample live runtime state into the proxy registry and render
        Prometheus text (merged with the runtime's traced registry when
        tracing is on)."""
        reg, rt = self.metrics, self.driver.rt
        now = self.driver.wall_now()
        with self.driver._lock:
            for w in range(rt.n_workers):
                lab = {"engine": str(w)}
                reg.gauge("saga_queue_depth", **lab).set(
                    now, float(len(rt.queues[w])))
                reg.gauge("saga_engine_alive", **lab).set(
                    now, float(rt._alive[w]))
                pool = rt.engines[w].pool
                reg.gauge("saga_kv_pool_blocks_used", **lab).set(
                    now, float(pool.physical_used_blocks()))
                reg.gauge("saga_kv_pool_blocks_total", **lab).set(
                    now, float(pool.total_blocks))
                reg.gauge("saga_kv_handoff_bytes", **lab).set(
                    now, float(rt.engines[w].handoff_copy_bytes))
            reg.gauge("saga_afs_deviation_max").set(
                now, float(rt.afs_dev_max))
            reg.gauge("saga_sessions_total").set(
                now, float(len(rt.sessions)))
            reg.gauge("saga_sessions_done").set(now, float(rt.n_done))
            for k, v in rt.stats().items():
                reg.gauge(f"saga_runtime_{k}").set(now, float(v))
            obs = rt.obs_metrics
        text = reg.to_prometheus()
        if obs is not None:
            text += obs.to_prometheus()
        return text
