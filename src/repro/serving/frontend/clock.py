"""Clock abstraction for the asyncio serving driver.

``AsyncServingDriver`` never reads time sources directly — it asks its
clock, so the same pacing code runs against the real asyncio clock
(``WallClock``) or a deterministic counter (``FakeClock``).  The fake
clock is how CI proves the driver reproduces the virtual-time
``summarize()`` byte-identically: sleeps advance it instantly, so the
run is pure event-order replay with zero wall-time influence.
"""
from __future__ import annotations

import asyncio


class WallClock:
    """Real time via the running asyncio event loop.  ``wait`` blocks
    until ``event`` fires (True) or ``timeout`` elapses (False) — the
    driver's interruptible pacing sleep, so a submission arriving
    earlier than the next scheduled virtual event wakes it."""

    virtual = False

    def time(self) -> float:
        loop = asyncio.get_running_loop()
        return loop.time()

    async def wait(self, event: asyncio.Event, timeout: float) -> bool:
        try:
            await asyncio.wait_for(event.wait(), max(timeout, 0.0))
            return True
        except asyncio.TimeoutError:
            return False


class FakeClock:
    """Deterministic clock: ``wait`` advances time by the full timeout
    and reports no interruption, regardless of pending submissions.
    Pacing therefore costs nothing and perturbs nothing — the driver
    degenerates to exact heap-order replay of the virtual run."""

    virtual = True

    def __init__(self, start: float = 0.0) -> None:
        self._now = float(start)

    def time(self) -> float:
        return self._now

    async def wait(self, event: asyncio.Event, timeout: float) -> bool:
        self._now += max(timeout, 0.0)
        return False
