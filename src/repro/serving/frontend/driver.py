"""Asyncio wall-clock driver for ``ServingRuntime``.

One runtime codebase, two substrates: the virtual-time ``run()`` loop
pops the event heap as fast as Python allows, while this driver pops
THE SAME HEAP in the same order but paces each pop against a wall
clock — virtual deadlines map to awaits, tool gaps become real sleeps,
and decode rounds optionally execute on a single worker thread so the
asyncio loop stays responsive to HTTP traffic while JAX computes.

Byte-identity contract: because the driver dispatches the identical
event sequence (``getattr(rt, "_on_" + kind)(*args)``, sanitizer hook
included) and replicates ``run()``'s exact termination condition, a
``FakeClock`` run produces a ``summarize()`` repr byte-identical to the
virtual-time run.  ``benchmarks/serve_bench.py`` fingerprints this and
CI diffs it against the committed pin.

Wall mapping: ``wall = t0_wall + (virt - t0_virt) * time_scale``.  A
``time_scale`` of 1.0 serves virtual seconds in real seconds; soak runs
compress it.  When compute outruns the budget the driver simply never
sleeps (lag is recorded in ``wall_stats``), so pacing can throttle but
never reorder.
"""
from __future__ import annotations

import asyncio
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

from repro.serving.frontend.clock import FakeClock, WallClock

INF = float("inf")


class AsyncWorkflowHandle:
    """Awaitable twin of the runtime's ``WorkflowHandle``: same
    read-only views, but completion is awaited on the asyncio loop
    (``WorkflowHandle.result()`` drives the clock itself, which only
    the driver may do here)."""

    __slots__ = ("_driver", "_ses")

    def __init__(self, driver: "AsyncServingDriver", ses) -> None:
        self._driver = driver
        self._ses = ses

    @property
    def session_id(self) -> str:
        return self._ses.session_id

    @property
    def done(self) -> bool:
        return self._ses.finished_at >= 0

    @property
    def status(self) -> str:
        return self._ses.state

    @property
    def step_outputs(self) -> List[List[int]]:
        return [list(o) for o in self._ses.step_outputs]

    @property
    def path(self) -> List[int]:
        return list(self._ses.inst.path)

    @property
    def tct(self) -> float:
        return self._ses.tct

    async def wait(self, timeout: Optional[float] = None) -> "SessionState":
        """Await session completion; returns the ``SessionState``."""
        if self.done:
            return self._ses
        fut = asyncio.get_running_loop().create_future()
        self._driver._watch(self._ses.session_id, fut)
        await asyncio.wait_for(fut, timeout)
        return self._ses


class AsyncServingDriver:
    """Drives a ``ServingRuntime`` under asyncio.

    Parameters
      runtime     — a ``ServingRuntime`` (any config; the driver never
                    schedules events itself).
      clock       — ``WallClock()`` (default) or ``FakeClock()`` for
                    deterministic replay.
      time_scale  — wall seconds per virtual second (pacing only).
      executor    — run handlers on a single worker thread so prefill /
                    decode compute doesn't block the asyncio loop.
                    Handler EXECUTION stays strictly serial either way.
    """

    def __init__(self, runtime, *, clock=None, time_scale: float = 1.0,
                 executor: bool = False) -> None:
        if time_scale <= 0:
            raise ValueError(f"time_scale={time_scale!r} must be > 0")
        self.rt = runtime
        self.clock = clock if clock is not None else WallClock()
        self.time_scale = float(time_scale)
        self._pool = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="saga-engine")
                      if executor else None)
        # guards the runtime: handlers may run on the executor thread
        # while submit()/state reads happen on the asyncio loop
        self._lock = threading.Lock()
        self._wake: Optional[asyncio.Event] = None
        self._watchers: Dict[str, List[asyncio.Future]] = {}
        self._listeners: List[Callable[[float, str, tuple], None]] = []
        self._stopping = False
        self._running = False
        self._t0_wall: Optional[float] = None
        self._t0_virt = 0.0
        self._last_done = 0
        self.wall_stats = {"events": 0, "max_lag_s": 0.0,
                           "wall_elapsed_s": 0.0, "submitted": 0}

    # -- client surface --------------------------------------------------
    def wall_now(self) -> float:
        return self.clock.time()

    def virt_now(self) -> float:
        """Current virtual time as seen from the wall clock (falls back
        to the runtime clock before the driver starts or under a fake
        clock)."""
        if self._t0_wall is None or self.clock.virtual:
            return self.rt.ev.now
        return self._t0_virt + \
            (self.clock.time() - self._t0_wall) / self.time_scale

    def submit(self, req, *, route_hint: Optional[int] = None,
               slo_s: Optional[float] = None,
               arrival: Optional[float] = None) -> AsyncWorkflowHandle:
        """Submit a program/request; safe to call from asyncio handlers
        while the driver is mid-run.  A live wall-clock run stamps the
        arrival at the wall-mapped virtual now, so inter-arrival gaps in
        real traffic survive into the virtual schedule."""
        if arrival is None and self._running and not self.clock.virtual:
            arrival = self.virt_now()
        with self._lock:
            h = self.rt.submit(req, arrival, route_hint=route_hint,
                               slo_s=slo_s)
        self.wall_stats["submitted"] += 1
        if self._wake is not None:
            self._wake.set()
        return AsyncWorkflowHandle(self, h._ses)

    def add_listener(self, fn: Callable[[float, str, tuple], None]) -> None:
        """Register a read-only observer called after every dispatched
        event with ``(t, kind, args)`` (trackers, metrics samplers).
        Listeners must never mutate the runtime."""
        self._listeners.append(fn)

    def stop(self) -> None:
        self._stopping = True
        if self._wake is not None:
            self._wake.set()

    # -- event pump ------------------------------------------------------
    async def run(self, horizon_s: float = INF) -> Dict[str, object]:
        """Drain the heap until every submitted session finishes —
        the asyncio twin of ``ServingRuntime.run`` with identical
        termination semantics (this equivalence is what the fake-clock
        fingerprint pins)."""
        self._begin()
        rt = self.rt
        try:
            while not self._stopping:
                nxt = rt.ev.peek_time()
                if nxt is None or nxt > horizon_s:
                    break
                if await self._pace(nxt):
                    continue                 # woken early: re-peek
                kind = await self._dispatch_next()
                if kind is not None and kind != "epoch" \
                        and rt.n_done == len(rt.sessions):
                    break
        finally:
            self._end()
        return rt.sessions

    async def serve_forever(self) -> None:
        """Pump events indefinitely, idling on the wake event whenever
        the heap drains (the HTTP proxy's mode: submissions re-arm the
        heap).  Returns after ``stop()``."""
        self._begin()
        rt = self.rt
        try:
            while not self._stopping:
                nxt = rt.ev.peek_time()
                if nxt is None:
                    self._wake.clear()
                    await self.clock.wait(self._wake, 0.05)
                    continue
                if await self._pace(nxt):
                    continue
                await self._dispatch_next()
        finally:
            self._end()

    # -- internals -------------------------------------------------------
    def _begin(self) -> None:
        if self._running:
            raise RuntimeError("driver is already running")
        self._running = True
        self._stopping = False
        self._wake = asyncio.Event()
        if self._t0_wall is None:
            self._t0_wall = self.clock.time()
            self._t0_virt = self.rt.ev.now

    def _end(self) -> None:
        self._running = False
        self.wall_stats["wall_elapsed_s"] = \
            self.clock.time() - (self._t0_wall or 0.0)

    def _wall_for(self, virt: float) -> float:
        return self._t0_wall + (virt - self._t0_virt) * self.time_scale

    async def _pace(self, nxt: float) -> bool:
        """Sleep until the wall deadline of virtual time ``nxt``.
        True → woken early (new submission / stop): caller re-peeks.
        False → deadline reached (or already behind): caller pops."""
        delay = self._wall_for(nxt) - self.clock.time()
        if delay > 0:
            self._wake.clear()
            return await self.clock.wait(self._wake, delay)
        lag = -delay
        if lag > self.wall_stats["max_lag_s"]:
            self.wall_stats["max_lag_s"] = lag
        # compute-bound stretch: still yield so proxy coroutines run
        if not self.clock.virtual:
            await asyncio.sleep(0)
        return False

    async def _dispatch_next(self) -> Optional[str]:
        """Pop and dispatch exactly one event, mirroring the body of
        ``ServingRuntime.run`` (handler, then sanitizer hook)."""
        rt = self.rt

        def step():
            with self._lock:
                t, kind, args = rt.ev.pop()
                getattr(rt, "_on_" + kind)(*args)
                if rt._san is not None:
                    rt._san.after_event(t, kind, args)
                return t, kind, args

        if self._pool is not None:
            t, kind, args = await asyncio.get_running_loop() \
                .run_in_executor(self._pool, step)
        else:
            t, kind, args = step()
        self.wall_stats["events"] += 1
        for fn in self._listeners:
            fn(t, kind, args)
        if rt.n_done != self._last_done:
            self._last_done = rt.n_done
            self._resolve_watchers()
        return kind

    def _watch(self, sid: str, fut: asyncio.Future) -> None:
        ses = self.rt.sessions.get(sid)
        if ses is not None and ses.finished_at >= 0:
            fut.set_result(ses)
            return
        self._watchers.setdefault(sid, []).append(fut)

    def _resolve_watchers(self) -> None:
        if not self._watchers:
            return
        done = [sid for sid in self._watchers
                if self.rt.sessions[sid].finished_at >= 0]
        for sid in done:
            for fut in self._watchers.pop(sid):
                if not fut.done():
                    fut.set_result(self.rt.sessions[sid])
