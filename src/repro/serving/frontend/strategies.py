"""Pluggable load-balancing strategies for the HTTP proxy.

A strategy picks the engine a NEW session should first land on; the
pick feeds ``ServingRuntime.submit(route_hint=...)``, a one-shot hint
consumed on the session's first dispatch.  Returning ``None`` defers to
the scheduler's own Eq. 7 affinity routing — that is the saga-affinity
strategy, and the default.  Later steps of a session always follow the
scheduler (park/resume affinity is the paper's whole point); strategies
only spread FIRST placements, e.g. to keep a canary engine cold or to
mimic a front-end LB the paper's baselines assume.

Strategies are registered by name so deployments select them from
config (``SagaHTTPProxy(strategy="least-loaded")``); ``register_strategy``
admits out-of-tree implementations.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional, Sequence, Type


class Strategy:
    """Pick an engine for a first placement, or ``None`` to defer to
    Eq. 7.  ``loads`` is the per-engine active-session count, ``alive``
    the liveness mask, ``roles`` the engine roles (``prefill`` engines
    hold no decode slots and must not be picked)."""

    name = "base"

    def pick(self, session_key: str, loads: Sequence[float],
             alive: Sequence[bool],
             roles: Sequence[str]) -> Optional[int]:
        raise NotImplementedError

    def _eligible(self, loads, alive, roles):
        return [w for w in range(len(loads))
                if alive[w] and roles[w] != "prefill"]


class SagaAffinity(Strategy):
    """Defer every placement to the scheduler's Eq. 7 routing (cache
    affinity + load threshold).  The default — byte-identical to not
    running a proxy at all."""

    name = "saga-affinity"

    def pick(self, session_key, loads, alive, roles) -> Optional[int]:
        return None


class RoundRobin(Strategy):
    """Cycle over live decode-capable engines in index order."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = itertools.count()

    def pick(self, session_key, loads, alive, roles) -> Optional[int]:
        ok = self._eligible(loads, alive, roles)
        if not ok:
            return None
        return ok[next(self._next) % len(ok)]


class LeastLoaded(Strategy):
    """Lowest active-session count among live decode-capable engines;
    ties break to the lowest index (deterministic)."""

    name = "least-loaded"

    def pick(self, session_key, loads, alive, roles) -> Optional[int]:
        ok = self._eligible(loads, alive, roles)
        if not ok:
            return None
        return min(ok, key=lambda w: (loads[w], w))


_REGISTRY: Dict[str, Type[Strategy]] = {}


def register_strategy(cls: Type[Strategy]) -> Type[Strategy]:
    if not cls.name or cls.name in _REGISTRY:
        raise ValueError(f"strategy name {cls.name!r} empty or taken")
    _REGISTRY[cls.name] = cls
    return cls


for _cls in (SagaAffinity, RoundRobin, LeastLoaded):
    register_strategy(_cls)


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown strategy {name!r} "
                         f"(have {sorted(_REGISTRY)})") from None
