"""Wall-clock serving front end (ROADMAP item 3).

The virtual-time ``EventLoop`` in ``repro.serving.events`` was built so
the SAME ``_on_*`` handler set could one day run under a real clock —
this package is that day:

  * ``clock``     — ``WallClock`` (asyncio wall time) and ``FakeClock``
                    (deterministic, sleeps advance it instantly) behind
                    one awaitable interface.
  * ``driver``    — ``AsyncServingDriver``: pops the runtime's event
                    heap in exact virtual order, paces pops against the
                    wall clock (virtual deadlines → awaits, tool gaps →
                    real sleeps, decode rounds → executor-threaded
                    engine steps).  Under ``FakeClock`` it reproduces
                    the virtual-time ``summarize()`` byte-identically —
                    CI diffs that fingerprint.
  * ``strategies``— pluggable load balancers (saga-affinity /
                    round-robin / least-loaded) feeding the runtime's
                    one-shot ``route_hint``.
  * ``tracker``   — ``TrackedRequest`` lifecycle (queued → prefill →
                    decode → parked → done) with per-phase wall-clock
                    accounting.
  * ``proxy``     — stdlib-asyncio HTTP server speaking
                    OpenAI-compatible ``/v1/chat/completions`` (plus
                    SSE streaming) with ``X-Session-Id`` /
                    ``X-Task-Id`` / ``X-Program-Id`` headers, and
                    ``/metrics`` Prometheus text from the ``repro.obs``
                    registry.

This package is the ONE place sagalint's det-clock rule permits wall
clocks (scoped configuration in ``repro.analysis.sagalint``, not
pragmas): everything here drives or observes the runtime, never
schedules inside it, so virtual-time determinism is untouched.

See docs/SERVING_API.md for the full contract.
"""
from repro.serving.frontend.clock import FakeClock, WallClock
from repro.serving.frontend.driver import (AsyncServingDriver,
                                           AsyncWorkflowHandle)
from repro.serving.frontend.proxy import SagaHTTPProxy
from repro.serving.frontend.strategies import (LeastLoaded, RoundRobin,
                                               SagaAffinity, Strategy,
                                               get_strategy,
                                               register_strategy)
from repro.serving.frontend.tracker import RequestTracker, TrackedRequest

__all__ = [
    "AsyncServingDriver", "AsyncWorkflowHandle", "FakeClock",
    "LeastLoaded", "RequestTracker", "RoundRobin", "SagaAffinity",
    "SagaHTTPProxy", "Strategy", "TrackedRequest", "WallClock",
    "get_strategy", "register_strategy",
]
