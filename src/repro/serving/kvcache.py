"""Paged KV cache pool (vLLM's PagedAttention adapted to TPU/JAX).

The pool is a pair of device arrays
    k_pool, v_pool: (L, num_blocks, block_size, K, dh)
plus host-side block tables {session -> [block ids]}.  Eviction and TTL
never touch device memory — they only mutate the table + free list,
exactly like the paper's WA-LRU over PagedAttention blocks.  The Pallas
paged-decode kernel (repro.kernels.paged_attention) consumes this layout
on TPU; the CPU engine gathers blocks into contiguous caches.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVPool:
    def __init__(self, n_layers: int, num_blocks: int, block_size: int,
                 n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.L = n_layers
        self.num_blocks = num_blocks
        self.block = block_size
        self.K = n_kv_heads
        self.dh = head_dim
        shape = (n_layers, num_blocks, block_size, n_kv_heads, head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self.free: List[int] = list(range(num_blocks))
        self.tables: Dict[str, List[int]] = {}
        self.lens: Dict[str, int] = {}

    # -- accounting ------------------------------------------------------
    @property
    def bytes_per_block(self) -> int:
        return int(2 * self.L * self.block * self.K * self.dh * 2)

    def used_blocks(self) -> int:
        return self.num_blocks - len(self.free)

    def audit_blocks(self) -> List[Tuple[str, Optional[str]]]:
        """Block-conservation audit: every block id must live in exactly
        one place — the free list or exactly one session's table.
        Returns (message, owning_session_or_None) per violation; empty
        when the pool is consistent.  A double-release shows up as a
        block both free and owned (or twice free); a leak as a block in
        neither."""
        errs: List[Tuple[str, Optional[str]]] = []
        owner: Dict[int, str] = {}
        for sid in sorted(self.tables):
            for b in self.tables[sid]:
                if b in owner:
                    errs.append((f"block {b} owned by both "
                                 f"{owner[b]!r} and {sid!r}", sid))
                elif not 0 <= b < self.num_blocks:
                    errs.append((f"block {b} of {sid!r} out of range",
                                 sid))
                else:
                    owner[b] = sid
        seen_free = set()
        for b in self.free:
            if b in seen_free:
                errs.append((f"block {b} on the free list twice "
                             "(double-release)", None))
            elif b in owner:
                errs.append((f"block {b} both free and owned by "
                             f"{owner[b]!r} (double-release)",
                             owner[b]))
            seen_free.add(b)
        lost = sorted(set(range(self.num_blocks)) - seen_free
                      - set(owner))
        if lost:
            errs.append((f"blocks {lost[:8]} in no table and not free "
                         "(leaked)", None))
        return errs

    def session_bytes(self, sid: str) -> int:
        return len(self.tables.get(sid, [])) * self.bytes_per_block

    def has(self, sid: str) -> bool:
        return sid in self.tables

    # -- alloc/free --------------------------------------------------------
    def _blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block)

    def can_fit(self, tokens: int) -> bool:
        return self._blocks_for(tokens) <= len(self.free)

    def free_session(self, sid: str) -> int:
        blocks = self.tables.pop(sid, [])
        self.lens.pop(sid, None)
        self.free.extend(blocks)
        return len(blocks)

    # -- park / resume -------------------------------------------------------
    def park(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
             n_tokens: int) -> bool:
        """Store a session's contiguous KV (L, S, K, dh) into pool blocks.
        Returns False (caller must evict) if no space."""
        n_tokens = int(n_tokens)
        nb = self._blocks_for(n_tokens)
        if sid in self.tables:
            self.free_session(sid)
        if nb > len(self.free):
            return False
        blocks = [self.free.pop() for _ in range(nb)]
        pad = nb * self.block - n_tokens
        if pad:
            k = jnp.pad(k[:, :n_tokens], ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v[:, :n_tokens], ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            k = k[:, :n_tokens]
            v = v[:, :n_tokens]
        kb = k.reshape(self.L, nb, self.block, self.K, self.dh)
        vb = v.reshape(self.L, nb, self.block, self.K, self.dh)
        idx = jnp.asarray(blocks, jnp.int32)
        self.k_pool = self.k_pool.at[:, idx].set(kb)
        self.v_pool = self.v_pool.at[:, idx].set(vb)
        self.tables[sid] = blocks
        self.lens[sid] = n_tokens
        return True

    def resume(self, sid: str) -> Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                                 int]]:
        """Gather a parked session back to contiguous (L, S, K, dh)."""
        blocks = self.tables.get(sid)
        if blocks is None:
            return None
        idx = jnp.asarray(blocks, jnp.int32)
        k = self.k_pool[:, idx].reshape(self.L, -1, self.K, self.dh)
        v = self.v_pool[:, idx].reshape(self.L, -1, self.K, self.dh)
        n = self.lens[sid]
        return k[:, :n], v[:, :n], n

    def block_table_array(self, sid: str, max_blocks: int) -> np.ndarray:
        """Padded int32 block table for the Pallas paged-decode kernel."""
        blocks = self.tables.get(sid, [])
        out = np.zeros((max_blocks,), np.int32)
        out[:len(blocks)] = blocks
        return out
