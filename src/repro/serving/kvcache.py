"""Paged KV cache pool (vLLM's PagedAttention adapted to TPU/JAX).

The pool is a pair of device arrays
    k_pool, v_pool: (L, total_blocks, block_size, K, dh)
plus host-side block tables {session -> [block ids]}.  Eviction and TTL
never touch device memory — they only mutate the table + free list,
exactly like the paper's WA-LRU over PagedAttention blocks.

Two session populations share the arrays:

  * **parked** sessions (the classic population): idle KV held across
    tool calls, counted against the *nominal* capacity ``num_blocks``
    that the coordinator's WA-LRU/TTL policy budgets against.
  * **resident** sessions (paged decode): slot-bound sessions whose KV
    lives in blocks from admit to finish.  Their blocks ride in the
    ``headroom_blocks`` the engine sizes for its slots
    (n_slots * max_len/block), so they never compete with the parked
    population — policy-visible capacity checks (``can_fit``,
    ``park_resident``) see exactly the same arithmetic as a
    gather-mode pool, which keeps paged and gather scheduling
    decisions bit-identical.

Parking a resident session is metadata-only (a set flip, no copy); so
is resuming a parked one (``mark_resident``).  The paged decode step
(``models.lm.decode_step_paged``) appends each new token's K/V straight
into the tail block on device.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVPool:
    def __init__(self, n_layers: int, num_blocks: int, block_size: int,
                 n_kv_heads: int, head_dim: int, dtype=jnp.bfloat16,
                 headroom_blocks: int = 0):
        self.L = n_layers
        # nominal (policy-visible) capacity: what WA-LRU/TTL budget against
        self.num_blocks = num_blocks
        # physical capacity: nominal + the engine's resident headroom
        self.total_blocks = num_blocks + headroom_blocks
        self.block = block_size
        self.K = n_kv_heads
        self.dh = head_dim
        shape = (n_layers, self.total_blocks, block_size, n_kv_heads,
                 head_dim)
        self.k_pool = jnp.zeros(shape, dtype)
        self.v_pool = jnp.zeros(shape, dtype)
        self.free: List[int] = list(range(self.total_blocks))
        self.tables: Dict[str, List[int]] = {}
        self.lens: Dict[str, int] = {}
        # slot-bound sessions: their blocks live in the headroom and are
        # invisible to the parked-capacity accounting below
        self.resident: Set[str] = set()

    # -- accounting ------------------------------------------------------
    @property
    def bytes_per_block(self) -> int:
        return int(2 * self.L * self.block * self.K * self.dh * 2)

    def used_blocks(self) -> int:
        """Blocks held by PARKED sessions — the policy-visible usage a
        gather-mode pool would report (resident sessions hold no parked
        blocks there either: their KV lives in the slot cache)."""
        return sum(len(t) for sid, t in self.tables.items()
                   if sid not in self.resident)

    def physical_used_blocks(self) -> int:
        return self.total_blocks - len(self.free)

    def audit_blocks(self) -> List[Tuple[str, Optional[str]]]:
        """Block-conservation audit: every block id must live in exactly
        one place — the free list or exactly one session's table.
        Returns (message, owning_session_or_None) per violation; empty
        when the pool is consistent.  A double-release shows up as a
        block both free and owned (or twice free); a leak as a block in
        neither."""
        errs: List[Tuple[str, Optional[str]]] = []
        owner: Dict[int, str] = {}
        for sid in sorted(self.tables):
            for b in self.tables[sid]:
                if b in owner:
                    errs.append((f"block {b} owned by both "
                                 f"{owner[b]!r} and {sid!r}", sid))
                elif not 0 <= b < self.total_blocks:
                    errs.append((f"block {b} of {sid!r} out of range",
                                 sid))
                else:
                    owner[b] = sid
        seen_free = set()
        for b in self.free:
            if b in seen_free:
                errs.append((f"block {b} on the free list twice "
                             "(double-release)", None))
            elif b in owner:
                errs.append((f"block {b} both free and owned by "
                             f"{owner[b]!r} (double-release)",
                             owner[b]))
            seen_free.add(b)
        lost = sorted(set(range(self.total_blocks)) - seen_free
                      - set(owner))
        if lost:
            errs.append((f"blocks {lost[:8]} in no table and not free "
                         "(leaked)", None))
        if self.used_blocks() > self.num_blocks:
            errs.append((f"parked blocks {self.used_blocks()} exceed "
                         f"nominal capacity {self.num_blocks}", None))
        stale = sorted(self.resident - set(self.tables))
        if stale:
            errs.append((f"resident sessions with no table: {stale[:5]}",
                         stale[0]))
        return errs

    def session_bytes(self, sid: str) -> int:
        return len(self.tables.get(sid, [])) * self.bytes_per_block

    def has(self, sid: str) -> bool:
        return sid in self.tables

    # -- alloc/free --------------------------------------------------------
    def _blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block)

    def can_fit(self, tokens: int) -> bool:
        """Policy-visible capacity check for PARKING ``tokens`` worth of
        KV: resident sessions ride in the headroom and do not count.
        (Gather mode: resident is empty, so this degenerates to the old
        free-list check.)"""
        return self._blocks_for(tokens) <= \
            self.num_blocks - self.used_blocks()

    def free_session(self, sid: str) -> int:
        blocks = self.tables.pop(sid, [])
        self.lens.pop(sid, None)
        self.resident.discard(sid)
        self.free.extend(blocks)
        return len(blocks)

    # -- allocate-at-admit (paged decode) ---------------------------------
    def alloc(self, sid: str) -> None:
        """Bind ``sid`` as a resident session with an empty table;
        prefill then lands straight into blocks via :meth:`extend` and a
        decode slot becomes just a batch-row binding.  A stale parked
        table (a coordinator miss whose old blocks survived) is freed
        first — that prefix is about to be regenerated anyway."""
        if sid in self.tables:
            self.free_session(sid)
        self.tables[sid] = []
        self.lens[sid] = 0
        self.resident.add(sid)

    def extend(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
               n_new: Optional[int] = None, *,
               bucket: Optional[int] = None) -> None:
        """Append contiguous KV (L, n, K, dh) at the session's current
        end, drawing tail blocks from the free list.  One scatter lands
        all n tokens (mid-block starts supported: a resume's delta
        prefill continues the partially-filled tail block).

        ``bucket`` is the caller's prefill compile quantum; it must be a
        whole number of blocks so a compile-bucket boundary never splits
        a tail block (the engine pads prefill lengths to
        lcm(bucket, block))."""
        assert bucket is None or bucket % self.block == 0, \
            f"prefill bucket {bucket} not a multiple of block {self.block}"
        n_new = int(k.shape[1]) if n_new is None else int(n_new)
        if n_new == 0:
            return
        start = self.lens[sid]
        end = start + n_new
        tbl = self.tables[sid]
        need = self._blocks_for(end) - len(tbl)
        assert need <= len(self.free), \
            f"pool headroom exhausted extending {sid!r}"
        for _ in range(need):
            tbl.append(self.free.pop())
        tok = np.arange(start, end)
        bids = jnp.asarray([tbl[i] for i in tok // self.block], jnp.int32)
        offs = jnp.asarray(tok % self.block, jnp.int32)
        kd = k[:, :n_new].astype(self.k_pool.dtype)
        vd = v[:, :n_new].astype(self.v_pool.dtype)
        self.k_pool = self.k_pool.at[:, bids, offs].set(kd)
        self.v_pool = self.v_pool.at[:, bids, offs].set(vd)
        self.lens[sid] = end

    def extend_parked(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
                      n_new: Optional[int] = None) -> bool:
        """Append contiguous delta KV behind a PARKED session's prefix —
        the landing half of a prefill→decode handoff on a cache hit: the
        parked prefix blocks stay put and the handed-off delta appends
        behind them (mid-block starts supported, same scatter as
        :meth:`extend`).  Unlike ``extend``, the new blocks join the
        parked population, so the draw is checked against the NOMINAL
        capacity; returns False (caller evicts or cancels the handoff)
        when the delta would not fit."""
        assert sid in self.tables and sid not in self.resident, \
            f"extend_parked of non-parked session {sid!r}"
        n_new = int(k.shape[1]) if n_new is None else int(n_new)
        need = self._blocks_for(self.lens[sid] + n_new) \
            - len(self.tables[sid])
        if need > self.num_blocks - self.used_blocks():
            return False
        self.extend(sid, k, v, n_new)
        return True

    def ensure_tail_room(self, sid: str) -> None:
        """Guarantee the next appended token has a destination block
        (the resident headroom makes this draw infallible)."""
        tbl = self.tables[sid]
        if self.lens[sid] == len(tbl) * self.block:
            assert self.free, f"pool headroom exhausted for {sid!r}"
            tbl.append(self.free.pop())

    def tail_slot(self, sid: str) -> Tuple[int, int]:
        """(block id, in-block offset) where the NEXT token's K/V lands
        — the jitted paged decode's scatter destination."""
        n = self.lens[sid]
        return self.tables[sid][n // self.block], n % self.block

    def append_token(self, sid: str) -> None:
        """Account one decoded token whose K/V the device step already
        wrote into the tail block (see ``tail_slot``)."""
        n = self.lens[sid]
        assert n < len(self.tables[sid]) * self.block, \
            f"append past tail block of {sid!r} (ensure_tail_room missed)"
        self.lens[sid] = n + 1

    # -- resident <-> parked (metadata-only park / resume) ----------------
    def park_resident(self, sid: str) -> bool:
        """Metadata-only park of a slot-bound session: the blocks stay
        put; the session merely moves from resident (headroom) to parked
        (nominal-capacity) accounting.  Returns False — caller evicts
        and retries — when the parked set would exceed nominal capacity,
        exactly where a gather-mode park would have failed."""
        assert sid in self.resident and sid in self.tables
        if len(self.tables[sid]) > self.num_blocks - self.used_blocks():
            return False
        self.resident.discard(sid)
        return True

    def mark_resident(self, sid: str) -> None:
        """Metadata-only resume: a parked session joins a decode slot;
        its blocks move from parked to headroom accounting."""
        assert sid in self.tables and sid not in self.resident
        self.resident.add(sid)

    # -- park / resume (gather transport) ---------------------------------
    def park(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
             n_tokens: int) -> bool:
        """Store contiguous KV (L, S, K, dh) into freshly drawn pool
        blocks (gather-mode park; paged-mode migration import).
        Returns False (caller must evict) if no space — checked on NET
        demand *before* any old table is freed, so a failed re-park
        never destroys the KV it was replacing."""
        assert sid not in self.resident, \
            f"park of resident session {sid!r} (use park_resident)"
        n_tokens = int(n_tokens)
        nb = self._blocks_for(n_tokens)
        owned = len(self.tables.get(sid, []))
        if nb - owned > self.num_blocks - self.used_blocks():
            return False
        if sid in self.tables:
            self.free_session(sid)
        blocks = [self.free.pop() for _ in range(nb)]
        pad = nb * self.block - n_tokens
        if pad:
            k = jnp.pad(k[:, :n_tokens], ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v[:, :n_tokens], ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            k = k[:, :n_tokens]
            v = v[:, :n_tokens]
        kb = k.reshape(self.L, nb, self.block, self.K, self.dh)
        vb = v.reshape(self.L, nb, self.block, self.K, self.dh)
        idx = jnp.asarray(blocks, jnp.int32)
        self.k_pool = self.k_pool.at[:, idx].set(kb)
        self.v_pool = self.v_pool.at[:, idx].set(vb)
        self.tables[sid] = blocks
        self.lens[sid] = n_tokens
        return True

    def resume(self, sid: str) -> Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                                 int]]:
        """Gather a session's blocks back to contiguous (L, S, K, dh) —
        gather-mode resume, and the transport half of a cross-engine
        migration (only the owned blocks are copied)."""
        blocks = self.tables.get(sid)
        if blocks is None:
            return None
        idx = jnp.asarray(blocks, jnp.int32)
        k = self.k_pool[:, idx].reshape(self.L, -1, self.K, self.dh)
        v = self.v_pool[:, idx].reshape(self.L, -1, self.K, self.dh)
        n = self.lens[sid]
        return k[:, :n], v[:, :n], n

    def block_table_array(self, sid: str, max_blocks: int) -> np.ndarray:
        """Padded int32 block table for the Pallas paged-decode kernel."""
        blocks = self.tables.get(sid, [])
        out = np.zeros((max_blocks,), np.int32)
        out[:len(blocks)] = blocks
        return out
