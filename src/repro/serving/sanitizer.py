"""Runtime KV/slot sanitizer: conservation checked at every event.

``check_conservation`` proves the end state clean; when it fails, the
leak happened thousands of events earlier with no pointer to the
culprit.  With ``ServingRuntime(sanitize=True)`` (or ``SAGA_SANITIZE=1``
in the environment) the runtime calls :meth:`RuntimeSanitizer.after_event`
after *every* dispatched event, shadow-auditing:

  * **block conservation** per engine pool — every block in exactly one
    of {free list, one session's table} (``PagedKVPool.audit_blocks``);
    a double-release or an orphaned block fails here, at the first
    event that produced it, naming the owning session;
  * **slot ownership** — each occupied slot maps to a live session
    whose ``(engine, slot, state)`` agree, and the slot-owner set
    equals the continuous-batching set ``_active[w]`` (a session
    leaked out of the batch still holds a slot forever);
  * **incremental indices** — ``_resident`` / ``_loadnum`` /
    ``_nonempty`` against ground truth recomputed from scratch;
  * **registry consistency** — ``inflight`` keys are exactly the
    prefill/decode sessions and their (engine, attempt) stamps match
    the session records; queued tickets reference queued sessions;
  * **policy/real mirror** — parked blocks are a subset of the
    coordinator's pool metadata (the invariant behind
    ``verify_pool_mirrors``);
  * **cross-pool in-transit state** (disaggregated mode) — every staged
    handoff job's blocks really exist on its prefill engine with the
    staged token count, unstaged jobs hold only a reservation, and no
    reservation ever goes negative.

Violations raise :class:`SanitizerError` naming the event (kind, args,
virtual time) plus the owning session and attempt.  The sanitizer only
*reads* runtime state, so a sanitized run's ``summarize()`` repr is
byte-identical to an unsanitized one — CI runs one smoke leg with
``SAGA_SANITIZE=1`` to keep that true.
"""
from __future__ import annotations

from typing import List, Optional, TYPE_CHECKING

if TYPE_CHECKING:                       # pragma: no cover
    from repro.serving.runtime import ServingRuntime


class SanitizerError(AssertionError):
    """Conservation violated at an event boundary (not at end-of-run)."""


class RuntimeSanitizer:
    """Read-only shadow auditor for one :class:`ServingRuntime`."""

    def __init__(self, rt: "ServingRuntime") -> None:
        self.rt = rt
        self.events_checked = 0

    # -- helpers --------------------------------------------------------
    def _attempt(self, sid: Optional[str]) -> str:
        if sid is None:
            return ""
        ses = self.rt.sessions.get(sid)
        if ses is None:
            return f" (session {sid!r} unknown)"
        return f" (session {sid!r} attempt={ses.attempt})"

    # -- the per-event audit --------------------------------------------
    def after_event(self, t: float, kind: str, args: tuple) -> None:
        rt = self.rt
        self.events_checked += 1
        errs: List[str] = []
        for w, eng in enumerate(rt.engines):
            for msg, sid in eng.pool.audit_blocks():
                errs.append(f"engine {w}: {msg}{self._attempt(sid)}")
            owners = {}
            for i, slot in enumerate(eng.slots):
                sid = slot.session_id
                if sid is None:
                    continue
                if sid in owners:
                    errs.append(f"engine {w}: slots {owners[sid]} and "
                                f"{i} both held by"
                                f"{self._attempt(sid)}")
                    continue
                owners[sid] = i
                ses = rt.sessions.get(sid)
                if ses is None:
                    errs.append(f"engine {w} slot {i} held by unknown "
                                f"session {sid!r}")
                elif (ses.engine, ses.slot, ses.state) != (w, i,
                                                           "decode"):
                    errs.append(
                        f"engine {w} slot {i}: session record "
                        f"(engine={ses.engine}, slot={ses.slot}, "
                        f"state={ses.state!r}) disagrees with the slot "
                        f"table{self._attempt(sid)}")
            if set(owners) != rt._active[w]:
                drift = sorted(set(owners) ^ rt._active[w])
                who = ", ".join(f"{s!r}{self._attempt(s)}"
                                for s in drift)
                errs.append(f"engine {w}: decode batch != slot owners "
                            f"— leaked/phantom: {who}")
            n_prefill = sum(1 for s in rt.sessions.values()
                            if s.engine == w and s.state == "prefill")
            if rt._resident[w] != len(owners) + n_prefill:
                errs.append(f"engine {w}: resident={rt._resident[w]} "
                            f"but slots={len(owners)} + "
                            f"prefills={n_prefill}")
            if int(rt._loadnum[w]) != rt._resident[w] + \
                    len(rt.queues[w]):
                errs.append(f"engine {w}: load index "
                            f"{int(rt._loadnum[w])} != resident "
                            f"{rt._resident[w]} + queued "
                            f"{len(rt.queues[w])}")
            if (w in rt._nonempty) != bool(rt.queues[w]):
                errs.append(f"engine {w}: nonempty-index membership "
                            f"{w in rt._nonempty} but queue length "
                            f"{len(rt.queues[w])}")
            # policy/real mirror: parked blocks ⊆ coordinator metadata.
            # Resident sessions are exempt — block ownership spans
            # admit→finish in paged mode, and a cache-miss admit has no
            # coordinator entry until its first park.  So are in-transit
            # handoff blocks staged on a prefill engine: the cross-pool
            # transfer deliberately carries no coordinator metadata
            # until it lands on the decode side.
            extra = sorted(set(eng.pool.tables)
                           - set(rt.co.pools[w].entries)
                           - eng.pool.resident
                           - rt._handoff_staged(w))
            if extra:
                who = ", ".join(f"{s!r}{self._attempt(s)}"
                                for s in extra[:5])
                errs.append(f"engine {w}: parked blocks with no pool "
                            f"metadata entry: {who}")
            if eng.paged:
                # resident set == slot owners: a resident session with
                # no slot leaks headroom blocks forever; a slot owner
                # not marked resident would count against (and can
                # exhaust) the parked-policy budget
                if eng.pool.resident != set(owners):
                    drift = sorted(eng.pool.resident ^ set(owners))
                    who = ", ".join(f"{s!r}{self._attempt(s)}"
                                    for s in drift[:5])
                    errs.append(f"engine {w}: resident sessions != "
                                f"slot owners — drift: {who}")
                for sid, i in sorted(owners.items()):
                    if eng.pool.lens.get(sid) != eng.slots[i].length:
                        errs.append(
                            f"engine {w} slot {i}: block-table length "
                            f"{eng.pool.lens.get(sid)} != slot length "
                            f"{eng.slots[i].length}{self._attempt(sid)}")
                if eng.pool.used_blocks() > eng.pool.num_blocks:
                    errs.append(
                        f"engine {w}: parked blocks "
                        f"{eng.pool.used_blocks()} exceed nominal "
                        f"capacity {eng.pool.num_blocks}")
            for _, sid in rt.queues[w].snapshot():
                ses = rt.sessions.get(sid)
                if ses is None or ses.state != "queued":
                    st = None if ses is None else ses.state
                    errs.append(f"engine {w}: queued ticket for "
                                f"session in state {st!r}"
                                f"{self._attempt(sid)}")
        live = {sid for sid, s in rt.sessions.items()
                if s.state in ("prefill", "decode")}
        if set(rt.inflight) != live:
            drift = sorted(set(rt.inflight) ^ live)
            who = ", ".join(f"{s!r}{self._attempt(s)}" for s in drift)
            errs.append(f"inflight registry != prefill/decode "
                        f"sessions — drift: {who}")
        for sid, (ew, att) in sorted(rt.inflight.items()):
            ses = rt.sessions.get(sid)
            if ses is not None and (ses.engine != ew
                                    or ses.attempt != att):
                errs.append(f"inflight stamp ({ew}, {att}) stale vs "
                            f"session (engine={ses.engine}, "
                            f"attempt={ses.attempt}) for {sid!r}")
        if rt.disagg:
            # cross-pool in-transit state: a staged job's blocks really
            # exist on its prefill engine with exactly the staged token
            # count; placed-but-unstaged jobs hold a reservation on a
            # prefill engine; pending jobs hold nothing anywhere
            for sid, job in sorted(rt._pf.jobs.items()):
                p = job.p_engine
                if job.state == "staged":
                    pool = rt.engines[p].pool
                    if pool.lens.get(sid) != job.n_stage:
                        errs.append(
                            f"handoff job {sid!r}: staged on engine {p} "
                            f"but pool holds "
                            f"{pool.lens.get(sid)} tokens, job staged "
                            f"{job.n_stage}")
                elif job.state == "prefill":
                    if p not in rt._pf.reserved or p < 0:
                        errs.append(f"handoff job {sid!r}: placed on "
                                    f"non-prefill engine {p}")
                elif job.state == "pending":
                    if p != -1 or sid not in rt._pf.pending:
                        errs.append(f"handoff job {sid!r}: pending but "
                                    f"p_engine={p}, in FIFO: "
                                    f"{sid in rt._pf.pending}")
            for p, r in sorted(rt._pf.reserved.items()):
                if r < 0:
                    errs.append(f"engine {p}: negative staging "
                                f"reservation {r}")
        if errs:
            raise SanitizerError(
                f"sanitizer: conservation violated after event "
                f"{kind!r} args={args!r} at t={t:.6f} "
                f"(event #{self.events_checked}):\n  "
                + "\n  ".join(errs))
