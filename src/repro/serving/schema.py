"""The stats() / summarize() key vocabulary, as one documented schema.

The counters grew organically across PRs 5-9 (copy-byte accounting,
fault/preemption keys, disagg handoff keys); this module is now the
single source of truth.  Contracts:

  * ``ServingRuntime.summarize()`` is the BYTE-IDENTITY surface — its
    repr is pinned by committed fingerprints.  Base keys appear always;
    ``fault`` keys only when a fault plan or preemption is active and
    ``disagg`` keys only in disaggregated mode, so pre-existing pins
    never see new keys.  Changing this schema means regenerating pins.
  * ``ServingRuntime.stats()`` is additive-only: consumers read by
    name, keys may be added freely (``validate_stats`` checks presence
    + type of the documented set, tolerating extras).
  * ``AsyncServingDriver.wall_stats`` is the wall-clock sidecar — new
    keys land here, never in ``summarize()``.

``tests/test_schema.py`` holds a live runtime to this file, so a key
added in code without a schema row fails CI before it can drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

#: condition labels for summarize() keys
ALWAYS = "always"
FAULT = "fault_or_preempt"     # fault_plan given or enable_preemption
DISAGG = "disagg"              # SAGAConfig.disaggregate


@dataclass(frozen=True)
class KeySpec:
    """One documented stats/summary key."""
    name: str
    type: type                 # int | float
    when: str                  # ALWAYS / FAULT / DISAGG
    doc: str


STATS_SCHEMA: Tuple[KeySpec, ...] = (
    KeySpec("prefill_tokens", int, ALWAYS,
            "tokens prefilled across engines (incl. regeneration)"),
    KeySpec("regen_tokens", int, ALWAYS,
            "prefill tokens that were pure cache-miss regeneration"),
    KeySpec("decode_steps", int, ALWAYS,
            "batched decode rounds executed across engines"),
    KeySpec("coordinator_hits", int, ALWAYS,
            "admissions whose KV was found (WA-LRU hit)"),
    KeySpec("coordinator_misses", int, ALWAYS,
            "admissions that had to regenerate"),
    KeySpec("park_copy_bytes", int, ALWAYS,
            "device bytes copied parking KV (0 in paged mode)"),
    KeySpec("resume_copy_bytes", int, ALWAYS,
            "device bytes copied resuming KV (0 in paged mode)"),
    KeySpec("migration_copy_bytes", int, ALWAYS,
            "device bytes moved pool-to-pool by work stealing"),
    KeySpec("steals", int, ALWAYS, "accepted work-steal decisions"),
    KeySpec("migrations", int, ALWAYS, "completed KV migrations"),
    KeySpec("prefetch_copies", int, ALWAYS,
            "speculative prefetch block replications"),
    KeySpec("faults_injected", int, ALWAYS,
            "engine fail/recover events applied"),
    KeySpec("cancelled_attempts", int, ALWAYS,
            "in-flight steps cancelled by faults/preemption"),
    KeySpec("preemptions", int, ALWAYS,
            "running decodes parked by AFS preemption"),
    KeySpec("afs_dev_max", float, ALWAYS,
            "max |service - fair target| over the run (seconds)"),
    KeySpec("kv_handoff_bytes", int, ALWAYS,
            "bytes moved prefill-pool -> decode-pool (disagg)"),
    KeySpec("handoff_count", int, ALWAYS, "completed KV handoffs"),
    KeySpec("handoffs_cancelled", int, ALWAYS,
            "handoffs cancelled by faults/capacity races"),
    KeySpec("prefetch_role_rejected", int, ALWAYS,
            "prefetches refused because the target was prefill-role"),
)

SUMMARY_SCHEMA: Tuple[KeySpec, ...] = (
    KeySpec("n_sessions", int, ALWAYS, "sessions submitted"),
    KeySpec("n_done", int, ALWAYS, "sessions finished"),
    KeySpec("tct_mean", float, ALWAYS, "mean task completion time (s)"),
    KeySpec("tct_p50", float, ALWAYS, "median TCT (s)"),
    KeySpec("tct_p99", float, ALWAYS, "p99 TCT (s)"),
    KeySpec("makespan", float, ALWAYS, "last finish time (virtual s)"),
    KeySpec("prefill_tokens", int, ALWAYS, "see stats()"),
    KeySpec("regen_tokens", int, ALWAYS, "see stats()"),
    KeySpec("decode_rounds", int, ALWAYS, "stats() decode_steps"),
    KeySpec("decoded_tokens", int, ALWAYS,
            "tokens emitted across all step outputs"),
    KeySpec("cache_hits", int, ALWAYS, "stats() coordinator_hits"),
    KeySpec("cache_misses", int, ALWAYS, "stats() coordinator_misses"),
    KeySpec("steals", int, ALWAYS, "see stats()"),
    KeySpec("migrations", int, ALWAYS, "see stats()"),
    KeySpec("prefetch_issued", int, ALWAYS, "prefetches scheduled"),
    KeySpec("prefetch_correct", int, ALWAYS,
            "prefetches whose prediction was used"),
    KeySpec("prefetch_copies", int, ALWAYS, "see stats()"),
    KeySpec("prefetch_wasted_bytes", float, ALWAYS,
            "replicated bytes never used"),
    KeySpec("faults_injected", int, FAULT, "see stats()"),
    KeySpec("cancelled_attempts", int, FAULT, "see stats()"),
    KeySpec("preemptions", int, FAULT, "see stats()"),
    KeySpec("afs_dev_max", float, FAULT, "see stats()"),
    KeySpec("handoffs", int, DISAGG, "stats() handoff_count"),
    KeySpec("handoff_bytes", float, DISAGG, "stats() kv_handoff_bytes"),
    KeySpec("handoffs_cancelled", int, DISAGG, "see stats()"),
    KeySpec("prefill_jobs", int, DISAGG,
            "prefill-pool jobs submitted"),
    KeySpec("speculative_prefills", int, DISAGG,
            "prefills started inside tool gaps"),
    KeySpec("prefill_deferred", int, DISAGG,
            "prefill jobs deferred for capacity"),
    KeySpec("prefetch_role_rejected", int, DISAGG, "see stats()"),
)

WALL_SCHEMA: Tuple[KeySpec, ...] = (
    KeySpec("events", int, ALWAYS, "events dispatched by the driver"),
    KeySpec("max_lag_s", float, ALWAYS,
            "worst wall lag behind the pacing deadline"),
    KeySpec("wall_elapsed_s", float, ALWAYS, "wall duration of the run"),
    KeySpec("submitted", int, ALWAYS,
            "submissions through the driver (not the runtime total)"),
)

_BOOLS_OK = {int: (int,), float: (float, int)}


def _check(schema: Tuple[KeySpec, ...], d: Dict[str, object],
           what: str) -> None:
    errs = []
    by_name = {k.name: k for k in schema}
    for name in sorted(d):
        spec = by_name.get(name)
        if spec is None:
            errs.append(f"{name!r} present but not in the schema")
        elif not isinstance(d[name], _BOOLS_OK[spec.type]) \
                or isinstance(d[name], bool):
            errs.append(f"{name!r} is {type(d[name]).__name__}, schema "
                        f"says {spec.type.__name__}")
    if errs:
        raise AssertionError(f"{what} diverges from "
                             "repro.serving.schema: " + "; ".join(errs))


def validate_stats(stats: Dict[str, object]) -> None:
    """Every documented stats() key present with the documented type;
    undocumented keys are an error (add a KeySpec when adding a key)."""
    missing = sorted(set(k.name for k in STATS_SCHEMA) - set(stats))
    if missing:
        raise AssertionError(f"stats() missing documented keys {missing}")
    _check(STATS_SCHEMA, stats, "stats()")


def validate_summary(summary: Dict[str, object], *,
                     fault: bool = False, disagg: bool = False) -> None:
    """summarize() keys must be EXACTLY the schema rows whose condition
    is active — order included (the repr is the byte-pin)."""
    want = [k.name for k in SUMMARY_SCHEMA
            if k.when == ALWAYS or (fault and k.when == FAULT)
            or (disagg and k.when == DISAGG)]
    got = list(summary)
    if got != want:
        raise AssertionError(
            f"summarize() keys {got} != schema expectation {want}")
    _check(SUMMARY_SCHEMA, summary, "summarize()")


def validate_wall_stats(ws: Dict[str, object]) -> None:
    _check(WALL_SCHEMA, ws, "wall_stats")
