"""Disaggregated prefill/decode engine pools (ROADMAP item 2).

In the unified pool a 1000-token agent-context resume shares an engine
with latency-critical decode rounds, so one long prefill stalls a whole
batch.  With ``SAGAConfig.disaggregate`` on, the serving runtime splits
its engines into roles:

  * **prefill** engines never hold decode slots, never appear in Eq. 7
    routing or the work stealer's idle set, and own no coordinator pool
    metadata.  Their ``PagedKVPool`` is a *staging area*: a prefill job
    computes the step's delta (or full-context) KV standalone — the
    causal mask makes a delta prefill independent of where the parked
    prefix lives, so the staged blocks are bit-identical to what the
    decode engine would have produced — and parks it awaiting handoff.
  * **decode** engines run the classic runtime lifecycle (slots, queues,
    batched rounds, park-on-tool, WA-LRU/TTL, stealing, prefetch).
    Eq. 7 affinity routing decides decode placement only.

The :class:`PrefillScheduler` owns the prefill pool: jobs are placed on
the least-backlogged live prefill engine (a per-engine serial virtual
server, ``avail_at``), gated on staging capacity so ``stage_prefill``
can never fail; jobs that do not fit wait in a FIFO and drain as
handoffs release staged blocks.  Completed prefill KV hands off to the
routed decode engine over the block-granular ``export_kv`` /
``import_handoff`` path; the transfer window is deterministic
(bytes / ``handoff_bytes_per_s`` + a latency floor — no RNG, so disagg
runs stay byte-identical across processes and ``PYTHONHASHSEED``).

Speculative *prefill*: the next step's prompt is resolved at the park
boundary (``resolve_next``), so the runtime submits the prefill job at
tool-gap START — prefill and handoff overlap the gap, generalizing
speculative prefetch, and a resume whose handoff already landed joins a
decode slot with zero prefill on the critical path.

Fault matrix (see ``docs/DISAGG.md``): every job is attempt-stamped, so
a prefill engine dying mid-handoff invalidates the pending
``pf_done`` / ``handoff_done`` events, reclaims staged blocks on both
sides, and the session re-prefills on a live engine — token-identical,
because the staged KV is a pure function of the context tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

ROLE_PREFILL = "prefill"
ROLE_DECODE = "decode"
ROLE_UNIFIED = "unified"

ROLES = (ROLE_PREFILL, ROLE_DECODE, ROLE_UNIFIED)


def default_roles(n_workers: int) -> List[str]:
    """Default disagg split: one prefill engine per four workers (at
    least one), the rest decode.  Prefill engines take the LOW indices
    so a fault plan targeting worker 0 exercises the prefill-death
    path."""
    n_prefill = max(1, n_workers // 4)
    if n_prefill >= n_workers:
        raise ValueError(
            f"disaggregation needs >= 2 engines, got {n_workers}")
    return [ROLE_PREFILL] * n_prefill \
        + [ROLE_DECODE] * (n_workers - n_prefill)


@dataclasses.dataclass
class HandoffJob:
    """One step's prefill-pool work item: compute KV for
    ``tokens[start:]`` on a prefill engine, stage it, hand the blocks
    off to decode engine ``d_engine``.  ``attempt`` stamps the job's
    ``pf_done``/``handoff_done`` events — a fault bumps the registry,
    and stale events no longer match (the runtime's inflight-registry
    pattern, applied to the handoff lifecycle)."""
    session_id: str
    attempt: int
    d_engine: int                 # Eq. 7 decode placement (routed at admit)
    start: int                    # first token to prefill (0 = full regen)
    tokens: List[int]             # full step context snapshot
    pf_tokens: float              # policy-visible prefill length (virtual)
    speculative: bool             # submitted at tool-gap start
    p_engine: int = -1            # assigned prefill engine (-1 = pending)
    state: str = "pending"        # pending | prefill | staged
    waiting: bool = False         # gap over: dispatch as soon as KV lands

    @property
    def n_stage(self) -> int:
        """Tokens staged on the prefill engine (delta or full ctx)."""
        return len(self.tokens) - self.start


class PrefillScheduler:
    """Deterministic prefill-pool scheduler.

    Placement: among alive prefill engines whose staging pool can hold
    the job (counting blocks already reserved by admitted-but-unstaged
    jobs), pick the earliest ``(avail_at, engine_id)`` — a serial
    virtual server per engine, mirroring how ``RuntimePerf`` models one
    prefill stream per worker.  Jobs that fit nowhere wait in
    ``pending`` (FIFO) and are re-tried whenever staged blocks are
    released.  All state is plain dicts/lists keyed by session id and
    engine id — no hash-order or RNG dependence anywhere."""

    def __init__(self, prefill_engines: Sequence[int]) -> None:
        self.prefill_engines: List[int] = sorted(prefill_engines)
        self.avail_at: Dict[int, float] = {p: 0.0
                                           for p in self.prefill_engines}
        # blocks promised to admitted jobs that have not staged yet
        self.reserved: Dict[int, int] = {p: 0
                                         for p in self.prefill_engines}
        self.jobs: Dict[str, HandoffJob] = {}
        self.pending: List[str] = []
        # counters (surfaced via ServingRuntime.stats / summarize)
        self.submitted = 0
        self.speculative = 0
        self.deferred = 0

    # -- job lifecycle ---------------------------------------------------
    def submit(self, job: HandoffJob) -> None:
        assert job.session_id not in self.jobs, \
            f"duplicate prefill job for {job.session_id!r}"
        self.jobs[job.session_id] = job
        self.submitted += 1
        if job.speculative:
            self.speculative += 1

    def place(self, job: HandoffJob, now: float, pools,
              alive: Sequence[bool]) -> Optional[Tuple[int, float]]:
        """Assign ``job`` to a prefill engine.  Returns (engine,
        start_time) and reserves the staging blocks, or None when no
        live prefill engine has capacity (caller queues the job in
        ``pending``)."""
        best: Optional[Tuple[float, int]] = None
        need = 0
        for p in self.prefill_engines:
            if not alive[p]:
                continue
            pool = pools[p]
            need = pool._blocks_for(job.n_stage)
            if self.reserved[p] + need > \
                    pool.num_blocks - pool.used_blocks():
                continue
            key = (max(self.avail_at[p], now), p)
            if best is None or key < best:
                best = key
        if best is None:
            return None
        t0, p = best
        self.reserved[p] += pools[p]._blocks_for(job.n_stage)
        job.p_engine = p
        job.state = "prefill"
        return p, t0

    def defer(self, job: HandoffJob) -> None:
        """No capacity anywhere: FIFO-queue the job for the next staged
        -block release."""
        assert job.state == "pending" and job.p_engine == -1
        self.pending.append(job.session_id)
        self.deferred += 1

    def note_busy_until(self, p: int, t: float) -> None:
        self.avail_at[p] = t

    def staged(self, job: HandoffJob, pools) -> None:
        """The job's KV landed in the staging pool: its reservation is
        now real ``used_blocks`` and must stop double-counting."""
        assert job.state == "prefill"
        self.unreserve(job, pools)
        job.state = "staged"

    def unreserve(self, job: HandoffJob, pools) -> None:
        """Return an un-staged job's block reservation (cancel path, or
        the moment staging converts it to real usage).  Staged jobs hold
        no reservation — their blocks are freed through the pool."""
        if job.state == "prefill" and job.p_engine in self.reserved:
            self.reserved[job.p_engine] = max(
                0, self.reserved[job.p_engine]
                - pools[job.p_engine]._blocks_for(job.n_stage))

    def pop(self, sid: str) -> Optional[HandoffJob]:
        """Remove a job from the registry (handoff complete or
        cancelled) and from the pending FIFO if it never placed."""
        job = self.jobs.pop(sid, None)
        if job is not None and sid in self.pending:
            self.pending.remove(sid)
        return job

    def drain(self, now: float, pools,
              alive: Sequence[bool]) -> List[Tuple[HandoffJob, int,
                                                   float]]:
        """Re-try every pending job in FIFO order after staged blocks
        were released (or a prefill engine recovered).  Returns the
        newly-placed (job, engine, start_time) triples; unplaced jobs
        keep their FIFO position."""
        placed: List[Tuple[HandoffJob, int, float]] = []
        still: List[str] = []
        for sid in self.pending:
            job = self.jobs.get(sid)
            if job is None:
                continue
            got = self.place(job, now, pools, alive)
            if got is None:
                still.append(sid)
            else:
                placed.append((job, got[0], got[1]))
        self.pending = still
        return placed

    def jobs_touching(self, w: int) -> List[HandoffJob]:
        """Jobs whose prefill OR decode engine is ``w`` — the fault
        path's cancellation set, deterministic order."""
        return [self.jobs[sid] for sid in sorted(self.jobs)
                if self.jobs[sid].p_engine == w
                or self.jobs[sid].d_engine == w]

    def staged_on(self, w: int) -> set:
        """Session ids whose staged (in-transit) blocks live on engine
        ``w`` — the sanitizer's cross-pool exemption set: these parked
        blocks deliberately have no coordinator pool metadata."""
        return {sid for sid, job in self.jobs.items()
                if job.p_engine == w and job.state == "staged"}
