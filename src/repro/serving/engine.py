"""Single-worker serving engine: continuous batching over decode slots +
paged KV living in pool blocks from admit to finish.

The engine executes REAL forward passes (jitted prefill / batched decode)
against a model from the zoo.  In the default **paged** mode a session's
KV lands in `PagedKVPool` blocks at admit (prefill scatters straight
into blocks), the batched decode step attends over per-slot block tables
and appends each new token's K/V into the tail block on device, and
park/resume/preempt are pure metadata flips — zero device copies.  A
decode slot is just a batch-row binding, so co-residency is bounded by
pool memory, not slot-cache memory.

``Engine(paged=False)`` keeps the original gather path as the reference
oracle: contiguous per-slot caches, park/resume as real pool<->slot
copies.  Both modes share the same prefill, the same policy-visible
capacity arithmetic, and (by construction of the masked attention) emit
bit-identical token ids — `tests/test_paged_decode.py` gates this per
architecture family.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.sharding import ShardingEnv
from repro.serving.kvcache import PagedKVPool


# jitted prefill specializes on sequence length: bucket lengths so a
# trace-driven workload compiles O(max_len / bucket) programs, not one
# per distinct prompt length.  Engines pad to lcm(bucket, block_size)
# so a compile bucket never splits a KV block (PagedKVPool.extend
# asserts this invariant).
_PREFILL_BUCKET = 32

# one jitted (decode, prefill, paged-decode) triple per (config,
# sharding-options) — engines of the same model share compiled code
# instead of each instance re-tracing through its own bound-method
# closures (a multi-engine runtime otherwise pays the full compile set
# per engine)
_JIT_CACHE: Dict[tuple, tuple] = {}


def _jitted_fns(cfg: ModelConfig, env: ShardingEnv):
    if env.mesh is not None:
        key = None          # meshes aren't value-hashable: no sharing
    else:
        key = (cfg, tuple(sorted(env.opts.items())))
    try:
        fns = _JIT_CACHE.get(key) if key is not None else None
    except TypeError:       # unhashable opt value: no sharing
        key, fns = None, None
    if fns is None:
        def decode_fn(params, tokens, cache, positions):
            return lm.decode_step(params, tokens, cache, positions, cfg,
                                  env)

        def prefill_fn(params, tokens, pad_to):
            batch = {"tokens": tokens}
            if cfg.family == "vlm":
                # text-only serving of a VLM: zero-length patch stream
                # (patches are pre-projected d_model embeddings
                # concatenated before the tokens, so an empty one is
                # exact, not an approximation)
                batch["patches"] = jnp.zeros(
                    (tokens.shape[0], 0, cfg.d_model), jnp.bfloat16)
            return lm.prefill(params, batch, cfg, env, max_len=pad_to)

        def paged_decode_fn(params, tokens, k_pool, v_pool, tables,
                            positions, block_ids, offsets):
            return lm.decode_step_paged(params, tokens, k_pool, v_pool,
                                        tables, positions, block_ids,
                                        offsets, cfg, env)

        fns = (jax.jit(decode_fn),
               jax.jit(prefill_fn, static_argnames=("pad_to",)),
               jax.jit(paged_decode_fn))
        if key is not None:
            _JIT_CACHE[key] = fns
    return fns


@dataclasses.dataclass
class SlotState:
    session_id: Optional[str] = None
    length: int = 0                 # tokens currently cached for the slot


class Engine:
    """Decode slots + prefill + park/resume for one worker."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 64,
                 block_size: int = 16, env: Optional[ShardingEnv] = None,
                 paged: bool = True):
        assert not cfg.enc_dec and cfg.family in ("dense", "moe", "vlm"), \
            "engine demo supports decoder-only KV families"
        assert not cfg.use_mla, \
            "engine KV paths assume the GQA (k, v) cache layout"
        self.cfg = cfg
        self.params = params
        self.env = env or ShardingEnv(None, opts={"remat": False,
                                                  "sp": False,
                                                  "moe_impl": "dense"})
        self.n_slots = n_slots
        self.max_len = max_len
        self.paged = paged
        self.slots = [SlotState() for _ in range(n_slots)]
        if paged:
            assert max_len % block_size == 0, \
                "paged decode needs max_len to be a whole number of blocks"
            self.max_nb = max_len // block_size
            # resident headroom: every slot can hold a max_len session in
            # blocks without ever competing with the parked population,
            # so policy-visible capacity stays identical to gather mode
            headroom = n_slots * self.max_nb
            self.cache = None
        else:
            self.max_nb = 0
            headroom = 0
            self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.pool = PagedKVPool(cfg.n_layers, pool_blocks, block_size,
                                cfg.n_kv_heads, cfg.head_dim,
                                headroom_blocks=headroom)
        # prefill compile quantum: a whole number of blocks AND of the
        # base bucket, so a bucket boundary never splits a tail block
        self._prefill_quantum = (_PREFILL_BUCKET * block_size
                                 // math.gcd(_PREFILL_BUCKET, block_size))
        # stats
        self.prefill_tokens = 0
        self.regen_tokens = 0
        self.decode_steps = 0
        # device-copy accounting for the park/resume/migration paths
        # (paged mode: park/resume are metadata-only and stay 0)
        self.park_copy_bytes = 0
        self.resume_copy_bytes = 0
        self.migration_copy_bytes = 0
        # prefill->decode handoff transport (disaggregated pools):
        # counted separately from migration so the A/B stays legible
        self.handoff_copy_bytes = 0

        (self._jit_decode, self._jit_prefill,
         self._jit_paged_decode) = _jitted_fns(self.cfg, self.env)

    # -- slot management -----------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.session_id is None:
                return i
        return None

    def used_slots(self) -> int:
        """Occupied decode slots (ground truth for load reporting and
        the runtime's conservation checks)."""
        return sum(1 for s in self.slots if s.session_id is not None)

    def _write_slot(self, slot: int, k, v, length: int) -> None:
        """k/v: (L, S, K, dh) -> into the batched decode cache."""
        pad = self.max_len - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        self.cache["k"] = self.cache["k"].at[:, slot].set(k)
        self.cache["v"] = self.cache["v"].at[:, slot].set(v)
        self.slots[slot].length = length

    def _prefill_kv(self, tokens: np.ndarray):
        """Prefill ``tokens`` and return (k, v) of shape (L, n, K, dh).

        Token length is padded up to the compile quantum — lcm(32-token
        bucket, block size) — so the jitted prefill compiles O(max_len /
        quantum) programs and a bucket boundary never splits a KV
        block.  Padding is exact under the causal mask: positions < n
        attend to the same key set either way, so their KV is
        bit-identical."""
        n = len(tokens)
        pad_to = min(self.max_len, -(-n // self._prefill_quantum)
                     * self._prefill_quantum)
        pad_to = max(pad_to, n)
        padded = np.zeros(pad_to, np.int32)
        padded[:n] = tokens
        _, cache = self._jit_prefill(self.params, jnp.asarray(padded[None]),
                                     pad_to=pad_to)
        return cache["k"][:, 0, :n], cache["v"][:, 0, :n]

    # -- public API ------------------------------------------------------------
    def start_session(self, sid: str, tokens: np.ndarray,
                      cached_hit: bool) -> Optional[int]:
        """Admit a session: resume parked KV if present (prefill only the
        delta) else full prefill.  Returns the slot id, or ``None`` when
        every slot is occupied — the caller (the serving runtime) queues
        the session instead of crashing."""
        slot = self.free_slot()
        if slot is None:
            return None
        tokens = np.asarray(tokens, np.int32)
        if self.paged:
            self._admit_paged(sid, tokens, cached_hit)
            self.slots[slot] = SlotState(sid, len(tokens))
        else:
            self._admit_gather(slot, sid, tokens, cached_hit)
            self.slots[slot].session_id = sid
        return slot

    def _admit_paged(self, sid: str, tokens: np.ndarray,
                     cached_hit: bool) -> None:
        """Land the session's KV in pool blocks.  A cached hit is a pure
        metadata flip (parked -> resident) plus a delta prefill scattered
        straight into blocks; a miss allocates at admit and prefills the
        full context into blocks.  No gather, no slot copy — resume-copy
        bytes stay 0."""
        pool = self.pool
        if cached_hit and pool.has(sid):
            n = pool.lens[sid]
            pool.mark_resident(sid)
            delta = tokens[n:]
            if len(delta):
                dk, dv = self._prefill_kv(delta)
                pool.extend(sid, dk, dv, bucket=self._prefill_quantum)
                self.prefill_tokens += len(delta)
        else:
            pool.alloc(sid)
            k, v = self._prefill_kv(tokens)
            pool.extend(sid, k, v, bucket=self._prefill_quantum)
            self.prefill_tokens += len(tokens)
            self.regen_tokens += len(tokens)

    def _admit_gather(self, slot: int, sid: str, tokens: np.ndarray,
                      cached_hit: bool) -> None:
        """Reference path: gather parked blocks into the contiguous
        per-slot cache (an O(context-bytes) resume copy)."""
        resumed = self.pool.resume(sid) if cached_hit else None
        if resumed is not None:
            k, v, n = resumed
            self.resume_copy_bytes += self.pool.session_bytes(sid)
            delta = tokens[n:]
            self.pool.free_session(sid)
            if len(delta):
                dk, dv = self._prefill_kv(delta)
                k = jnp.concatenate([k, dk], axis=1)
                v = jnp.concatenate([v, dv], axis=1)
                self.prefill_tokens += len(delta)
            self._write_slot(slot, k, v, len(tokens))
        else:
            k, v = self._prefill_kv(tokens)
            self.prefill_tokens += len(tokens)
            self.regen_tokens += len(tokens)
            self._write_slot(slot, k, v, len(tokens))

    def decode(self, slot_tokens: Dict[int, int], n_steps: int = 1,
               greedy: bool = True) -> Dict[int, List[int]]:
        """Run `n_steps` batched decode steps for the given slots.
        slot_tokens: {slot: next input token id}.  Returns generated ids
        per slot."""
        if self.paged:
            return self._decode_paged(slot_tokens, n_steps)
        out: Dict[int, List[int]] = {s: [] for s in slot_tokens}
        cur = dict(slot_tokens)
        for _ in range(n_steps):
            tok = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            for s, t in cur.items():
                tok[s, 0] = t
                pos[s] = self.slots[s].length
            logits, self.cache = self._jit_decode(
                self.params, jnp.asarray(tok), self.cache,
                jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for s in cur:
                self.slots[s].length += 1
                out[s].append(int(nxt[s]))
                cur[s] = int(nxt[s])
            self.decode_steps += 1
        return out

    def _decode_paged(self, slot_tokens: Dict[int, int],
                      n_steps: int) -> Dict[int, List[int]]:
        """Batched decode attending directly over pool block tables.
        Each step appends the new K/V into the tail block on device;
        idle batch rows carry an out-of-range append sentinel so they
        write nowhere."""
        out: Dict[int, List[int]] = {s: [] for s in slot_tokens}
        cur = dict(slot_tokens)
        pool = self.pool
        sentinel = pool.total_blocks
        for _ in range(n_steps):
            tok = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            tables = np.zeros((self.n_slots, self.max_nb), np.int32)
            ablk = np.full((self.n_slots,), sentinel, np.int32)
            aoff = np.zeros((self.n_slots,), np.int32)
            for s, t in cur.items():
                sid = self.slots[s].session_id
                pool.ensure_tail_room(sid)
                tok[s, 0] = t
                pos[s] = self.slots[s].length
                tbl = pool.tables[sid]
                tables[s, :len(tbl)] = tbl
                ablk[s], aoff[s] = pool.tail_slot(sid)
            logits, pool.k_pool, pool.v_pool = self._jit_paged_decode(
                self.params, jnp.asarray(tok), pool.k_pool, pool.v_pool,
                jnp.asarray(tables), jnp.asarray(pos),
                jnp.asarray(ablk), jnp.asarray(aoff))
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for s in cur:
                pool.append_token(self.slots[s].session_id)
                self.slots[s].length += 1
                out[s].append(int(nxt[s]))
                cur[s] = int(nxt[s])
            self.decode_steps += 1
        return out

    def park_session(self, sid: str) -> bool:
        """Session pauses for a tool call.  Paged mode: metadata-only —
        the blocks already live in the pool, parking just flips the
        session from resident to parked accounting (on False the slot
        keeps its binding so ``release_session`` still frees the
        blocks).  Gather mode: copy the slot KV into pool blocks."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s.session_id == sid), None)
        if slot is None:
            return False
        if self.paged:
            if not self.pool.park_resident(sid):
                return False
            self.slots[slot] = SlotState()
            return True
        n = self.slots[slot].length
        k = self.cache["k"][:, slot]
        v = self.cache["v"][:, slot]
        ok = self.pool.park(sid, k, v, n)
        if ok:
            self.park_copy_bytes += self.pool.session_bytes(sid)
        self.slots[slot] = SlotState()
        return ok

    def release_session(self, sid: str) -> bool:
        """Free a session's slot WITHOUT parking its KV (task finished:
        nothing will resume).  In paged mode this returns the resident
        blocks to the free list — still metadata-only."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s.session_id == sid), None)
        if slot is None:
            return False
        if self.paged and sid in self.pool.resident:
            self.pool.free_session(sid)
        self.slots[slot] = SlotState()
        return True

    # -- KV export/import (cross-engine migration + prefetch copies) --------
    def export_kv(self, sid: str) -> Optional[Tuple[jnp.ndarray,
                                                    jnp.ndarray, int]]:
        """Gather a parked session's KV to contiguous (L, n, K, dh)
        WITHOUT freeing its blocks — the transport half of a pool-to-pool
        copy (work-steal migration, speculative prefetch).  Only the
        owned blocks are copied."""
        return self.pool.resume(sid)

    def import_kv(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
                  n_tokens: int) -> bool:
        """Land an exported KV prefix into this engine's pool.  Returns
        False when the pool has no room (caller evicts and retries, or
        abandons the copy)."""
        ok = self.pool.park(sid, k, v, n_tokens)
        if ok:
            self.migration_copy_bytes += self.pool.session_bytes(sid)
        return ok

    # -- disaggregated prefill/decode handoff (serving/disagg.py) -----------
    def stage_prefill(self, sid: str, tokens: np.ndarray,
                      start: int) -> bool:
        """Prefill-role engines: compute KV for ``tokens[start:]``
        standalone (the causal mask makes a delta prefill independent of
        where the parked prefix lives — same jitted fn, same inputs,
        bit-identical KV) and stage it in this pool as a PARKED session
        awaiting handoff.  ``start == 0`` is a miss: the full context is
        regenerated here.  Returns False when the staging pool cannot
        fit — the PrefillScheduler gates admission on ``can_fit`` so
        this only trips under races it then defers."""
        delta = np.asarray(tokens[start:], np.int32)
        dk, dv = self._prefill_kv(delta)
        if not self.pool.park(sid, dk, dv, len(delta)):
            return False
        self.prefill_tokens += len(delta)
        if start == 0:
            self.regen_tokens += len(delta)
        return True

    def import_handoff(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
                       n_tokens: int, *, append: bool) -> bool:
        """Decode-role engines: land handed-off prefill KV.  ``append``
        (cache hit) extends the parked prefix in place; otherwise (miss)
        the full context parks fresh.  Returns False when the parked
        population would overflow nominal capacity — the runtime evicts
        and retries, or cancels the handoff."""
        if append:
            ok = self.pool.extend_parked(sid, k, v, n_tokens)
        else:
            ok = self.pool.park(sid, k, v, n_tokens)
        if ok:
            self.handoff_copy_bytes += int(n_tokens) * \
                (self.pool.bytes_per_block // self.pool.block)
        return ok

    def evict_session(self, sid: str) -> None:
        """Policy eviction of parked blocks.  A resident session's
        blocks are pinned by its slot (mirroring gather mode, where a
        resumed session holds no pool blocks at all): no-op until the
        slot releases them."""
        if self.paged and sid in self.pool.resident:
            return
        self.pool.free_session(sid)

    def fail(self) -> List[str]:
        """Engine crash: every decode slot and every parked session is
        lost at once.  Clears the slot table and the block tables (the
        device arrays stay allocated — new sessions overwrite them, and
        an empty slot/table means no decode or resume can read stale
        KV).  Returns the session ids whose state was held here, sorted,
        so the runtime can cancel their in-flight attempts."""
        lost = {s.session_id for s in self.slots
                if s.session_id is not None}
        lost.update(self.pool.tables)
        self.slots = [SlotState() for _ in range(self.n_slots)]
        for sid in list(self.pool.tables):
            self.pool.free_session(sid)
        return sorted(lost)

    def has_cache(self, sid: str) -> bool:
        return self.pool.has(sid)

    def pool_used_fraction(self) -> float:
        return self.pool.used_blocks() / self.pool.num_blocks
