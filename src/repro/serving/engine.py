"""Single-worker serving engine: continuous batching over decode slots +
paged park/resume of idle session KV.

The engine executes REAL forward passes (jitted prefill / batched decode)
against a model from the zoo.  Idle sessions park their KV into the
PagedKVPool; WA-LRU/TTL decisions from the coordinator mutate only block
tables.  On TPU the decode hot loop is the Pallas paged-attention
kernel; on CPU we gather parked blocks into the contiguous decode cache
(same math — the kernels are validated against this path in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.sharding import ShardingEnv
from repro.serving.kvcache import PagedKVPool


# jitted prefill specializes on sequence length: bucket lengths so a
# trace-driven workload compiles O(max_len / bucket) programs, not one
# per distinct prompt length
_PREFILL_BUCKET = 32

# one jitted (decode, prefill) pair per (config, sharding-options) —
# engines of the same model share compiled code instead of each instance
# re-tracing through its own bound-method closures (a multi-engine
# runtime otherwise pays the full compile set per engine)
_JIT_CACHE: Dict[tuple, tuple] = {}


def _jitted_fns(cfg: ModelConfig, env: ShardingEnv):
    if env.mesh is not None:
        key = None          # meshes aren't value-hashable: no sharing
    else:
        key = (cfg, tuple(sorted(env.opts.items())))
    try:
        fns = _JIT_CACHE.get(key) if key is not None else None
    except TypeError:       # unhashable opt value: no sharing
        key, fns = None, None
    if fns is None:
        def decode_fn(params, tokens, cache, positions):
            return lm.decode_step(params, tokens, cache, positions, cfg,
                                  env)

        def prefill_fn(params, tokens, pad_to):
            return lm.prefill(params, {"tokens": tokens}, cfg, env,
                              max_len=pad_to)

        fns = (jax.jit(decode_fn),
               jax.jit(prefill_fn, static_argnames=("pad_to",)))
        if key is not None:
            _JIT_CACHE[key] = fns
    return fns


@dataclasses.dataclass
class SlotState:
    session_id: Optional[str] = None
    length: int = 0                 # tokens currently in the slot cache


class Engine:
    """Decode slots + prefill + park/resume for one worker."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 64,
                 block_size: int = 16, env: Optional[ShardingEnv] = None):
        assert not cfg.enc_dec and cfg.family in ("dense", "moe", "vlm"), \
            "engine demo supports decoder-only KV families"
        self.cfg = cfg
        self.params = params
        self.env = env or ShardingEnv(None, opts={"remat": False,
                                                  "sp": False,
                                                  "moe_impl": "dense"})
        self.n_slots = n_slots
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(n_slots)]
        self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.pool = PagedKVPool(cfg.n_layers, pool_blocks, block_size,
                                cfg.n_kv_heads, cfg.head_dim)
        # stats
        self.prefill_tokens = 0
        self.regen_tokens = 0
        self.decode_steps = 0

        self._jit_decode, self._jit_prefill = _jitted_fns(self.cfg,
                                                          self.env)

    # -- slot management -----------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.session_id is None:
                return i
        return None

    def used_slots(self) -> int:
        """Occupied decode slots (ground truth for load reporting and
        the runtime's conservation checks)."""
        return sum(1 for s in self.slots if s.session_id is not None)

    def _write_slot(self, slot: int, k, v, length: int) -> None:
        """k/v: (L, S, K, dh) -> into the batched decode cache."""
        pad = self.max_len - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        self.cache["k"] = self.cache["k"].at[:, slot].set(k)
        self.cache["v"] = self.cache["v"].at[:, slot].set(v)
        self.slots[slot].length = length

    def _prefill_kv(self, tokens: np.ndarray):
        """Prefill ``tokens`` and return (k, v) of shape (L, n, K, dh).

        Token length is padded up to a 32-token compile bucket (the
        jitted prefill specializes on sequence length, so unbucketed
        variable-length agent prompts recompile per distinct length).
        Padding is exact under the causal mask: positions < n attend to
        the same key set either way, so their KV is bit-identical."""
        n = len(tokens)
        pad_to = min(self.max_len, -(-n // _PREFILL_BUCKET)
                     * _PREFILL_BUCKET)
        pad_to = max(pad_to, n)
        padded = np.zeros(pad_to, np.int32)
        padded[:n] = tokens
        _, cache = self._jit_prefill(self.params, jnp.asarray(padded[None]),
                                     pad_to=pad_to)
        return cache["k"][:, 0, :n], cache["v"][:, 0, :n]

    # -- public API ------------------------------------------------------------
    def start_session(self, sid: str, tokens: np.ndarray,
                      cached_hit: bool) -> Optional[int]:
        """Admit a session: resume parked KV if present (prefill only the
        delta) else full prefill.  Returns the slot id, or ``None`` when
        every slot is occupied — the caller (the serving runtime) queues
        the session instead of crashing."""
        slot = self.free_slot()
        if slot is None:
            return None
        tokens = np.asarray(tokens, np.int32)
        resumed = self.pool.resume(sid) if cached_hit else None
        if resumed is not None:
            k, v, n = resumed
            delta = tokens[n:]
            self.pool.free_session(sid)
            if len(delta):
                dk, dv = self._prefill_kv(delta)
                k = jnp.concatenate([k, dk], axis=1)
                v = jnp.concatenate([v, dv], axis=1)
                self.prefill_tokens += len(delta)
            self._write_slot(slot, k, v, len(tokens))
        else:
            k, v = self._prefill_kv(tokens)
            self.prefill_tokens += len(tokens)
            self.regen_tokens += len(tokens)
            self._write_slot(slot, k, v, len(tokens))
        self.slots[slot].session_id = sid
        return slot

    def decode(self, slot_tokens: Dict[int, int], n_steps: int = 1,
               greedy: bool = True) -> Dict[int, List[int]]:
        """Run `n_steps` batched decode steps for the given slots.
        slot_tokens: {slot: next input token id}.  Returns generated ids
        per slot."""
        out: Dict[int, List[int]] = {s: [] for s in slot_tokens}
        cur = dict(slot_tokens)
        for _ in range(n_steps):
            tok = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            for s, t in cur.items():
                tok[s, 0] = t
                pos[s] = self.slots[s].length
            logits, self.cache = self._jit_decode(
                self.params, jnp.asarray(tok), self.cache,
                jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for s in cur:
                self.slots[s].length += 1
                out[s].append(int(nxt[s]))
                cur[s] = int(nxt[s])
            self.decode_steps += 1
        return out

    def park_session(self, sid: str) -> bool:
        """Session pauses for a tool call: move its slot KV to the pool."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s.session_id == sid), None)
        if slot is None:
            return False
        n = self.slots[slot].length
        k = self.cache["k"][:, slot]
        v = self.cache["v"][:, slot]
        ok = self.pool.park(sid, k, v, n)
        self.slots[slot] = SlotState()
        return ok

    def release_session(self, sid: str) -> bool:
        """Free a session's slot WITHOUT parking its KV (task finished:
        nothing will resume, pooling the blocks would be a wasted copy)."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s.session_id == sid), None)
        if slot is None:
            return False
        self.slots[slot] = SlotState()
        return True

    # -- KV export/import (cross-engine migration + prefetch copies) --------
    def export_kv(self, sid: str) -> Optional[Tuple[jnp.ndarray,
                                                    jnp.ndarray, int]]:
        """Gather a parked session's KV to contiguous (L, n, K, dh)
        WITHOUT freeing its blocks — the transport half of a pool-to-pool
        copy (work-steal migration, speculative prefetch)."""
        return self.pool.resume(sid)

    def import_kv(self, sid: str, k: jnp.ndarray, v: jnp.ndarray,
                  n_tokens: int) -> bool:
        """Land an exported KV prefix into this engine's pool.  Returns
        False when the pool has no room (caller evicts and retries, or
        abandons the copy)."""
        return self.pool.park(sid, k, v, n_tokens)

    def evict_session(self, sid: str) -> None:
        self.pool.free_session(sid)

    def fail(self) -> List[str]:
        """Engine crash: every decode slot and every parked session is
        lost at once.  Clears the slot table and the block tables (the
        device arrays stay allocated — new sessions overwrite them, and
        an empty slot/table means no decode or resume can read stale
        KV).  Returns the session ids whose state was held here, sorted,
        so the runtime can cancel their in-flight attempts."""
        lost = {s.session_id for s in self.slots
                if s.session_id is not None}
        lost.update(self.pool.tables)
        self.slots = [SlotState() for _ in range(self.n_slots)]
        for sid in list(self.pool.tables):
            self.pool.free_session(sid)
        return sorted(lost)

    def has_cache(self, sid: str) -> bool:
        return self.pool.has(sid)

    def pool_used_fraction(self) -> float:
        return self.pool.used_blocks() / self.pool.num_blocks
