"""Single-worker serving engine: continuous batching over decode slots +
paged park/resume of idle session KV.

The engine executes REAL forward passes (jitted prefill / batched decode)
against a model from the zoo.  Idle sessions park their KV into the
PagedKVPool; WA-LRU/TTL decisions from the coordinator mutate only block
tables.  On TPU the decode hot loop is the Pallas paged-attention
kernel; on CPU we gather parked blocks into the contiguous decode cache
(same math — the kernels are validated against this path in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.sharding import ShardingEnv
from repro.serving.kvcache import PagedKVPool


@dataclasses.dataclass
class SlotState:
    session_id: Optional[str] = None
    length: int = 0                 # tokens currently in the slot cache


class Engine:
    """Decode slots + prefill + park/resume for one worker."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 512, pool_blocks: int = 64,
                 block_size: int = 16, env: Optional[ShardingEnv] = None):
        assert not cfg.enc_dec and cfg.family in ("dense", "moe", "vlm"), \
            "engine demo supports decoder-only KV families"
        self.cfg = cfg
        self.params = params
        self.env = env or ShardingEnv(None, opts={"remat": False,
                                                  "sp": False,
                                                  "moe_impl": "dense"})
        self.n_slots = n_slots
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(n_slots)]
        self.cache = lm.init_cache(cfg, n_slots, max_len)
        self.pool = PagedKVPool(cfg.n_layers, pool_blocks, block_size,
                                cfg.n_kv_heads, cfg.head_dim)
        # stats
        self.prefill_tokens = 0
        self.regen_tokens = 0
        self.decode_steps = 0

        self._jit_decode = jax.jit(self._decode_fn)
        self._jit_prefill = jax.jit(self._prefill_fn,
                                    static_argnames=("pad_to",))

    # -- jitted kernels -----------------------------------------------------
    def _decode_fn(self, params, tokens, cache, positions):
        return lm.decode_step(params, tokens, cache, positions, self.cfg,
                              self.env)

    def _prefill_fn(self, params, tokens, pad_to):
        batch = {"tokens": tokens}
        return lm.prefill(params, batch, self.cfg, self.env, max_len=pad_to)

    # -- slot management -----------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.session_id is None:
                return i
        return None

    def _write_slot(self, slot: int, k, v, length: int) -> None:
        """k/v: (L, S, K, dh) -> into the batched decode cache."""
        pad = self.max_len - k.shape[1]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        self.cache["k"] = self.cache["k"].at[:, slot].set(k)
        self.cache["v"] = self.cache["v"].at[:, slot].set(v)
        self.slots[slot].length = length

    # -- public API ------------------------------------------------------------
    def start_session(self, sid: str, tokens: np.ndarray,
                      cached_hit: bool) -> int:
        """Admit a session: resume parked KV if present (prefill only the
        delta) else full prefill.  Returns the slot id."""
        slot = self.free_slot()
        assert slot is not None, "no free slots (caller must wait)"
        tokens = np.asarray(tokens, np.int32)
        resumed = self.pool.resume(sid) if cached_hit else None
        if resumed is not None:
            k, v, n = resumed
            delta = tokens[n:]
            self.pool.free_session(sid)
            if len(delta):
                _, dcache = self._jit_prefill(
                    self.params, jnp.asarray(delta[None]),
                    pad_to=len(delta))
                k = jnp.concatenate([k, dcache["k"][:, 0]], axis=1)
                v = jnp.concatenate([v, dcache["v"][:, 0]], axis=1)
                self.prefill_tokens += len(delta)
            self._write_slot(slot, k, v, len(tokens))
        else:
            _, cache = self._jit_prefill(self.params,
                                         jnp.asarray(tokens[None]),
                                         pad_to=len(tokens))
            self.prefill_tokens += len(tokens)
            self.regen_tokens += len(tokens)
            self._write_slot(slot, cache["k"][:, 0], cache["v"][:, 0],
                             len(tokens))
        self.slots[slot].session_id = sid
        return slot

    def decode(self, slot_tokens: Dict[int, int], n_steps: int = 1,
               greedy: bool = True) -> Dict[int, List[int]]:
        """Run `n_steps` batched decode steps for the given slots.
        slot_tokens: {slot: next input token id}.  Returns generated ids
        per slot."""
        out: Dict[int, List[int]] = {s: [] for s in slot_tokens}
        cur = dict(slot_tokens)
        for _ in range(n_steps):
            tok = np.zeros((self.n_slots, 1), np.int32)
            pos = np.zeros((self.n_slots,), np.int32)
            for s, t in cur.items():
                tok[s, 0] = t
                pos[s] = self.slots[s].length
            logits, self.cache = self._jit_decode(
                self.params, jnp.asarray(tok), self.cache,
                jnp.asarray(pos))
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
            for s in cur:
                self.slots[s].length += 1
                out[s].append(int(nxt[s]))
                cur[s] = int(nxt[s])
            self.decode_steps += 1
        return out

    def park_session(self, sid: str) -> bool:
        """Session pauses for a tool call: move its slot KV to the pool."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s.session_id == sid), None)
        if slot is None:
            return False
        n = self.slots[slot].length
        k = self.cache["k"][:, slot]
        v = self.cache["v"][:, slot]
        ok = self.pool.park(sid, k, v, n)
        self.slots[slot] = SlotState()
        return ok

    def evict_session(self, sid: str) -> None:
        self.pool.free_session(sid)

    def has_cache(self, sid: str) -> bool:
        return self.pool.has(sid)

    def pool_used_fraction(self) -> float:
        return self.pool.used_blocks() / self.pool.num_blocks
