"""Real-JAX serving engine: paged KV pool, continuous batching, sessions,
multi-worker server under the SAGA coordinator."""
