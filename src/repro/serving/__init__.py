"""Real-JAX serving layer: paged KV pool, engines, and the event-driven
concurrent runtime under the SAGA coordinator.

Architecture map (module -> paper section):

  * ``kvcache.PagedKVPool`` — PagedAttention-style block pool and the
    *only* home a session's KV ever has: blocks are allocated at admit
    (``alloc``/``extend``), the decode step appends into the tail block
    (``ensure_tail_room``/``append_token``), and WA-LRU / TTL decisions
    (§4.1-§4.2) mutate only block tables, never device memory.
    Capacity is split nominal/headroom: parked sessions compete for the
    ``num_blocks`` the coordinator meters, while resident (decoding)
    sessions draw from a per-slot headroom — so paged and gather modes
    make bit-identical park/evict/admit policy decisions.
  * ``engine.Engine`` — one worker: jitted prefill scattered straight
    into pool blocks + continuous-batching decode that attends over
    per-slot block tables (``lm.decode_step_paged``), appending each new
    token's K/V on device.  Park / resume / AFS preemption are pure
    metadata flips (``park_resident``/``mark_resident`` — zero device
    copies, counted in ``stats()`` as ``park_copy_bytes`` /
    ``resume_copy_bytes`` staying 0); resume prefills only the context
    delta.  KV export/import for pool-to-pool migration still copies,
    but only the session's owned blocks.  ``Engine(paged=False)`` keeps
    the original contiguous-slot gather path as the reference oracle —
    both modes emit bit-identical token ids.  Admission is
    non-asserting: a full engine returns ``None`` and the runtime
    queues.
  * ``events`` — deterministic virtual-time event heap + AFS-ordered
    ``SessionQueue`` (§6 admission); the byte-identical replay
    substrate.
  * ``runtime.ServingRuntime`` — the serving twin of the discrete-event
    simulator, on real forward passes: workflow-atomic interleaving of
    concurrent agent sessions (§3.1), AEG-guided reuse via the shared
    ``GlobalCoordinator`` (§3.2-§3.3), Eq. 7 affinity routing +
    work stealing with real KV block migration (§5), speculative
    prefetch as real pool-to-pool copies overlapping tool gaps (§4.3),
    and the 100 ms incremental AFS epoch tick (§6).

    Submission is the unified ``repro.workflow.AgentProgram`` API —
    scripted (legacy ``AgentRequest``s compile to it byte-identically),
    explicit-graph (declared AEG + seeded branch resolution: retry and
    conditional edges execute, and the scheduler sees the true
    structure), and dynamic (a client callback decides each next step
    from the real decoded tokens at park/resume boundaries).
    ``submit`` returns a ``WorkflowHandle`` (``result()`` /
    ``step_outputs`` / ``status`` / taken ``path``).
  * ``disagg`` — disaggregated prefill/decode pools (opt-in via
    ``SAGAConfig.disaggregate``; ``docs/DISAGG.md``): engines declare
    roles, a deterministic ``PrefillScheduler`` owns the prefill pool
    (new-session and tool-resume prefills, speculative prefill
    overlapping tool gaps), and finished KV hands off to the decode
    pool block-granularly (``stage_prefill`` → ``export_kv`` →
    ``import_handoff``) over a deterministic transfer window; Eq. 7
    affinity then routes *decode* placement only.  Every handoff job
    is attempt-stamped so an engine dying mid-handoff cancels cleanly
    and re-prefills token-identically.
  * ``client.SagaClient`` — THE submission surface (``for_runtime`` /
    ``for_server`` / ``for_simulation`` / ``for_driver``):
    ``client.submit(program_or_request, tenant=, slo=)`` returns a
    ``WorkflowHandle`` on every substrate; see docs/SERVING_API.md.
  * ``schema`` — the documented ``stats()`` / ``summarize()`` key
    vocabulary (``summarize()`` repr is the byte-identity pin; new
    wall-clock keys live in ``AsyncServingDriver.wall_stats``).
  * ``frontend`` — the wall-clock production surface (ROADMAP item 3):
    ``AsyncServingDriver`` pumps the SAME event heap under asyncio
    pacing (fake-clock mode replays the virtual run byte-identically),
    ``SagaHTTPProxy`` speaks OpenAI-compatible chat completions with
    ``X-Session-Id``/``X-Task-Id``/``X-Program-Id`` tracking headers,
    pluggable load-balancing strategies, ``TrackedRequest`` lifecycle
    accounting, and a Prometheus ``/metrics`` endpoint.
  * ``server.MultiWorkerServer`` — legacy blocking facade: a thin
    serial wrapper over the runtime (deprecated shim; use
    ``SagaClient``).
  * ``sanitizer.RuntimeSanitizer`` — read-only per-event conservation
    auditor (``SAGA_SANITIZE=1`` / ``ServingRuntime(sanitize=True)``):
    block/slot ownership, incremental indices, and registry stamps
    re-checked after every dispatched event, failing at the first bad
    event with the owning session and attempt named (see
    ``docs/INVARIANTS.md``).
  * ``repro.obs`` (``SAGA_TRACE=1`` / ``ServingRuntime(trace=True)``)
    — virtual-time span tracer + metrics registry hooked into the same
    semantic points on both substrates: per-session span trees
    (queue_wait / prefill / resume / decode / tool_gap / migration,
    engine rounds, preempt / cancel / prefetch / fault instants) and
    epoch-tick gauges (queue depth, KV pool occupancy, AFS deviation).
    Read-only by contract: traced ``summarize()`` is byte-identical to
    untraced, trace bytes identical across ``PYTHONHASHSEED``.
    Exports Perfetto ``trace_event`` JSON, Prometheus text, and the
    per-phase TCT decomposition (see ``docs/OBSERVABILITY.md``).

Fault / preemption lifecycle (runtime twin of the simulator's
attempt-stamped registry; ``cluster.faults`` plans drive both
substrates)::

              route                prefill_done             step done
   [queued] --------> [prefill] ---------------> [decode] -----------+
      ^  ^   admit        |    (attempt-stamped)   |  |              |
      |  |                | fail: attempt          |  | fail:        v
      |  |                | cancelled, ctx         |  | rollback   [tool]
      |  |                | rolled back,           |  | + retry      |
      |  |                v re-dispatch            |  v              |
      |  |           (re-route / orphan <----------+ orphan if       |
      |  |            buffer if no engine alive;     all dead)       |
      |  |            recover / scale_up readmits)                   |
      |  |                                         epoch tick:       |
      |  |   AFS preemption (deficit > threshold,  decide victim     |
      |  |   blocked > preempt_block_s, Thm. 2     at round          |
      |  |   under/over-served check)              boundary          |
      |  +--------------------------------- [decode victim parked:   |
      |      re-enqueued mid-step (delta-    slot KV -> pool, TTL    |
      |      only resume finishes the step   entry, starved head     |
      |      token-for-token identically)    admitted]               |
      +--------------------------------------------------------------+
                     tool_done -> next step (resume hits pool KV,
                     or regenerates from the last parked prefix if a
                     fault / eviction took it — §3.1)

   Engine ``fail`` wipes slots + block tables + coordinator pool
   metadata + affinities, cancels in-flight prefetch copies sourced
   there (counted as waste), refunds partially-charged AFS work, and
   requeues the pending queue on live engines.  ``check_conservation``
   asserts admitted == finished and zero slot/KV-block leak after every
   run, chaos plans included — and identical-seed runs stay
   byte-identical across ``PYTHONHASHSEED`` under all of it.
"""
