"""Deterministic virtual-time event plumbing for the serving runtime.

The runtime (``repro.serving.runtime``) interleaves many concurrent
agent sessions over real JAX engines.  Real compute (prefill, batched
decode, KV block copies) executes eagerly when an event is processed;
*time* is virtual — a seeded, reproducible clock advanced by the event
heap — so tool-call gaps cost nothing on the wall clock and two
identical-seed runs replay byte-identically even across processes with
different ``PYTHONHASHSEED``.

Two pieces live here:

  * ``EventLoop`` — a (time, seq, kind, args) min-heap.  ``seq`` is a
    global monotone counter, so same-timestamp events fire in schedule
    order: determinism never rests on float tie-breaking or object
    identity.  Handlers resolve by name (``_on_<kind>`` on the
    runtime); the vocabulary includes the disaggregated prefill-pool
    lifecycle (``pf_done`` — staging prefill finished, ``handoff_done``
    — cross-pool KV transfer landed), both attempt-stamped so faults
    make in-flight events stale rather than racy.
  * ``SessionQueue`` — a per-engine pending-session priority queue
    (AFS-ordered admission, §6), the serving twin of the simulator's
    ``StepQueue``: a lazy-deletion heap with tombstoned removal so the
    work stealer can extract an arbitrary victim session in O(n) scan /
    O(log n) amortized pop without rebuilding the heap.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Iterator, List, Optional, Tuple


class EventLoop:
    """Virtual-time event heap.  ``pop`` advances ``now`` monotonically;
    scheduling in the past is clamped to ``now`` (a zero-latency event,
    still ordered after everything already scheduled at ``now``)."""

    __slots__ = ("now", "_heap", "_seq")

    def __init__(self, start: float = 0.0) -> None:
        self.now = start
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def schedule(self, t: float, kind: str, args: tuple = ()) -> None:
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq),
                                    kind, args))

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop(self) -> Tuple[float, str, tuple]:
        t, _, kind, args = heapq.heappop(self._heap)
        self.now = t
        return t, kind, args


class SessionQueue:
    """AFS-priority pending-session queue for one engine.

    Keyed ``(priority, enqueued_at, seq)`` — priority is the negated
    tenant AFS share at enqueue time (higher AFS drains first), FIFO
    within a tenant.  ``remove`` tombstones (work stealing extracts the
    oldest un-cooled session, which is rarely the heap head)."""

    __slots__ = ("_heap", "_live", "_seq")

    def __init__(self, seq: Optional[Iterator[int]] = None) -> None:
        self._heap: List[Tuple[float, float, int, "object"]] = []
        self._live = 0
        self._seq = seq if seq is not None else itertools.count()

    def __len__(self) -> int:
        return self._live

    def __bool__(self) -> bool:
        return self._live > 0

    def push(self, prio: float, enqueued_at: float, item) -> None:
        heapq.heappush(self._heap, (prio, enqueued_at, next(self._seq),
                                    item))
        self._live += 1

    def pop(self):
        h = self._heap
        while h and getattr(h[0][3], "cancelled", False):
            heapq.heappop(h)
        if not h:
            return None
        self._live -= 1
        return heapq.heappop(h)[3]

    def peek(self):
        """Highest-priority live item without removing it (the AFS
        preemption trigger inspects the blocked head).  Compacts dead
        heap heads as a side effect, like ``pop``."""
        h = self._heap
        while h and getattr(h[0][3], "cancelled", False):
            heapq.heappop(h)
        return h[0][3] if h else None

    def drain(self) -> List["object"]:
        """Remove and return every live item in heap (priority) order —
        the engine-failure requeue path."""
        items = []
        while True:
            item = self.pop()
            if item is None:
                return items
            items.append(item)

    def remove(self, session_id: str):
        """Tombstone and return the queued item for ``session_id`` (the
        steal path), or None."""
        for _, _, _, item in self._heap:
            if not item.cancelled and item.session_id == session_id:
                item.cancelled = True
                self._live -= 1
                return item
        return None

    def snapshot(self) -> List[Tuple[float, str]]:
        """(enqueued_at, session_id) oldest-first — the work stealer's
        victim-queue view."""
        return sorted((enq, item.session_id)
                      for _, enq, _, item in self._heap
                      if not item.cancelled)


class _RuntimeQueueView:
    """Persistent stealer-facing view of one engine's SessionQueue (the
    serving twin of the simulator's ``_QueueView``): O(1) emptiness, the
    sorted dump built only if the stealer actually picked this engine as
    the victim.  Holds a getter, not the queue, so queue swaps stay
    visible."""

    __slots__ = ("_get",)

    def __init__(self, get_queue) -> None:
        self._get = get_queue

    def __len__(self) -> int:
        return len(self._get())

    def __bool__(self) -> bool:
        return bool(self._get())

    def __iter__(self):
        return iter(self._get().snapshot())
