"""SagaClient — the one submission surface for every substrate.

Before this facade there were three client-facing submission paths:
``ServingRuntime.submit`` (event-driven serving), ``MultiWorkerServer.
run_task`` (blocking serial wrapper) and raw ``ClusterSim`` task lists
(simulator).  Tests, benchmarks, examples and the HTTP proxy each
picked one and coupled to its quirks.  ``SagaClient`` collapses them:

    client = SagaClient.for_runtime(rt)          # virtual-time serving
    client = SagaClient.for_server(server)       # serial wrapper
    client = SagaClient.for_simulation(policy)   # discrete-event sim
    client = SagaClient.for_driver(driver)       # asyncio wall clock

    h = client.submit(program_or_request, tenant="teamA", slo=30.0)
    client.run()
    h.done, h.status, h.step_outputs (serving) / h.metrics (sim)

``submit`` accepts anything ``as_instance`` does — ``AgentProgram``
(scripted/graph/dynamic), legacy ``AgentRequest``, simulator ``Task`` —
and every backend returns a handle with the same core surface
(``session_id`` / ``done`` / ``status``).  ``tenant=`` overrides the
submission's tenant without mutating the caller's object; ``slo=``
registers an explicit deadline with the coordinator on the serving
substrates (the simulator derives deadlines from Eq. 9 work estimates
— its scheduler is deadline-free by construction, so ``slo`` only
annotates the handle there).

The old entry points remain as thin deprecated shims so golden
byte-pins stay untouched.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Optional

INF = float("inf")


def _retenant(obj, tenant: Optional[str]):
    """Shallow-copy ``obj`` with its tenant replaced (copy.copy keeps
    adapter side-channels like ``_raw_steps`` that dataclasses.replace
    would drop).  No-op when tenant is None or already equal."""
    if tenant is None or getattr(obj, "tenant", None) == tenant:
        return obj
    c = copy.copy(obj)
    c.tenant = tenant
    return c


class SimWorkflowHandle:
    """Deferred-simulation handle: resolves after ``client.run()``."""

    def __init__(self, client: "SagaClient", task_id: str,
                 slo: Optional[float]) -> None:
        self._client = client
        self.session_id = task_id
        self.slo = slo

    @property
    def _metrics(self):
        sim = self._client._sim
        return None if sim is None else sim.metrics.get(self.session_id)

    @property
    def done(self) -> bool:
        m = self._metrics
        return m is not None and m.finish >= 0

    @property
    def status(self) -> str:
        if self._client._sim is None:
            return "pending"
        return "done" if self.done else "queued"

    @property
    def metrics(self):
        """Simulator ``TaskMetrics`` (tct / regen_tokens / steps)."""
        if not self.done:
            raise RuntimeError(f"task {self.session_id} not finished "
                               "(call client.run() first)")
        return self._metrics

    @property
    def tct(self) -> float:
        return self.metrics.tct

    @property
    def slo_met(self) -> Optional[bool]:
        return None if self.slo is None else self.tct <= self.slo


class SagaClient:
    """Facade over one scheduling substrate; construct via the
    ``for_*`` classmethods."""

    def __init__(self, *, _runtime=None, _server=None, _driver=None,
                 _sim_factory=None) -> None:
        given = [x for x in (_runtime, _server, _driver, _sim_factory)
                 if x is not None]
        if len(given) != 1:
            raise ValueError("construct SagaClient via for_runtime / "
                             "for_server / for_simulation / for_driver")
        self._rt = _runtime
        self._server = _server
        self._driver = _driver
        self._sim_factory = _sim_factory
        self._sim = None
        self._pending: List[object] = []        # sim submissions
        self.handles: Dict[str, object] = {}

    # -- constructors ----------------------------------------------------
    @classmethod
    def for_runtime(cls, runtime) -> "SagaClient":
        """Virtual-time event-driven serving (``ServingRuntime``)."""
        return cls(_runtime=runtime)

    @classmethod
    def for_server(cls, server) -> "SagaClient":
        """The serial ``MultiWorkerServer`` wrapper (its runtime clock
        carries across submissions; ``run()`` drains after each)."""
        return cls(_server=server)

    @classmethod
    def for_driver(cls, driver) -> "SagaClient":
        """Asyncio wall-clock driver; ``submit`` returns awaitable
        ``AsyncWorkflowHandle``s and ``run()`` is a no-op (the driver's
        ``run()``/``serve_forever()`` coroutine pumps events)."""
        return cls(_driver=driver)

    @classmethod
    def for_simulation(cls, policy=None, *, n_workers: int = 16,
                       perf=None, seed: int = 0, fault_plan=None,
                       straggler=None, straggler_slowdown: float = 4.0,
                       trace=None) -> "SagaClient":
        """Deferred ``ClusterSim``: submissions accumulate, ``run()``
        builds and runs the simulator (it takes its task list at
        construction).  ``policy`` is a ``SimPolicy`` or ``SAGAConfig``
        (wrapped), default SAGA."""
        from repro.cluster.simulator import ClusterSim, SimPolicy
        from repro.core.coordinator import SAGAConfig

        if policy is None:
            policy = SimPolicy()
        elif isinstance(policy, SAGAConfig):
            policy = SimPolicy(saga=policy)

        def factory(tasks):
            return ClusterSim(tasks, policy, n_workers=n_workers,
                              perf=perf, seed=seed, fault_plan=fault_plan,
                              straggler=straggler,
                              straggler_slowdown=straggler_slowdown,
                              trace=trace)
        return cls(_sim_factory=factory)

    # -- core API --------------------------------------------------------
    def submit(self, program_or_request, *, tenant: Optional[str] = None,
               slo: Optional[float] = None,
               arrival: Optional[float] = None,
               route_hint: Optional[int] = None):
        """Submit one workflow; returns a handle (backend-specific type,
        shared ``session_id``/``done``/``status`` surface)."""
        obj = _retenant(program_or_request, tenant)
        if self._rt is not None:
            h = self._rt.submit(obj, arrival, route_hint=route_hint,
                                slo_s=slo)
        elif self._server is not None:
            rt = self._server.runtime
            h = rt.submit(obj, rt.ev.now if arrival is None else arrival,
                          route_hint=route_hint, slo_s=slo)
        elif self._driver is not None:
            h = self._driver.submit(obj, route_hint=route_hint,
                                    slo_s=slo, arrival=arrival)
        else:
            if self._sim is not None:
                raise RuntimeError("simulation already ran; build a "
                                   "fresh SagaClient.for_simulation")
            tid = getattr(obj, "task_id", None) \
                or getattr(obj, "program_id", None) \
                or getattr(obj, "session_id", None)
            if tid is None:
                raise TypeError(f"cannot infer task id from "
                                f"{type(obj).__name__}")
            self._pending.append(obj)
            h = SimWorkflowHandle(self, str(tid), slo)
        self.handles[h.session_id] = h
        return h

    def run(self, horizon_s: float = INF):
        """Advance the substrate until submitted work completes (sim:
        build-and-run; driver: no-op — await its coroutine instead)."""
        if self._rt is not None:
            return self._rt.run(horizon_s)
        if self._server is not None:
            return self._server.runtime.run(horizon_s)
        if self._driver is not None:
            return None
        if self._sim is None:
            self._sim, self._pending = \
                self._sim_factory(self._pending), []
        return self._sim.run(horizon_s)

    # -- read-only surface ----------------------------------------------
    @property
    def runtime(self):
        """The underlying ``ServingRuntime`` when one exists (runtime /
        server / driver backends), else None."""
        if self._rt is not None:
            return self._rt
        if self._server is not None:
            return self._server.runtime
        if self._driver is not None:
            return self._driver.rt
        return None

    def stats(self) -> dict:
        rt = self.runtime
        return rt.stats() if rt is not None else {}

    def summarize(self) -> dict:
        rt = self.runtime
        if rt is not None:
            return rt.summarize()
        if self._sim is None:
            raise RuntimeError("nothing ran yet")
        from repro.cluster.simulator import summarize
        return summarize(self._sim)

    def check_conservation(self) -> None:
        rt = self.runtime
        if rt is not None:
            rt.check_conservation()
        elif self._sim is not None:
            self._sim.check_conservation()
