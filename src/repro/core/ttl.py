"""Tool-call-aware TTL (paper §4.2, Algorithm 1) + memory pressure (Eq. 6).

Algorithm 1:
  1. (mu, sigma) <- FitLogNormal(H_t)        # tool latencies are log-normal
  2. ttl_base    <- Percentile(H_t, p)       # default p = 95
  3. pressure_factor <- 1 - 0.5 * m
  4. ttl_adaptive <- ttl_base * pressure_factor
  5. return min(ttl_adaptive, TTL_max)       # TTL_max = 300 s

Eq. 6:  m = max(0, (used - th_low) / (th_high - th_low)),
        th_low = 0.7, th_high = 0.9 of pool capacity.
"""
from __future__ import annotations

import math
from bisect import bisect_left, insort
from typing import Dict, List, Sequence, Tuple


def memory_pressure(used_fraction: float, th_low: float = 0.7,
                    th_high: float = 0.9) -> float:
    m = (used_fraction - th_low) / max(th_high - th_low, 1e-9)
    return max(0.0, min(1.0, m))


def fit_lognormal(history: Sequence[float]) -> Tuple[float, float]:
    """MLE fit of (mu, sigma) for a log-normal over positive samples."""
    logs = [math.log(max(x, 1e-6)) for x in history]
    n = len(logs)
    if n == 0:
        return 0.0, 1.0
    mu = sum(logs) / n
    if n == 1:
        return mu, 1.0
    var = sum((x - mu) ** 2 for x in logs) / (n - 1)
    return mu, math.sqrt(max(var, 1e-12))


def _pct_index(n: int, p: float) -> int:
    return min(n - 1, max(0, int(math.ceil(p / 100.0 * n)) - 1))


def percentile(history: Sequence[float], p: float) -> float:
    if not history:
        return 0.0
    xs = sorted(history)
    return xs[_pct_index(len(xs), p)]


class ToolTTLPolicy:
    """Per-tool-type TTL with empirical latency histories.

    The paper maintains EMAs of per-tool latency distributions; we keep a
    bounded history window (equivalent information, exact percentiles).
    When a tool type has too little history, the log-normal fit supplies
    the percentile analytically (mu + z_p * sigma in log space).
    """

    Z95 = 1.6448536269514722

    def __init__(self, p: float = 95.0, ttl_max_s: float = 300.0,
                 min_samples: int = 8):
        self.p = p
        self.ttl_max = ttl_max_s
        self.min_samples = min_samples
        self._hist: Dict[str, List[float]] = {}
        # incrementally-maintained sorted view of each history.  TTL
        # queries interleave 1:1 with observations on the step hot
        # path, so re-sorting per query was O(n log n) per LLM step.
        # Wholesale ``hist`` assignment (checkpoint restore, tests)
        # clears the cache via the property setter; each entry also
        # holds the backing list and compares it by identity (``is``),
        # so per-key replacement — even one that reuses a freed list's
        # address — can never serve a stale sort.
        self._sorted: Dict[str, Tuple[List[float], List[float]]] = {}

    @property
    def hist(self) -> Dict[str, List[float]]:
        return self._hist

    @hist.setter
    def hist(self, value: Dict[str, List[float]]) -> None:
        self._hist = value
        self._sorted.clear()

    def _sorted_hist(self, tool: str, h: List[float]) -> List[float]:
        cached = self._sorted.get(tool)
        if cached is not None and cached[0] is h \
                and len(cached[1]) == len(h):
            return cached[1]
        s = sorted(h)
        self._sorted[tool] = (h, s)
        return s

    def observe(self, tool: str, latency_s: float,
                max_hist: int = 4096) -> None:
        h = self.hist.setdefault(tool, [])
        s = self._sorted_hist(tool, h)   # sync BEFORE mutating h
        h.append(latency_s)
        insort(s, latency_s)
        if len(h) > max_hist:
            for x in h[:len(h) - max_hist]:
                s.pop(bisect_left(s, x))
            del h[:len(h) - max_hist]
        self._sorted[tool] = (h, s)

    def ttl(self, tool: str, mem_pressure: float,
            default_s: float = 30.0) -> float:
        """Algorithm 1.  mem_pressure = Eq. 6's m in [0,1]."""
        h = self.hist.get(tool, [])
        if len(h) >= self.min_samples:
            xs = self._sorted_hist(tool, h)
            ttl_base = xs[_pct_index(len(xs), self.p)]
        elif h:
            mu, sigma = fit_lognormal(h)
            z = self.Z95 * (self.p / 95.0)
            ttl_base = math.exp(mu + z * sigma)
        else:
            ttl_base = default_s
        pressure_factor = 1.0 - 0.5 * max(0.0, min(1.0, mem_pressure))
        return min(ttl_base * pressure_factor, self.ttl_max)

    def deadline(self, tool: str, now: float, mem_pressure: float) -> float:
        return now + self.ttl(tool, mem_pressure)
