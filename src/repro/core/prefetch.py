"""Speculative KV prefetching (paper §4.3).

When node v finishes inference and its tool call starts, prefetch the
prefix cache of the most likely successor u = argmax P(v -> u) so the
cache load overlaps the tool-call gap.  On TPU the copy is an async
device-to-device transfer (CUDA streams in the paper); the simulator
models it as a bandwidth-limited background copy using spare HBM.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.aeg import AEG


@dataclass
class PrefetchJob:
    session_id: str
    node_id: int              # successor being prefetched
    bytes_: float
    issued_at: float
    ready_at: float           # completion time under bandwidth model
    correct: Optional[bool] = None   # filled when the real next step lands
    worker: Optional[int] = None     # copy source (engine fault cleanup)


class SpeculativePrefetcher:
    def __init__(self, bandwidth_Bps: float = 25e9,
                 spare_capacity_fraction: float = 0.1):
        self.bw = bandwidth_Bps
        self.spare = spare_capacity_fraction
        self.inflight: Dict[str, PrefetchJob] = {}
        self.issued = 0
        self.correct = 0
        self.wasted_bytes = 0.0

    def maybe_issue(self, session_id: str, aeg: Optional[AEG],
                    node_id: int, entry_bytes: float, now: float,
                    pool_used_frac: float,
                    target: Optional[int] = None,
                    worker: Optional[int] = None) -> Optional[PrefetchJob]:
        """Issue a prefetch for the argmax successor if spare memory
        exists.  ``target`` overrides the successor prediction with an
        already-resolved node (declared graphs: the taken edge is known
        at the park boundary, so the prefetch is exact, not
        speculative).  ``worker`` records the copy's source engine so a
        fault there can cancel the job.  Returns the job (simulator
        schedules ready_at)."""
        if aeg is None or pool_used_frac > 1.0 - self.spare:
            return None
        succ = target if target is not None \
            else aeg.most_likely_successor(node_id)
        if succ is None:
            return None
        # an in-flight job for the same session is superseded, never
        # resolved: its bytes were copied for nothing and must count as
        # waste (previously they silently vanished from the accounting)
        prev = self.inflight.get(session_id)
        if prev is not None:
            self.wasted_bytes += prev.bytes_
        job = PrefetchJob(session_id=session_id, node_id=succ,
                          bytes_=entry_bytes, issued_at=now,
                          ready_at=now + entry_bytes / self.bw,
                          worker=worker)
        self.inflight[session_id] = job
        self.issued += 1
        return job

    def cancel(self, session_id: str) -> None:
        """Drop an in-flight job whose session ended before its next
        step arrived (task finished mid-gap).  The copy was pure waste."""
        job = self.inflight.pop(session_id, None)
        if job is not None:
            self.wasted_bytes += job.bytes_

    def cancel_worker(self, worker: int) -> int:
        """An engine died: every in-flight replication sourced from it
        can never land (its parked blocks are gone), so the jobs are
        cancelled and their bytes counted as waste — previously only
        supersession cancelled them, and a dead-source job would linger
        until ``resolve`` mis-scored it against the wrong copy.  Returns
        the number of jobs cancelled."""
        victims = [sid for sid, job in self.inflight.items()
                   if job.worker == worker]
        for sid in victims:
            self.wasted_bytes += self.inflight.pop(sid).bytes_
        return len(victims)

    def resolve(self, session_id: str, actual_node: int,
                now: float) -> bool:
        """The session's real next step arrived: was the prefetch warm
        and correct?  Returns True when the step's prefill is absorbed."""
        job = self.inflight.pop(session_id, None)
        if job is None:
            return False
        ok = job.node_id == actual_node and job.ready_at <= now
        job.correct = ok
        if ok:
            self.correct += 1
        else:
            self.wasted_bytes += job.bytes_
        return ok
