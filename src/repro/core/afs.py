"""Agent Fair Share scheduling (paper §6, Eq. 8-9, Theorem 2).

Definition 2:  AFS_i = sum_{t in T_i} work_remain(t) / (deadline(t) - now)

work_remain(t) (Eq. 9) sums estimated prefill+decode GPU-seconds over the
pending AEG nodes.  The epoch allocator (100 ms) assigns worker capacity
proportionally to AFS and triggers preemption when a low-AFS task blocks
a high-AFS task for > 500 ms — the preempted task's cache is migrated,
not discarded (§6.2), so WA-LRU predictions survive preemption (§3.1).

Theorem 2 (Lyapunov drift): urgency-proportional allocation is a
restoring force on the deviation e_i = S_i - mu_i * t; `lyapunov_v`
exposes V(t) = sum e_i^2 so tests/benches can verify the negative-drift
property empirically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy ships with repo
    np = None


class _TaskCols(NamedTuple):
    """Cached per-task columns for the vectorized AFS recompute."""
    deadlines: "np.ndarray"
    works: "np.ndarray"          # mutated in place on finish/progress
    tenant_idx: "np.ndarray"
    names: List[str]             # tenant order at build time
    row_of: Dict[str, int]       # task_id -> row in the columns


@dataclass
class TaskProgress:
    task_id: str
    tenant: str
    deadline: float
    work_remain_s: float          # Eq. 9 estimate (GPU-seconds)
    blocked_since: Optional[float] = None


@dataclass
class TenantState:
    tenant: str
    afs: float = 0.0
    service_s: float = 0.0        # cumulative GPU-seconds received (S_i)
    share: float = 0.0            # current epoch allocation fraction


class AFSScheduler:
    def __init__(self, epoch_s: float = 0.100,
                 preempt_block_s: float = 0.500):
        self.epoch_s = epoch_s
        self.preempt_block_s = preempt_block_s
        self.tenants: Dict[str, TenantState] = {}
        self.tasks: Dict[str, TaskProgress] = {}
        self.preemptions = 0
        # recompute() runs every 100 ms over every pending task; the
        # (deadline, work, tenant-index) columns change only on task
        # add/finish/progress, so they are cached as arrays and the
        # per-epoch work is vectorized (bit-identical accumulation
        # order to the scalar loop).
        self._cols = None

    def _invalidate(self) -> None:
        self._cols = None

    # -- registration ----------------------------------------------------
    def add_task(self, tp: TaskProgress) -> None:
        self.tasks[tp.task_id] = tp
        self.tenants.setdefault(tp.tenant, TenantState(tp.tenant))
        self._invalidate()

    def finish_task(self, task_id: str) -> None:
        if self.tasks.pop(task_id, None) is not None:
            # zero the cached work column instead of rebuilding: a
            # zero contribution is exact (x + 0.0 == x), and finishes
            # are the highest-rate mutation
            if self._cols is not None and task_id in self._cols.row_of:
                self._cols.works[self._cols.row_of[task_id]] = 0.0
            else:
                self._invalidate()

    def note_service(self, tenant: str, gpu_seconds: float) -> None:
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantState(tenant)
            self._invalidate()
        self.tenants[tenant].service_s += gpu_seconds

    def note_progress(self, task_id: str, work_done_s: float) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.work_remain_s = max(0.0, t.work_remain_s - work_done_s)
            if self._cols is not None and task_id in self._cols.row_of:
                self._cols.works[self._cols.row_of[task_id]] = \
                    t.work_remain_s
            else:
                self._invalidate()

    # -- Eq. 8 -------------------------------------------------------------
    def recompute(self, now: float) -> Dict[str, float]:
        # Epoch hot path (every 100 ms over every pending task).  At
        # cluster scale the per-task Python loop dominated the whole
        # simulator event loop, so the task columns are cached and the
        # slack/contribution math runs vectorized; bincount accumulates
        # per tenant in the same task order as the scalar loop, so the
        # result is bit-identical.
        if np is not None and self.tasks:
            if self._cols is None:
                names = list(self.tenants)
                tidx = {k: i for i, k in enumerate(names)}
                self._cols = _TaskCols(
                    np.array([t.deadline for t in self.tasks.values()]),
                    np.array([t.work_remain_s
                              for t in self.tasks.values()]),
                    np.array([tidx[t.tenant]
                              for t in self.tasks.values()]),
                    names,
                    {k: i for i, k in enumerate(self.tasks)},
                )
            c = self._cols
            slack = np.maximum(c.deadlines - now, self.epoch_s)
            acc_v = np.bincount(c.tenant_idx, weights=c.works / slack,
                                minlength=len(c.names))
            acc = dict(zip(c.names, acc_v.tolist()))
        else:
            acc = dict.fromkeys(self.tenants, 0.0)
            eps = self.epoch_s
            for t in self.tasks.values():
                slack = t.deadline - now
                if slack < eps:
                    slack = eps
                acc[t.tenant] += t.work_remain_s / slack
        total = 0.0
        for v in acc.values():
            if v > 0.0:
                total += v
        uniform = 1.0 / max(len(self.tenants), 1)
        for ten in self.tenants.values():
            afs = acc[ten.tenant]
            ten.afs = afs
            ten.share = (afs / total) if total > 0 else uniform
        return {k: v.share for k, v in self.tenants.items()}

    def priority(self, tenant: str) -> float:
        t = self.tenants.get(tenant)
        return t.afs if t else 0.0

    # -- preemption (§6.2 step 4) ------------------------------------------
    def note_blocked(self, task_id: str, now: float) -> None:
        t = self.tasks.get(task_id)
        if t and t.blocked_since is None:
            t.blocked_since = now

    def note_unblocked(self, task_id: str) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.blocked_since = None

    def should_preempt(self, blocked_task: str, blocking_task: str,
                       now: float) -> bool:
        b = self.tasks.get(blocked_task)
        lo = self.tasks.get(blocking_task)
        if b is None or lo is None or b.blocked_since is None:
            return False
        if now - b.blocked_since < self.preempt_block_s:
            return False
        if self.priority(b.tenant) <= self.priority(lo.tenant):
            return False
        self.preemptions += 1
        return True

    # -- Theorem 2 instrumentation ------------------------------------------
    def lyapunov_v(self, now: float, t0: float, capacity: float,
                   workloads: Dict[str, float]) -> float:
        """V(t) = sum_i (S_i(t) - mu_i * (t - t0))^2 with
        mu_i = W_i / sum_j W_j * C (proportional fair share)."""
        tot_w = sum(workloads.values()) or 1.0
        v = 0.0
        for ten, w in workloads.items():
            mu = w / tot_w * capacity
            s = self.tenants.get(ten, TenantState(ten)).service_s
            v += (s - mu * (now - t0)) ** 2
        return v
