"""Agent Fair Share scheduling (paper §6, Eq. 8-9, Theorem 2).

Definition 2:  AFS_i = sum_{t in T_i} work_remain(t) / (deadline(t) - now)

work_remain(t) (Eq. 9) sums estimated prefill+decode GPU-seconds over the
pending AEG nodes.  The epoch allocator (100 ms) assigns worker capacity
proportionally to AFS and triggers preemption when a low-AFS task blocks
a high-AFS task for > 500 ms — the preempted task's cache is migrated,
not discarded (§6.2), so WA-LRU predictions survive preemption (§3.1).

Theorem 2 (Lyapunov drift): urgency-proportional allocation is a
restoring force on the deviation e_i = S_i - mu_i * t; `lyapunov_v`
exposes V(t) = sum e_i^2 so tests/benches can verify the negative-drift
property empirically.

Incremental accumulation (delta-update invariants)
--------------------------------------------------
``recompute(now)`` runs every epoch over every pending task, so the
per-task (deadline, work, tenant-index) columns are *persistent*
capacity-doubled arrays maintained by O(1) delta updates instead of
being rebuilt on structural change:

  * ``add_task``      appends one row (amortized O(1); arrays double).
  * ``finish_task``   tombstones the row by zeroing its work column — a
    zero contribution is exact (``x + 0.0 == x`` bitwise), so finished
    rows never perturb the running bincount sums.
  * ``note_progress`` marks the row dirty; ``recompute`` flushes dirty
    rows (O(|dirty|)) before the vectorized slack math, coalescing any
    number of progress updates between epochs into one column write.
  * tombstones are compacted away once they outnumber live rows
    (amortized O(1) per op, order-preserving so sums stay bit-exact).

The slack/contribution reduction itself must touch every live row —
Eq. 8's ``deadline - now`` term changes for every task every tick — but
it stays a single vectorized ``bincount`` (C speed), and the Python-
loop column rebuild the old cached-column path performed on every
admission/finish is gone entirely.

Invariant: ``recompute(now) == recompute_full(now)`` bit-for-bit at any
interleaving of add/finish/progress events — ``recompute_full`` rebuilds
fresh columns from the live task dict and runs the identical math, and
``tests/test_faults.py::test_incremental_vs_full_afs_equivalence``
property-checks the equality.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy ships with repo
    np = None

_MIN_ROWS = 64               # initial column capacity / compaction floor


@dataclass
class TaskProgress:
    task_id: str
    tenant: str
    deadline: float
    work_remain_s: float          # Eq. 9 estimate (GPU-seconds)
    blocked_since: Optional[float] = None


@dataclass
class TenantState:
    tenant: str
    afs: float = 0.0
    service_s: float = 0.0        # cumulative GPU-seconds received (S_i)
    share: float = 0.0            # current epoch allocation fraction


class AFSScheduler:
    def __init__(self, epoch_s: float = 0.100,
                 preempt_block_s: float = 0.500):
        self.epoch_s = epoch_s
        self.preempt_block_s = preempt_block_s
        self.tenants: Dict[str, TenantState] = {}
        self.tasks: Dict[str, TaskProgress] = {}
        self.preemptions = 0
        # persistent vectorized columns (see module docstring)
        self._n = 0                       # used rows incl. tombstones
        self._live = 0                    # rows backing a pending task
        self._row_of: Dict[str, int] = {}
        self._dirty: Set[str] = set()     # task ids with unflushed work
        self._names: List[str] = []       # tenant order (first-seen)
        self._tpos: Dict[str, int] = {}
        if np is not None:
            self._deadlines = np.zeros(_MIN_ROWS)
            self._works = np.zeros(_MIN_ROWS)
            self._tidx = np.zeros(_MIN_ROWS, dtype=np.intp)

    # -- column maintenance ------------------------------------------------
    def _tenant_index(self, tenant: str) -> int:
        pos = self._tpos.get(tenant)
        if pos is None:
            pos = len(self._names)
            self._tpos[tenant] = pos
            self._names.append(tenant)
        return pos

    def _grow(self) -> None:
        cap = max(_MIN_ROWS, 2 * len(self._deadlines))
        for name in ("_deadlines", "_works", "_tidx"):
            old = getattr(self, name)
            new = np.zeros(cap, dtype=old.dtype)
            new[:self._n] = old[:self._n]
            setattr(self, name, new)

    def _compact(self) -> None:
        """Drop tombstoned rows once they outnumber live ones.  Keeps
        relative row order, so per-tenant bincount accumulation order —
        and therefore every bit of the shares — is unchanged."""
        keep = sorted(self._row_of.items(), key=lambda kv: kv[1])
        n = len(keep)
        for new_row, (tid, old_row) in enumerate(keep):
            self._deadlines[new_row] = self._deadlines[old_row]
            self._works[new_row] = self._works[old_row]
            self._tidx[new_row] = self._tidx[old_row]
            self._row_of[tid] = new_row
        self._n = n
        self._live = n

    def _flush_dirty(self) -> None:
        """Apply pending work-column deltas — O(|dirty|), the only rows
        ``recompute`` writes."""
        # sagalint: ok(det-set-order) each tid writes only its own row, so visit order cannot change the flushed column
        for tid in self._dirty:
            row = self._row_of.get(tid)
            if row is not None:
                t = self.tasks.get(tid)
                self._works[row] = t.work_remain_s if t is not None else 0.0
        self._dirty.clear()

    # -- registration ----------------------------------------------------
    def add_task(self, tp: TaskProgress) -> None:
        self.tasks[tp.task_id] = tp
        self.tenants.setdefault(tp.tenant, TenantState(tp.tenant))
        if np is None:
            return
        pos = self._tenant_index(tp.tenant)
        if self._n >= len(self._deadlines):
            self._grow()
        row = self._n
        self._n += 1
        self._live += 1
        self._deadlines[row] = tp.deadline
        self._works[row] = tp.work_remain_s
        self._tidx[row] = pos
        self._row_of[tp.task_id] = row

    def finish_task(self, task_id: str) -> None:
        if self.tasks.pop(task_id, None) is None:
            return
        self._dirty.discard(task_id)
        if np is None:
            return
        row = self._row_of.pop(task_id, None)
        if row is not None:
            # tombstone: a zero contribution is exact (x + 0.0 == x)
            self._works[row] = 0.0
            self._live -= 1
            if self._n > _MIN_ROWS and self._n > 2 * self._live:
                self._compact()

    def note_service(self, tenant: str, gpu_seconds: float) -> None:
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantState(tenant)
            if np is not None:
                self._tenant_index(tenant)
        self.tenants[tenant].service_s += gpu_seconds

    def note_progress(self, task_id: str, work_done_s: float) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.work_remain_s = max(0.0, t.work_remain_s - work_done_s)
            if np is not None:     # scalar fallback has no columns to sync
                self._dirty.add(task_id)

    def set_work(self, task_id: str, work_s: float) -> None:
        """Replace a task's Eq. 9 work-remaining estimate outright (the
        coordinator re-derives it from the declared AEG's branch
        structure each step).  Same dirty-row protocol as
        ``note_progress`` — flushed O(|dirty|) on the next epoch."""
        t = self.tasks.get(task_id)
        if t:
            t.work_remain_s = max(0.0, work_s)
            if np is not None:
                self._dirty.add(task_id)

    def refund_work(self, task_id: str, work_s: float) -> None:
        """Return previously-charged progress to a task's Eq. 9
        work-remaining estimate: a fault cancelled the step mid-attempt,
        so the partial progress noted when the preemption parked it is
        un-done — the retried step re-runs in full and its priority must
        reflect that.  Same dirty-row protocol as ``note_progress``."""
        t = self.tasks.get(task_id)
        if t and work_s > 0.0:
            t.work_remain_s += work_s
            if np is not None:
                self._dirty.add(task_id)

    # -- Eq. 8 -------------------------------------------------------------
    def _accumulate(self, now: float) -> Dict[str, float]:
        """Per-tenant AFS numerators in tenant first-seen order."""
        if np is not None and self.tasks:
            self._flush_dirty()
            n = self._n
            slack = np.maximum(self._deadlines[:n] - now, self.epoch_s)
            acc_v = np.bincount(self._tidx[:n],
                                weights=self._works[:n] / slack,
                                minlength=len(self._names))
            return dict(zip(self._names, acc_v.tolist()))
        acc = dict.fromkeys(self.tenants, 0.0)
        eps = self.epoch_s
        for t in self.tasks.values():
            slack = t.deadline - now
            if slack < eps:
                slack = eps
            acc[t.tenant] += t.work_remain_s / slack
        return acc

    def _shares_from(self, acc: Dict[str, float],
                     write: bool = True) -> Dict[str, float]:
        total = 0.0
        for v in acc.values():
            if v > 0.0:
                total += v
        uniform = 1.0 / max(len(self.tenants), 1)
        shares: Dict[str, float] = {}
        for ten in self.tenants.values():
            afs = acc.get(ten.tenant, 0.0)
            share = (afs / total) if total > 0 else uniform
            if write:
                ten.afs = afs
                ten.share = share
            shares[ten.tenant] = share
        return shares

    def recompute(self, now: float) -> Dict[str, float]:
        """Epoch hot path: flush O(|dirty|) column writes, then one
        vectorized slack/bincount reduction (C speed) over the
        persistent columns.  No Python-loop rebuilds, ever."""
        return self._shares_from(self._accumulate(now), write=True)

    def recompute_full(self, now: float) -> Dict[str, float]:
        """Reference path: rebuild fresh columns from the live task dict
        and run the identical math.  Pure (does not touch tenant or
        column state) — the incremental path is regression-gated to
        match this bit-for-bit."""
        if np is not None and self.tasks:
            names = list(self.tenants)
            tpos = {k: i for i, k in enumerate(names)}
            deadlines = np.array([t.deadline for t in self.tasks.values()])
            works = np.array([t.work_remain_s
                              for t in self.tasks.values()])
            tidx = np.array([tpos[t.tenant] for t in self.tasks.values()],
                            dtype=np.intp)
            slack = np.maximum(deadlines - now, self.epoch_s)
            acc_v = np.bincount(tidx, weights=works / slack,
                                minlength=len(names))
            acc = dict(zip(names, acc_v.tolist()))
        else:
            acc = dict.fromkeys(self.tenants, 0.0)
            eps = self.epoch_s
            for t in self.tasks.values():
                slack = t.deadline - now
                if slack < eps:
                    slack = eps
                acc[t.tenant] += t.work_remain_s / slack
        return self._shares_from(acc, write=False)

    def priority(self, tenant: str) -> float:
        t = self.tenants.get(tenant)
        return t.afs if t else 0.0

    # -- preemption (§6.2 step 4) ------------------------------------------
    def deficit(self, blocked_tenant: str, running_tenant: str) -> float:
        """Fair-share deficit of a blocked tenant against a running one:
        the AFS-priority gap Eq. 8 says the allocator owes the blocked
        side.  The serving runtime preempts a running decode only when
        this exceeds its configured threshold (plus the blocked-time
        hysteresis in ``should_preempt``), so marginal inversions never
        thrash the decode batch."""
        return self.priority(blocked_tenant) - self.priority(running_tenant)

    def note_blocked(self, task_id: str, now: float) -> None:
        t = self.tasks.get(task_id)
        if t and t.blocked_since is None:
            t.blocked_since = now

    def note_unblocked(self, task_id: str) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.blocked_since = None

    def should_preempt(self, blocked_task: str, blocking_task: str,
                       now: float) -> bool:
        b = self.tasks.get(blocked_task)
        lo = self.tasks.get(blocking_task)
        if b is None or lo is None or b.blocked_since is None:
            return False
        if now - b.blocked_since < self.preempt_block_s:
            return False
        if self.priority(b.tenant) <= self.priority(lo.tenant):
            return False
        self.preemptions += 1
        return True

    # -- Theorem 2 instrumentation ------------------------------------------
    def lyapunov_v(self, now: float, t0: float, capacity: float,
                   workloads: Dict[str, float]) -> float:
        """V(t) = sum_i (S_i(t) - mu_i * (t - t0))^2 with
        mu_i = W_i / sum_j W_j * C (proportional fair share)."""
        tot_w = sum(workloads.values()) or 1.0
        v = 0.0
        for ten, w in workloads.items():
            mu = w / tot_w * capacity
            s = self.tenants.get(ten, TenantState(ten)).service_s
            v += (s - mu * (now - t0)) ** 2
        return v
