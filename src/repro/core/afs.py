"""Agent Fair Share scheduling (paper §6, Eq. 8-9, Theorem 2).

Definition 2:  AFS_i = sum_{t in T_i} work_remain(t) / (deadline(t) - now)

work_remain(t) (Eq. 9) sums estimated prefill+decode GPU-seconds over the
pending AEG nodes.  The epoch allocator (100 ms) assigns worker capacity
proportionally to AFS and triggers preemption when a low-AFS task blocks
a high-AFS task for > 500 ms — the preempted task's cache is migrated,
not discarded (§6.2), so WA-LRU predictions survive preemption (§3.1).

Theorem 2 (Lyapunov drift): urgency-proportional allocation is a
restoring force on the deviation e_i = S_i - mu_i * t; `lyapunov_v`
exposes V(t) = sum e_i^2 so tests/benches can verify the negative-drift
property empirically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class TaskProgress:
    task_id: str
    tenant: str
    deadline: float
    work_remain_s: float          # Eq. 9 estimate (GPU-seconds)
    blocked_since: Optional[float] = None


@dataclass
class TenantState:
    tenant: str
    afs: float = 0.0
    service_s: float = 0.0        # cumulative GPU-seconds received (S_i)
    share: float = 0.0            # current epoch allocation fraction


class AFSScheduler:
    def __init__(self, epoch_s: float = 0.100,
                 preempt_block_s: float = 0.500):
        self.epoch_s = epoch_s
        self.preempt_block_s = preempt_block_s
        self.tenants: Dict[str, TenantState] = {}
        self.tasks: Dict[str, TaskProgress] = {}
        self.preemptions = 0

    # -- registration ----------------------------------------------------
    def add_task(self, tp: TaskProgress) -> None:
        self.tasks[tp.task_id] = tp
        self.tenants.setdefault(tp.tenant, TenantState(tp.tenant))

    def finish_task(self, task_id: str) -> None:
        self.tasks.pop(task_id, None)

    def note_service(self, tenant: str, gpu_seconds: float) -> None:
        self.tenants.setdefault(tenant, TenantState(tenant))
        self.tenants[tenant].service_s += gpu_seconds

    def note_progress(self, task_id: str, work_done_s: float) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.work_remain_s = max(0.0, t.work_remain_s - work_done_s)

    # -- Eq. 8 -------------------------------------------------------------
    def recompute(self, now: float) -> Dict[str, float]:
        for ten in self.tenants.values():
            ten.afs = 0.0
        for t in self.tasks.values():
            slack = max(t.deadline - now, self.epoch_s)
            self.tenants[t.tenant].afs += t.work_remain_s / slack
        total = sum(max(v.afs, 0.0) for v in self.tenants.values())
        for ten in self.tenants.values():
            ten.share = (ten.afs / total) if total > 0 else \
                (1.0 / max(len(self.tenants), 1))
        return {k: v.share for k, v in self.tenants.items()}

    def priority(self, tenant: str) -> float:
        t = self.tenants.get(tenant)
        return t.afs if t else 0.0

    # -- preemption (§6.2 step 4) ------------------------------------------
    def note_blocked(self, task_id: str, now: float) -> None:
        t = self.tasks.get(task_id)
        if t and t.blocked_since is None:
            t.blocked_since = now

    def note_unblocked(self, task_id: str) -> None:
        t = self.tasks.get(task_id)
        if t:
            t.blocked_since = None

    def should_preempt(self, blocked_task: str, blocking_task: str,
                       now: float) -> bool:
        b = self.tasks.get(blocked_task)
        lo = self.tasks.get(blocking_task)
        if b is None or lo is None or b.blocked_since is None:
            return False
        if now - b.blocked_since < self.preempt_block_s:
            return False
        if self.priority(b.tenant) <= self.priority(lo.tenant):
            return False
        self.preemptions += 1
        return True

    # -- Theorem 2 instrumentation ------------------------------------------
    def lyapunov_v(self, now: float, t0: float, capacity: float,
                   workloads: Dict[str, float]) -> float:
        """V(t) = sum_i (S_i(t) - mu_i * (t - t0))^2 with
        mu_i = W_i / sum_j W_j * C (proportional fair share)."""
        tot_w = sum(workloads.values()) or 1.0
        v = 0.0
        for ten, w in workloads.items():
            mu = w / tot_w * capacity
            s = self.tenants.get(ten, TenantState(ten)).service_s
            v += (s - mu * (now - t0)) ** 2
        return v
