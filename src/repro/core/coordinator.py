"""Global coordinator: the workflow-atomic scheduling brain (paper §3.1).

Wires AEGs + WA-LRU + TTL + affinity + stealing + AFS + prefetch into a
single object used by BOTH the discrete-event simulator
(``repro.cluster.simulator``) and the real JAX serving engine
(``repro.serving.server``).  All methods take explicit ``now`` so the
coordinator is time-source agnostic.

Cross-layer behaviours from §3.1:
  * AFS preemption migrates cache WITH its TTL state, so WA-LRU at the
    destination keeps honoring the prediction (``migrate_session``).
  * Work stealing is gated by both T_idle and R_max (in WorkStealer).
  * Coordinator state is checkpointable (``snapshot``/``restore``) —
    fault tolerance for 1000+-node deployments.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field, asdict
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

try:
    import numpy as np
except ImportError:          # pragma: no cover - numpy ships with repo
    np = None

INF = float("inf")

from repro.core.aeg import AEG, PatternInferencer, ToolStats
from repro.core.affinity import SessionRouter
from repro.core.afs import AFSScheduler, TaskProgress
from repro.core.prefetch import SpeculativePrefetcher
from repro.core.stealing import StealDecision, WorkStealer
from repro.core.ttl import ToolTTLPolicy, memory_pressure
from repro.core.walru import (CacheEntry, EvictionWeights, LRUCache,
                              PrefixLRUCache, WALRUCache)


_CACHE_POLICIES = ("walru", "lru", "prefix", "none")
_OBS_TIERS = ("hints", "pattern", "none")


@dataclass(kw_only=True)
class SAGAConfig:
    """Scheduling knobs for both substrates.  Keyword-only: every field
    has a default and positional construction has never been supported
    by any in-tree call site, so argument order can no longer silently
    change meaning.  ``validate()`` is the single coherence gate
    (replacing scattered asserts); the full field table lives in
    docs/SERVING_API.md."""

    # WA-LRU (Eq. 1, Table 9)
    alpha: float = 0.3
    beta: float = 0.5
    gamma: float = 0.2
    # routing (Eq. 7)
    theta: float = 0.8
    # stealing (§5.2)
    t_idle_s: float = 0.100
    r_max: float = 2.0
    # TTL (Algorithm 1 / Eq. 6)
    ttl_percentile: float = 95.0
    ttl_max_s: float = 300.0
    th_low: float = 0.7
    th_high: float = 0.9
    # AEG inference (§3.3)
    theta_conf: float = 0.7
    min_tasks: int = 30
    # AFS (§6)
    epoch_s: float = 0.100
    preempt_block_s: float = 0.500
    # AFS preemption of RUNNING decodes (§6.2 step 4, serving runtime):
    # a queued session whose tenant's fair-share deficit against a
    # running victim exceeds ``preempt_deficit`` for longer than
    # ``preempt_block_s`` parks the victim at the next batched-decode
    # round boundary.  Off by default: admission-only ordering is the
    # pre-preemption behaviour every golden byte-pin was captured under.
    enable_preemption: bool = False
    preempt_deficit: float = 0.0
    # observability tier: hints | pattern | none
    observability: str = "hints"
    # cache policy: walru | lru | prefix | none (no cross-request reuse,
    # vLLM v0.6.0 discards KV at request end)
    cache_policy: str = "walru"
    prefix_fraction: float = 0.35
    # component toggles (Table 4 ablations)
    enable_affinity: bool = True
    enable_stealing: bool = True
    enable_ttl: bool = True
    enable_prefetch: bool = True
    enable_afs: bool = True
    # disaggregated prefill/decode engine pools (serving runtime §5 /
    # ROADMAP item 2).  Off by default: the unified pool is the
    # behaviour every committed fingerprint was captured under.  When
    # on, the runtime splits engines into prefill / decode roles, a
    # PrefillScheduler owns prefill placement, and Eq. 7 affinity
    # routing decides decode placement only (see serving/disagg.py).
    disaggregate: bool = False
    seed: int = 0

    def validate(self, *, roles: Optional[Sequence[str]] = None,
                 n_workers: Optional[int] = None) -> "SAGAConfig":
        """Raise ``ValueError`` listing every incoherent setting, or
        return ``self`` so construction sites can chain.  ``roles`` is
        the serving runtime's per-engine role list (``decode`` /
        ``prefill``); when given, role/disaggregation coherence is
        checked too.  Called from ``GlobalCoordinator.__init__`` so a
        bad config fails loudly on both substrates."""
        errs: List[str] = []

        def rng(name: str, lo: float, hi: float) -> None:
            v = getattr(self, name)
            if not lo <= v <= hi:
                errs.append(f"{name}={v!r} must be in [{lo}, {hi}]")

        for f in ("alpha", "beta", "gamma", "th_low", "th_high",
                  "theta_conf", "prefix_fraction"):
            rng(f, 0.0, 1.0)
        rng("ttl_percentile", 0.0, 100.0)
        # theta is a load threshold in engine-count units, not a
        # fraction: >1 deliberately over-commits toward affinity.
        for f in ("theta", "t_idle_s", "r_max", "ttl_max_s", "epoch_s",
                  "preempt_block_s"):
            if getattr(self, f) <= 0:
                errs.append(f"{f}={getattr(self, f)!r} must be > 0")
        if self.min_tasks < 1:
            errs.append(f"min_tasks={self.min_tasks!r} must be >= 1")
        if self.th_low > self.th_high:
            errs.append(f"th_low={self.th_low!r} must not exceed "
                        f"th_high={self.th_high!r}")
        if self.cache_policy not in _CACHE_POLICIES:
            errs.append(f"cache_policy={self.cache_policy!r} not one of "
                        f"{_CACHE_POLICIES}")
        if self.observability not in _OBS_TIERS:
            errs.append(f"observability={self.observability!r} not one "
                        f"of {_OBS_TIERS}")
        if self.preempt_deficit < 0:
            errs.append(f"preempt_deficit={self.preempt_deficit!r} must "
                        "be >= 0 (0 parks on any positive deficit)")
        if self.preempt_deficit > 0 and not self.enable_preemption:
            errs.append(f"preempt_deficit={self.preempt_deficit!r} has "
                        "no effect without enable_preemption=True")
        if self.enable_preemption and not self.enable_afs:
            errs.append("enable_preemption=True needs enable_afs=True "
                        "(preemption restores the AFS fair share)")
        if roles is not None:
            bad = sorted(set(roles) - {"decode", "prefill", "unified"})
            if bad:
                errs.append(f"unknown engine roles {bad!r} (want "
                            "'prefill', 'decode' or 'unified')")
            if n_workers is not None and len(roles) != n_workers:
                errs.append(f"{len(roles)} roles for {n_workers} engines")
            if "prefill" in roles and not self.disaggregate:
                errs.append("prefill-role engines need "
                            "SAGAConfig.disaggregate=True")
            if self.disaggregate and all(r == "prefill" for r in roles):
                errs.append("disaggregation needs a decode engine "
                            "(all-prefill cluster can serve nothing)")
        if errs:
            raise ValueError("invalid SAGAConfig: " + "; ".join(errs))
        return self


@dataclass
class SessionInfo:
    session_id: str
    tenant: str
    aeg: Optional[AEG]
    node_id: int = 0
    ctx_tokens: float = 0.0
    cur_tool: str = "unknown"
    tools_seen: List[str] = field(default_factory=list)
    prefix_tokens: float = 0.0
    # tier-a explicit graph (client-declared AEG): node advancement
    # follows the substrate-reported taken edge, prefetch targets the
    # resolved next node, and AFS work is re-estimated from Eq. 9
    declared: bool = False
    step_cost_s: float = 0.0      # mean GPU-seconds per step (Eq. 9)


class GlobalCoordinator:
    def __init__(self, cfg: SAGAConfig, n_workers: int,
                 worker_capacity_bytes: float):
        cfg.validate()
        self.cfg = cfg
        self.n_workers = n_workers
        self.capacity = worker_capacity_bytes
        self.sessions: Dict[str, SessionInfo] = {}
        self.stats = ToolStats()
        self.ttl = ToolTTLPolicy(p=cfg.ttl_percentile,
                                 ttl_max_s=cfg.ttl_max_s)
        self.router = SessionRouter(theta=cfg.theta)
        self.stealer = WorkStealer(t_idle_s=cfg.t_idle_s, r_max=cfg.r_max,
                                   seed=cfg.seed)
        self.afs = AFSScheduler(epoch_s=cfg.epoch_s,
                                preempt_block_s=cfg.preempt_block_s)
        self.prefetcher = SpeculativePrefetcher()
        self.inferencer = PatternInferencer(theta_conf=cfg.theta_conf,
                                            min_tasks=cfg.min_tasks)
        self.pools: List[WALRUCache] = [self._make_pool()
                                        for _ in range(n_workers)]
        self.alive = [True] * n_workers
        self._n_dead = 0
        if np is not None:
            self._alive_np = np.ones(n_workers, dtype=bool)
        # incremental aggregates: total cached bytes across pools and a
        # session -> {workers whose pool holds its entry} index, so
        # memory sampling and task teardown are O(sites touched), not
        # O(n_workers)
        self.pools_used = 0.0
        self._sites: Dict[str, Set[int]] = {}
        # disaggregated pools (cfg.disaggregate): workers the serving
        # runtime declared as prefill-role.  Routing masks them to INF
        # (Eq. 7 decides decode placement only) and they never enter the
        # work stealer's idle set.
        self.prefill_workers: Set[int] = set()
        # instrumentation
        self.cache_hits = 0
        self.cache_misses = 0
        self.regen_tokens = 0.0

    def set_worker_role(self, worker: int, role: str) -> None:
        """Declare a worker's engine role (``prefill`` / ``decode`` /
        ``unified``).  Only ``prefill`` changes behaviour: the worker is
        excluded from Eq. 7 routing and from the steal idle set."""
        if role == "prefill":
            self.prefill_workers.add(worker)
        else:
            self.prefill_workers.discard(worker)

    def cached_sites(self, session_id: str) -> Tuple[int, ...]:
        """Workers whose pool currently holds an entry for the session
        (home + prefetch replicas), sorted.  The serving runtime frees
        the matching real KV blocks when a task finishes."""
        return tuple(sorted(self._sites.get(session_id, ())))

    def _site_add(self, session_id: str, worker: int) -> None:
        self._sites.setdefault(session_id, set()).add(worker)

    def _site_discard(self, session_id: str, worker: int) -> None:
        s = self._sites.get(session_id)
        if s is not None:
            s.discard(worker)
            if not s:
                del self._sites[session_id]

    # ------------------------------------------------------------------
    def _make_pool(self) -> WALRUCache:
        w = EvictionWeights(self.cfg.alpha, self.cfg.beta, self.cfg.gamma)
        if self.cfg.cache_policy == "none":
            return LRUCache(0.0, w)          # nothing survives a request
        if self.cfg.cache_policy == "lru":
            return LRUCache(self.capacity, w)
        if self.cfg.cache_policy == "prefix":
            return PrefixLRUCache(self.capacity, w,
                                  prefix_fraction=self.cfg.prefix_fraction)
        return WALRUCache(self.capacity, w, p_reuse_fn=self._p_reuse)

    def _p_reuse(self, entry: CacheEntry) -> float:
        info = self.sessions.get(entry.session_id)
        if info is None or info.aeg is None:
            return 0.5
        return info.aeg.p_reuse(info.node_id, info.ctx_tokens, self.stats)

    # -- session lifecycle ----------------------------------------------
    def register_task(self, session_id: str, tenant: str,
                      planned_tools: Optional[Sequence[str]],
                      deadline: float, work_est_s: float,
                      now: float, prefix_tokens: float = 0.0,
                      aeg: Optional[AEG] = None,
                      step_cost_s: float = 0.0,
                      entry_node: int = 0) -> None:
        """Admit a workflow.  ``aeg`` is the client-declared execution
        graph (tier-a observability, §3.3): honored only when the
        scheduler is configured to see workflow hints — baselines that
        model request-level systems (``observability="none"``) stay
        blind even when the client declares, and ``"pattern"`` mode
        deliberately ignores hints to measure inference quality.  With
        a declared graph, node advancement follows the taken edge
        reported by the substrate (``on_step_end(next_node=...)``) and
        AFS work-remaining re-estimates from Eq. 9 each step."""
        declared = False
        node_id = 0
        if aeg is not None and self.cfg.observability == "hints":
            declared = True
            node_id = entry_node
        else:
            aeg = None
            if self.cfg.observability == "hints" and planned_tools:
                aeg = AEG.linear_chain(list(planned_tools))
            elif self.cfg.observability == "pattern":
                first = planned_tools[0] if planned_tools else "unknown"
                aeg = self.inferencer.infer(first)
        self.sessions[session_id] = SessionInfo(
            session_id, tenant, aeg, node_id=node_id,
            prefix_tokens=prefix_tokens, declared=declared,
            step_cost_s=step_cost_s)
        if self.cfg.enable_afs:
            self.afs.add_task(TaskProgress(session_id, tenant, deadline,
                                           work_est_s))

    def task_finished(self, session_id: str, now: float) -> None:
        info = self.sessions.pop(session_id, None)
        if info is not None:
            self.inferencer.record_trace(info.tools_seen)
        self.afs.finish_task(session_id)
        self.router.forget(session_id)
        # a prefetch issued during the final tool gap can never resolve:
        # account its copy as waste instead of leaking the job
        self.prefetcher.cancel(session_id)
        # only the workers whose pool actually holds the session (the
        # sites index) — not a cluster-wide sweep.  Explicit unpin
        # before removal: a hit entry pinned at the final step's start
        # must not survive as an unevictable ghost if removal is ever
        # made lazy.
        for w in sorted(self._sites.pop(session_id, ())):
            self.unpin(session_id, w)
            e = self.pools[w].remove(session_id)
            if e is not None:
                self.pools_used -= e.size_bytes

    # -- routing (Eq. 7) ---------------------------------------------------
    def route(self, session_id: str, loads: Sequence[float],
              now: float) -> int:
        if np is not None and isinstance(loads, np.ndarray):
            # numpy fast path (the simulator's incremental load vector):
            # dead-worker masking and argmin run in C
            if self._n_dead:
                loads = np.where(self._alive_np[:len(loads)], loads, INF)
            if self.prefill_workers:
                # disaggregated pools: Eq. 7 decides DECODE placement
                # only — prefill-role workers are never a routing target
                loads = loads.astype(float, copy=True)
                loads[sorted(self.prefill_workers)] = INF
            if not self.cfg.enable_affinity:
                return int(loads.argmin())
        else:
            loads = [INF if (not self.alive[i]
                             or i in self.prefill_workers) else l
                     for i, l in enumerate(loads)]
            if not self.cfg.enable_affinity:
                return min(range(len(loads)), key=lambda i: loads[i])
        return self.router.route(
            session_id, loads,
            cached=lambda w, s: self.pools[w].contains(s))

    # -- cache events -------------------------------------------------------
    def on_step_start(self, session_id: str, worker: int,
                      ctx_tokens: float, now: float
                      ) -> Tuple[bool, float, float]:
        """Session begins an LLM step on `worker`.  Returns
        (cache_hit, prefill_tokens, background_tokens):
          hit  -> (True, delta_since_cached, 0): only the tool
                  observation + new prompt prefill.
          miss + correct speculative prefetch -> (False, delta, suffix):
                  the suffix regeneration ran as BACKGROUND prefill
                  during the tool gap (the simulator charges it to the
                  worker's prefill server if it had idle capacity —
                  prefetch hides latency, never compute).
          miss -> (False, regen, 0): full (or radix-suffix) regeneration
                  on the critical path."""
        info = self.sessions.get(session_id)
        pool = self.pools[worker]
        entry = pool.lookup(session_id, now)
        prefetch_hit = False
        if info is not None and self.cfg.enable_prefetch:
            # declared graphs: the taken edge was resolved at the park
            # boundary, so the step being started IS node_id and the
            # prefetch (targeted at it) resolves exactly; linear-chain
            # sessions keep the legacy successor-id convention
            expected = info.node_id if info.declared \
                else info.node_id + 1
            prefetch_hit = self.prefetcher.resolve(
                session_id, expected, now)
        if entry is not None:
            entry.pinned = True
            self.cache_hits += 1
            return True, max(0.0, ctx_tokens - entry.tokens), 0.0
        self.cache_misses += 1
        regen = ctx_tokens
        if isinstance(pool, PrefixLRUCache) and info is not None:
            regen = max(0.0, ctx_tokens - info.prefix_tokens)
        if prefetch_hit and info is not None:
            cached = info.ctx_tokens
            delta = max(0.0, ctx_tokens - cached)
            self.regen_tokens += cached
            return False, delta, min(regen, cached)
        self.regen_tokens += regen
        return False, regen, 0.0

    def ensure_headroom(self, worker: int, active_kv_bytes: float,
                        required_bytes: float, now: float) -> int:
        """Evict idle entries until a new step's KV fits next to the
        running requests (vLLM preempts idle blocks the same way).
        Returns number of evictions."""
        pool = self.pools[worker]
        n = 0
        while (pool.used + active_kv_bytes + required_bytes > self.capacity
               and pool.entries):
            victim = pool.select_victim(now)
            if victim is None:
                break
            pool.remove(victim.session_id)
            self.pools_used -= victim.size_bytes
            self._site_discard(victim.session_id, worker)
            pool.evictions += 1
            pool.bytes_evicted += victim.size_bytes
            n += 1
        return n

    def drop_entry(self, session_id: str, worker: int,
                   count_eviction: bool = True) -> Optional[CacheEntry]:
        """Remove one pool entry and keep every aggregate (bytes total,
        sites index, eviction counters) in sync.  The serving runtime's
        event-driven WA-LRU reconciliation uses this instead of the old
        per-step scan over every cached session."""
        pool = self.pools[worker]
        e = pool.remove(session_id)
        if e is None:
            return None
        self.pools_used -= e.size_bytes
        self._site_discard(session_id, worker)
        if count_eviction:
            pool.evictions += 1
            pool.bytes_evicted += e.size_bytes
        return e

    def replicate_entry(self, session_id: str, src: int, dst: int,
                        now: float) -> Tuple[bool, List[CacheEntry]]:
        """Speculative prefetch landing (§4.3): clone ``src``'s pool
        entry into ``dst`` — the source keeps its copy, unlike
        ``migrate_session``.  Returns (inserted, evicted_at_dst) so the
        caller can mirror the real KV blocks (copy on success, evict the
        victims' blocks either way)."""
        e = self.pools[src].entries.get(session_id)
        if e is None or self.pools[dst].contains(session_id):
            return False, []
        clone = CacheEntry(session_id=session_id, size_bytes=e.size_bytes,
                           t_last=now, tokens=e.tokens, node_id=e.node_id,
                           ttl_deadline=e.ttl_deadline)
        dst_pool = self.pools[dst]
        used_before = dst_pool.used
        evicted = dst_pool.insert(clone, now)
        self.pools_used += dst_pool.used - used_before
        for ev in evicted:
            self._site_discard(ev.session_id, dst)
        if dst_pool.contains(session_id):
            self._site_add(session_id, dst)
            return True, evicted
        return False, evicted

    def unpin(self, session_id: str, worker: int) -> None:
        """Release the decode-time pin taken by ``on_step_start`` on a
        cache hit.  Called on step end and task finish; without it a
        pinned entry is only released by wholesale replacement, which a
        cancelled (fault-aborted) step never performs."""
        e = self.pools[worker].entries.get(session_id)
        if e is not None:
            e.pinned = False

    def on_step_end(self, session_id: str, worker: int, ctx_tokens: float,
                    entry_bytes: float, next_tool: str, now: float,
                    next_node: Optional[int] = None) -> List[CacheEntry]:
        """LLM step done; session enters a tool call.  Unpins the
        step's hit entry, then inserts/updates the cache entry with a
        tool-aware TTL and maybe issues a prefetch.  ``next_node`` is
        the AEG node the *taken edge* leads to (declared graphs —
        branch/retry structure); None keeps the legacy linear
        advancement.  Returns evicted entries."""
        self.unpin(session_id, worker)
        info = self.sessions.get(session_id)
        if info is not None:
            info.node_id = info.node_id + 1 if next_node is None \
                else next_node
            info.ctx_tokens = ctx_tokens
            info.cur_tool = next_tool
            info.tools_seen.append(next_tool)
            if (self.cfg.observability == "pattern"
                    and info.aeg is not None):
                info.aeg = self.inferencer.infer(next_tool)
            if (info.declared and info.aeg is not None
                    and info.step_cost_s > 0.0 and self.cfg.enable_afs):
                # Eq. 9 on the true branch structure: expected remaining
                # steps from the node the taken edge reached
                self.afs.set_work(
                    session_id,
                    info.aeg.work_remaining_steps(info.node_id)
                    * info.step_cost_s)
        evicted = self._insert_ttl_entry(session_id, worker, ctx_tokens,
                                         entry_bytes, next_tool, now,
                                         info.node_id if info else 0)
        pool = self.pools[worker]
        if info is not None and self.cfg.enable_prefetch:
            # declared graphs prefetch the RESOLVED next node (the taken
            # edge, known at this park boundary) instead of speculating
            # on the argmax successor
            target = info.node_id if info.declared else None
            self.prefetcher.maybe_issue(session_id, info.aeg, info.node_id,
                                        entry_bytes, now,
                                        pool.utilization(), target=target,
                                        worker=worker)
        return evicted

    def _insert_ttl_entry(self, session_id: str, worker: int,
                          ctx_tokens: float, entry_bytes: float,
                          tool: str, now: float,
                          node_id: int) -> List[CacheEntry]:
        """Insert/replace a session's pool entry with a tool-aware TTL
        and reconcile every aggregate (bytes total, sites index) — the
        shared tail of ``on_step_end`` and ``preempt_park``, factored so
        the accounting ``check_conservation`` guards lives once."""
        pool = self.pools[worker]
        m = memory_pressure(pool.utilization(), self.cfg.th_low,
                            self.cfg.th_high)
        deadline = None
        if self.cfg.enable_ttl:
            deadline = self.ttl.deadline(tool, now, m)
        entry = CacheEntry(session_id=session_id, size_bytes=entry_bytes,
                           t_last=now, tokens=ctx_tokens,
                           node_id=node_id, ttl_deadline=deadline)
        used_before = pool.used
        evicted = pool.insert(entry, now)
        self.pools_used += pool.used - used_before
        for ev in evicted:
            self._site_discard(ev.session_id, worker)
        if pool.contains(session_id):
            self._site_add(session_id, worker)
        else:            # replaced-but-didn't-fit: old entry is gone too
            self._site_discard(session_id, worker)
        return evicted

    def preempt_park(self, session_id: str, worker: int,
                     ctx_tokens: float, entry_bytes: float,
                     now: float) -> List[CacheEntry]:
        """AFS preemption parked a RUNNING decode mid-step (§6.2): the
        victim's slot KV moves to the pool so a starved session can take
        the slot, and it resumes later with a delta-only prefill.  Like
        ``on_step_end`` this unpins and inserts a TTL-stamped entry, but
        the step is NOT over: the AEG cursor does not advance, tool
        stats see nothing, and no prefetch is speculated (the session
        is going back on the queue, not into a tool gap).  TTL uses the
        tool the session is between — preemption must not demote its
        survival odds below a same-aged tool park (§3.1: predictions
        survive preemption).  Returns evicted entries so the caller can
        free the victims' real blocks."""
        self.unpin(session_id, worker)
        info = self.sessions.get(session_id)
        return self._insert_ttl_entry(
            session_id, worker, ctx_tokens, entry_bytes,
            info.cur_tool if info is not None else "unknown", now,
            info.node_id if info else 0)

    def handoff_land(self, session_id: str, worker: int,
                     ctx_tokens: float, entry_bytes: float,
                     now: float) -> Tuple[bool, List[CacheEntry]]:
        """A prefill→decode KV handoff landed on ``worker`` (disagg
        mode): the staged blocks are now a parked prefix there, so WA-LRU
        must see them.  Inserts a pinned TTL entry — pinned because the
        session is about to resume on this prefix, exactly like a hit's
        ``on_step_start`` pin; ``on_step_end`` unpins as usual.  No
        hit/miss accounting: the step's verdict was counted when the
        prefill job was admitted.  Returns (inserted, evicted) so the
        caller mirrors the real blocks."""
        info = self.sessions.get(session_id)
        evicted = self._insert_ttl_entry(
            session_id, worker, ctx_tokens, entry_bytes,
            info.cur_tool if info is not None else "unknown", now,
            info.node_id if info else 0)
        e = self.pools[worker].entries.get(session_id)
        if e is not None:
            e.pinned = True
        return self.pools[worker].contains(session_id), evicted

    def on_tool_done(self, session_id: str, tool: str, latency_s: float,
                     obs_tokens: float, now: float) -> None:
        self.stats.observe(tool, obs_tokens, latency_s)
        self.ttl.observe(tool, latency_s)

    # -- stealing / migration ------------------------------------------------
    def on_worker_idle(self, worker: int, now: float) -> None:
        """A worker's pending queue just went empty — enter the indexed
        idle set with the *exact* transition time (the legacy per-epoch
        scan quantized idle starts to epoch boundaries).  Prefill-role
        workers never enter the idle set: decode stealers must not raid
        the prefill pool (and a prefill engine has no decode queue to
        accrue steal credit from) — this guard also covers the
        recover/scale-up paths, which re-announce idleness here."""
        if worker in self.prefill_workers:
            return
        if self.cfg.enable_stealing and self.alive[worker]:
            self.stealer.note_queue_state(worker, True, now)

    def on_worker_busy(self, worker: int) -> None:
        """A worker's pending queue just became non-empty — leave the
        idle set (O(1))."""
        if self.cfg.enable_stealing:
            self.stealer.note_queue_state(worker, False, 0.0)

    def epoch_tick(self, now: float, loads: Sequence[float],
                   queues: Sequence[Sequence[Tuple[float, str]]],
                   alive: Optional[Sequence[bool]] = None, *,
                   victim_candidates: Optional[Sequence[int]] = None,
                   scan_queues: bool = True
                   ) -> Tuple[Optional[StealDecision], Dict[str, float]]:
        """Per-epoch AFS share recompute + steal decision.  ``alive``
        defaults to the coordinator's own liveness view; dead workers
        are treated as not-idle (their empty queues must not accrue
        steal credit) and are excluded from thief and victim roles.

        ``scan_queues=True`` (legacy) refreshes the stealer's idle set
        by walking every worker queue.  Callers that report queue-depth
        transitions through ``on_worker_idle``/``on_worker_busy`` (the
        simulator) pass ``scan_queues=False`` plus their nonempty-queue
        index as ``victim_candidates``, making the tick O(changes)
        instead of O(n_workers)."""
        if alive is None:
            alive = self.alive
        shares = self.afs.recompute(now) if self.cfg.enable_afs else {}
        decision = None
        if self.cfg.enable_stealing:
            if scan_queues:
                for w in range(len(loads)):
                    up = w < len(alive) and alive[w]
                    self.stealer.note_queue_state(w, up and not queues[w],
                                                  now)
            decision = self.stealer.maybe_steal(
                now, loads, queues, alive=alive,
                candidates=victim_candidates)
        return decision, shares

    def migrate_session(self, session_id: str, src: int, dst: int,
                        now: float) -> Tuple[float, List[CacheEntry]]:
        """Move a session's cache entry (Llumnix-style).  TTL state moves
        with it (§3.1).  Returns (bytes migrated, entries evicted at the
        destination) — the serving runtime frees the victims' real KV
        blocks from the evicted list."""
        entry = self.pools[src].remove(session_id)
        if entry is None:
            return 0.0, []
        self.pools_used -= entry.size_bytes
        self._site_discard(session_id, src)
        entry.t_last = now
        dst_pool = self.pools[dst]
        used_before = dst_pool.used
        evicted = dst_pool.insert(entry, now)
        self.pools_used += dst_pool.used - used_before
        for ev in evicted:
            self._site_discard(ev.session_id, dst)
        if dst_pool.contains(session_id):
            self._site_add(session_id, dst)
        self.router.set_home(session_id, dst)
        return entry.size_bytes, evicted

    # -- fault tolerance -------------------------------------------------
    def worker_failed(self, worker: int) -> List[str]:
        """Worker dies: cache lost (pool wiped, so any pinned hit
        entries go with it), affinities dropped, liveness flag cleared
        — routing/stealing consult it from here on.  Sessions re-route
        on their next step and pay cache-loss regeneration (§3.1); the
        simulator pairs this with cancelling the worker's in-flight
        steps and requeueing them on live workers.  Returns the session
        ids whose state was lost."""
        if not self.alive[worker]:
            return []
        self.alive[worker] = False
        self._n_dead += 1
        if np is not None:
            self._alive_np[worker] = False
        pool = self.pools[worker]
        lost = list(pool.entries)
        self.pools_used -= pool.used
        for sid in lost:
            self._site_discard(sid, worker)
        self.pools[worker] = self._make_pool()
        dropped = self.router.evict_worker(worker)
        # NOTE: in-flight prefetch jobs are NOT cancelled here — on the
        # simulator they model background regenerations that run wherever
        # the next step lands, so they survive the source's death.  The
        # serving runtime, whose jobs are real block copies sourced from
        # the dead engine, calls ``prefetcher.cancel_worker`` itself.
        # dead workers leave the indexed idle set: an empty queue on a
        # corpse must not accrue steal credit
        self.stealer.note_queue_state(worker, False, 0.0)
        return sorted(set(lost) | set(dropped))

    def worker_recovered(self, worker: int, now: float = 0.0) -> None:
        if self.alive[worker]:
            return
        self.alive[worker] = True
        self._n_dead -= 1
        if np is not None:
            self._alive_np[worker] = True
        # a recovered worker comes back with an empty queue: idle now
        self.on_worker_idle(worker, now)

    def add_worker(self, now: float = 0.0) -> int:
        self.pools.append(self._make_pool())
        self.alive.append(True)
        if np is not None:
            self._alive_np = np.append(self._alive_np, True)
        self.n_workers += 1
        self.on_worker_idle(self.n_workers - 1, now)
        return self.n_workers - 1

    # -- checkpoint/restart ------------------------------------------------
    @staticmethod
    def _session_snap(v: SessionInfo) -> dict:
        snap = {
            "tenant": v.tenant, "node_id": v.node_id,
            "ctx_tokens": v.ctx_tokens, "cur_tool": v.cur_tool,
            "tools_seen": list(v.tools_seen),
            "prefix_tokens": v.prefix_tokens,
            "declared": v.declared, "step_cost_s": v.step_cost_s,
        }
        if v.declared and v.aeg is not None:
            # the declared graph must survive restarts: Eq. 9 set_work
            # and prefetch targeting run on it after restore
            snap["aeg_nodes"] = {int(nid): n.tool
                                 for nid, n in v.aeg.nodes.items()}
            snap["aeg_edges"] = [(int(nid), int(u), float(p))
                                 for nid, n in v.aeg.nodes.items()
                                 for u, p in n.succs]
            snap["aeg_p_term"] = v.aeg.p_term
        return snap

    def snapshot(self) -> dict:
        return {
            "cfg": asdict(self.cfg),
            "router_home": dict(self.router.home),
            "sessions": {k: self._session_snap(v)
                         for k, v in self.sessions.items()},
            "ttl_hist": {k: list(v) for k, v in self.ttl.hist.items()},
            "inferencer_counts": {a: dict(b) for a, b in
                                  self.inferencer.counts.items()},
            "inferencer_n": self.inferencer.n_tasks,
            "alive": list(self.alive),
        }

    def restore(self, snap: dict) -> None:
        self.router.home = dict(snap["router_home"])
        for k, sv in snap["sessions"].items():
            info = SessionInfo(k, sv["tenant"], None, sv["node_id"],
                               sv["ctx_tokens"], sv["cur_tool"],
                               list(sv["tools_seen"]), sv["prefix_tokens"],
                               declared=sv.get("declared", False),
                               step_cost_s=sv.get("step_cost_s", 0.0))
            if info.declared and sv.get("aeg_nodes"):
                # rebuild the declared graph exactly (int() for snapshots
                # that round-tripped through JSON string keys)
                tools = {int(n): t for n, t in sv["aeg_nodes"].items()}
                edges = [(int(u), int(w), float(p))
                         for u, w, p in sv["aeg_edges"]]
                info.aeg = AEG.from_edges(
                    tools, edges, p_term=sv.get("aeg_p_term", 0.03))
            elif self.cfg.observability == "hints":
                info.declared = False      # graph lost: fall back to
                info.aeg = AEG.linear_chain(   # linear-chain hints
                    info.tools_seen[-1:] * 4 or ["unknown"])
            self.sessions[k] = info
        self.ttl.hist = {k: list(v) for k, v in snap["ttl_hist"].items()}
        for a, b in snap["inferencer_counts"].items():
            for c, n in b.items():
                self.inferencer.counts[a][c] = n
        self.inferencer.n_tasks = snap["inferencer_n"]
        self.alive = list(snap["alive"])
        # resync the liveness mirrors the numpy route() fast path and
        # the fail/recover transition counters depend on
        self._n_dead = sum(1 for a in self.alive if not a)
        if np is not None:
            self._alive_np = np.array(self.alive, dtype=bool)
