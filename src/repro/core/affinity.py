"""Session-affinity routing (paper §5.1, Eq. 7).

    route(r) = w_s*                 if load(w_s*) < theta and cached(w_s*, s)
             = argmin_w load(w)     otherwise

theta = 0.8 reserves 20% headroom (Table 9: TCT varies <5% for
theta in [0.6, 0.95]).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence


class SessionRouter:
    def __init__(self, theta: float = 0.8):
        self.theta = theta
        self.home: Dict[str, int] = {}          # session -> worker id
        # instrumentation
        self.affinity_hits = 0
        self.affinity_misses = 0

    def route(self, session_id: str, loads: Sequence[float],
              cached: Callable[[int, str], bool]) -> int:
        """Eq. 7.  loads[w] in [0,1]; cached(w, s) checks the KV pool."""
        w_star = self.home.get(session_id)
        if (w_star is not None and w_star < len(loads)
                and loads[w_star] < self.theta
                and cached(w_star, session_id)):
            self.affinity_hits += 1
            return w_star
        self.affinity_misses += 1
        if hasattr(loads, "argmin"):        # numpy load vector: C argmin
            w = int(loads.argmin())
        else:
            w = min(range(len(loads)), key=lambda i: loads[i])
        self.home[session_id] = w
        return w

    def set_home(self, session_id: str, worker: int) -> None:
        self.home[session_id] = worker

    def forget(self, session_id: str) -> None:
        self.home.pop(session_id, None)

    def evict_worker(self, worker: int) -> Sequence[str]:
        """Worker died / removed: drop its affinities (fault tolerance)."""
        dropped = [s for s, w in self.home.items() if w == worker]
        for s in dropped:
            del self.home[s]
        return dropped
