"""Randomized work stealing with thrashing safeguards (paper §5.2).

Triggers: (1) a worker's queue empty for T_idle = 100 ms, or (2) the
max/min load ratio exceeds R_max = 2.0.

Steal protocol: idle worker w_i picks a victim w_j uniformly at random
among overloaded workers, takes the OLDEST pending session, migrates its
KV cache (Llumnix-style; mean 230 ms / P95 890 ms per Table 7), then
re-homes affinity to w_i.

Safeguards (§5.2): (a) both trigger conditions must hold simultaneously;
(b) a migrated session re-establishes affinity at the thief so a second
migration of the same session is structurally prevented (cooldown);
(c) migration is asynchronous at the source, and a stale steal request
arriving after the victim refilled is rejected at acceptance time.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class StealDecision:
    thief: int
    victim: int
    session_id: str


class WorkStealer:
    def __init__(self, t_idle_s: float = 0.100, r_max: float = 2.0,
                 migration_cooldown_s: float = 5.0, seed: int = 0):
        self.t_idle = t_idle_s
        self.r_max = r_max
        self.cooldown = migration_cooldown_s
        self.rng = random.Random(seed)
        self.idle_since: Dict[int, float] = {}
        self.last_migrated: Dict[str, float] = {}
        # instrumentation
        self.steals = 0
        self.rejected_stale = 0

    def note_queue_state(self, worker: int, empty: bool, now: float) -> None:
        """Transition hook: ``idle_since`` *is* the indexed idle-worker
        set — a dict keyed by worker id holding the time its queue went
        empty.  O(1) membership add/remove; callers invoke it on
        queue-depth transitions (and the legacy per-epoch scan path
        refreshes it wholesale, which is idempotent)."""
        if empty:
            self.idle_since.setdefault(worker, now)
        else:
            self.idle_since.pop(worker, None)

    def _idle_ok(self, worker: int, now: float) -> bool:
        t0 = self.idle_since.get(worker)
        return t0 is not None and (now - t0) >= self.t_idle

    def maybe_steal(self, now: float, loads: Sequence[float],
                    queues: Sequence[Sequence[Tuple[float, str]]],
                    alive: Optional[Sequence[bool]] = None,
                    candidates: Optional[Sequence[int]] = None
                    ) -> Optional[StealDecision]:
        """queues[w] = [(enqueue_time, session_id), ...] oldest-first.

        Returns a decision or None.  Safeguard (a): requires an idle
        thief AND a victim above the load-ratio threshold at the same
        instant.  ``alive`` masks dead workers out of both roles: a
        dead worker has an empty queue and so accrues idle time, but
        stealing onto it would strand the session forever.

        Thieves come from the indexed idle set (``idle_since``), not a
        cluster-wide scan; ``candidates`` optionally restricts the
        victim scan to workers known to have pending work (the
        simulator passes its nonempty-queue index), making the whole
        call O(idle + nonempty) instead of O(n_workers).
        """
        n = len(loads)
        if candidates is not None and not candidates:
            return None        # no queue anywhere: nothing to steal

        def _ok(w: int) -> bool:
            return alive is None or (w < len(alive) and alive[w])

        idle = sorted(w for w, t0 in self.idle_since.items()
                      if w < n and _ok(w) and (now - t0) >= self.t_idle)
        if not idle:
            return None
        lo_load = loads.min() if hasattr(loads, "min") else min(loads)
        lo = max(float(lo_load), 1e-6)
        cand = sorted(candidates) if candidates is not None else range(n)
        overloaded = [w for w in cand
                      if _ok(w) and loads[w] / lo >= self.r_max
                      and queues[w]]
        if not overloaded:
            return None
        thief = min(idle, key=lambda w: loads[w])
        victim = self.rng.choice(overloaded)     # uniform random (Blumofe)
        # oldest pending session not under migration cooldown (safeguard b)
        for t_enq, sid in queues[victim]:
            if now - self.last_migrated.get(sid, -1e18) >= self.cooldown:
                self.steals += 1
                self.last_migrated[sid] = now
                # restart (don't evict) the thief's idle clock: its
                # queue is still empty, so under transition-driven
                # updates nothing would ever re-add it
                self.idle_since[thief] = now
                return StealDecision(thief, victim, sid)
        return None

    def accept(self, decision: StealDecision, victim_queue_len: int,
               now: float, thief_alive: bool = True) -> bool:
        """Safeguard (c): reject stale steals after the victim refilled
        below the imbalance threshold, or whose thief died, for callers
        where decision and acceptance are asynchronous (a real serving
        engine).  The simulator calls this in the same epoch tick as
        maybe_steal, so there the checks cannot fire — its genuinely
        asynchronous window is the KV transfer, handled by the
        dead-destination re-route in ``_on_migr_done``."""
        if victim_queue_len == 0 or not thief_alive:
            self.rejected_stale += 1
            return False
        return True
