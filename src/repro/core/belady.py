"""Bélády-optimal offline replay + empirical competitive ratio (§7).

Definition 3: CR(A) = Cost_A(sigma) / Cost_OPT(sigma) where cost is the
total KV regeneration (tokens prefilled).  Bélády's policy evicts the
entry whose next access lies farthest in the future [Belady 1966]; we
replay recorded traces against it and against the online policies
(WA-LRU / LRU / prefix-LRU) to produce Table 2.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.walru import CacheEntry, WALRUCache


@dataclass
class Access:
    """One cache touch: session s needs its context at time t.

    tokens: context tokens that must exist (regeneration cost if the
    entry was evicted).  bytes_: entry size after this step.  tool: tool
    type entered after this step (drives TTL).  node_id: AEG position.
    """
    t: float
    session: str
    tokens: float
    bytes_: float
    node_id: int = 0
    tool: str = "unknown"
    last: bool = False
    prefix_tokens: float = 0.0      # tokens recoverable via shared prefix


INF = float("inf")


class BeladyOracle:
    """Offline-optimal eviction with full future knowledge."""

    def __init__(self, capacity_bytes: float):
        self.capacity = capacity_bytes

    def replay(self, trace: Sequence[Access]) -> float:
        # next use index per access
        next_use: List[float] = [INF] * len(trace)
        last_seen: Dict[str, int] = {}
        for i in range(len(trace) - 1, -1, -1):
            s = trace[i].session
            next_use[i] = last_seen.get(s, INF)
            last_seen[s] = i

        cached: Dict[str, float] = {}          # session -> size
        nxt: Dict[str, float] = {}             # session -> next access idx
        used = 0.0
        cost = 0.0
        for i, a in enumerate(trace):
            if a.session in cached:
                used -= cached[a.session]
                del cached[a.session]
            else:
                cost += a.tokens               # full regeneration
            if a.last:
                nxt.pop(a.session, None)
                continue
            # insert with Bélády eviction
            need = a.bytes_
            nxt[a.session] = next_use[i]
            while used + need > self.capacity and cached:
                victim = max(cached, key=lambda s: nxt.get(s, INF))
                if nxt.get(victim, INF) <= i:   # shouldn't happen
                    nxt[victim] = INF
                used -= cached.pop(victim)
            if used + need <= self.capacity:
                cached[a.session] = need
                used += need
        return cost


def replay_policy(trace: Sequence[Access], cache: WALRUCache,
                  ttl_policy=None, stats=None, aeg_lookup=None) -> float:
    """Replay an access trace through an online cache policy.

    Returns total regeneration cost in tokens.  If the cache is a
    PrefixLRUCache, a re-prefill only pays the non-prefix tokens (shared
    system-prompt/tool-definition prefix survives in the radix tree).
    """
    from repro.core.walru import PrefixLRUCache
    prefix_aware = isinstance(cache, PrefixLRUCache)

    cost = 0.0
    for a in trace:
        hit = cache.lookup(a.session, a.t)
        if hit is None:
            regen = a.tokens
            if prefix_aware:
                regen = max(0.0, a.tokens - a.prefix_tokens)
            cost += regen
            cache.tokens_regenerated += regen
        # NOTE: completed sessions are NOT removed — in a real serving
        # system the final step's cache lingers until evicted.  This is
        # the paper's central asymmetry (§4.1): recency-driven LRU keeps
        # completed sessions (they are the most recent!), while WA-LRU
        # knows completion => P_reuse = 0 and evicts them first.
        entry = CacheEntry(session_id=a.session, size_bytes=a.bytes_,
                           t_last=a.t, tokens=a.tokens, node_id=a.node_id,
                           completed=a.last)
        if ttl_policy is not None and not a.last:
            used_frac = cache.utilization()
            from repro.core.ttl import memory_pressure
            entry.ttl_deadline = ttl_policy.deadline(
                a.tool, a.t, memory_pressure(used_frac))
        cache.insert(entry, a.t)
    return cost


def competitive_ratio(policy_cost: float, opt_cost: float) -> float:
    if opt_cost <= 0:
        return 1.0 if policy_cost <= 0 else INF
    return policy_cost / opt_cost
