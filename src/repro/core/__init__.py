"""SAGA's contribution: workflow-atomic scheduling primitives.

Everything in this package is pure, deterministic Python (no jax): the
same objects drive both the discrete-event cluster simulator
(``repro.cluster``) and the real JAX serving engine (``repro.serving``).
"""
from repro.core.aeg import AEG, AEGNode, PatternInferencer, ToolStats
from repro.core.walru import CacheEntry, WALRUCache, EvictionWeights
from repro.core.ttl import ToolTTLPolicy, memory_pressure
from repro.core.belady import BeladyOracle, replay_policy, competitive_ratio
from repro.core.affinity import SessionRouter
from repro.core.stealing import WorkStealer
from repro.core.afs import AFSScheduler, TenantState
from repro.core.prefetch import SpeculativePrefetcher
from repro.core.coordinator import GlobalCoordinator, SAGAConfig

__all__ = [
    "AEG", "AEGNode", "PatternInferencer", "ToolStats",
    "CacheEntry", "WALRUCache", "EvictionWeights",
    "ToolTTLPolicy", "memory_pressure",
    "BeladyOracle", "replay_policy", "competitive_ratio",
    "SessionRouter", "WorkStealer",
    "AFSScheduler", "TenantState",
    "SpeculativePrefetcher",
    "GlobalCoordinator", "SAGAConfig",
]
