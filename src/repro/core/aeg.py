"""Agent Execution Graphs (paper §3.2) + pattern inference (§3.3).

Definition 1: G = (V, E, P, phi) — nodes are LLM inference steps, edges
carry transition probabilities, phi maps each node to a tool type.

Three observability tiers (§3.3):
  (a) explicit — the framework hands us the AEG at task admission
      (``AEG.linear_chain`` / ``AEG.from_edges``);
  (b) implicit — ``PatternInferencer`` learns tool-type transition
      probabilities from completed traces, keeping edges with
      P >= theta_conf (default 0.7);
  (c) cold-start — until ``min_tasks`` traces are seen the inferencer
      reports no AEG and the scheduler falls back to request-level
      behaviour.

``overlap`` implements Eq. 5: for linear ReAct chains the successor's
prompt is the full current context plus the tool observation, so
overlap = n_cur / (n_cur + E[n_obs]) with per-tool observation-length
EMAs (``ToolStats``).
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

TERMINAL = "__finish__"


@dataclass
class AEGNode:
    node_id: int
    tool: str                       # phi(v): tool type of the step
    succs: List[Tuple[int, float]] = field(default_factory=list)


class ToolStats:
    """Per-tool-type EMAs of observation length and tool latency."""

    def __init__(self, alpha: float = 0.1):
        self.alpha = alpha
        self.obs_len: Dict[str, float] = {}
        self.latency_hist: Dict[str, List[float]] = defaultdict(list)

    def observe(self, tool: str, obs_tokens: int, latency_s: float,
                max_hist: int = 4096) -> None:
        prev = self.obs_len.get(tool)
        self.obs_len[tool] = (obs_tokens if prev is None
                              else (1 - self.alpha) * prev +
                              self.alpha * obs_tokens)
        h = self.latency_hist[tool]
        h.append(latency_s)
        if len(h) > max_hist:
            del h[:len(h) - max_hist]

    def expected_obs_len(self, tool: str, default: float = 512.0) -> float:
        return self.obs_len.get(tool, default)


class AEG:
    """Agent Execution Graph with reuse-probability queries (Eq. 4-5)."""

    def __init__(self, nodes: Dict[int, AEGNode], p_term: float = 0.03):
        self.nodes = nodes
        self.p_term = p_term

    # -- constructors ---------------------------------------------------
    @classmethod
    def linear_chain(cls, tools: Sequence[str], p_term: float = 0.03,
                     retry_probs: Optional[Dict[int, float]] = None) -> "AEG":
        """ReAct chain: v_i -> v_{i+1} with P = 1 - p_term; optional
        backward retry edges (Fig. 3's coral edges)."""
        nodes = {}
        n = len(tools)
        for i, t in enumerate(tools):
            succs: List[Tuple[int, float]] = []
            retry = (retry_probs or {}).get(i, 0.0)
            if i + 1 < n:
                succs.append((i + 1, (1.0 - p_term) * (1.0 - retry)))
            if retry > 0 and i > 0:
                succs.append((i - 1, (1.0 - p_term) * retry))
            nodes[i] = AEGNode(i, t, succs)
        return cls(nodes, p_term)

    @classmethod
    def from_edges(cls, tools: Dict[int, str],
                   edges: Sequence[Tuple[int, int, float]],
                   p_term: float = 0.03) -> "AEG":
        nodes = {i: AEGNode(i, t) for i, t in tools.items()}
        for u, v, p in edges:
            nodes[u].succs.append((v, p))
        return cls(nodes, p_term)

    # -- queries ----------------------------------------------------------
    def successors(self, node_id: int) -> List[Tuple[int, float]]:
        node = self.nodes.get(node_id)
        return list(node.succs) if node else []

    def most_likely_successor(self, node_id: int) -> Optional[int]:
        succs = self.successors(node_id)
        if not succs:
            return None
        return max(succs, key=lambda sp: sp[1])[0]

    def overlap(self, n_current_tokens: float, succ_node: int,
                stats: ToolStats) -> float:
        """Eq. 5 for linear ReAct chains: the successor prompt is the full
        current context + the expected tool observation."""
        node = self.nodes.get(succ_node)
        tool = node.tool if node else "unknown"
        n_obs = stats.expected_obs_len(tool)
        if n_current_tokens <= 0:
            return 0.0
        return n_current_tokens / (n_current_tokens + max(n_obs, 0.0))

    def p_reuse(self, node_id: int, n_current_tokens: float,
                stats: ToolStats) -> float:
        """Eq. 4: sum over successors of P(v->u) * overlap(s, u)."""
        total = 0.0
        for u, p in self.successors(node_id):
            total += p * self.overlap(n_current_tokens, u, stats)
        return min(1.0, total)

    def work_remaining_steps(self, node_id: int, horizon: int = 256) -> float:
        """Expected number of remaining LLM steps from node_id (used by
        AFS Eq. 9).  Follows max-prob successors, discounting by edge
        probability mass, up to `horizon`."""
        steps = 0.0
        mass = 1.0
        cur = node_id
        seen = 0
        while mass > 1e-3 and seen < horizon:
            succs = self.successors(cur)
            if not succs:
                break
            u, p = max(succs, key=lambda sp: sp[1])
            cont = sum(pp for _, pp in succs)
            steps += mass * cont
            mass *= cont
            cur = u
            seen += 1
        return steps


class PatternInferencer:
    """Tier (b): infer tool-type transition structure from request
    streams (§3.3).

    Nodes are tool types (a first-order Markov abstraction of the step
    graph); an edge survives if its conditional probability exceeds
    theta_conf OR it is the argmax next-type (so prediction is always
    possible once warm).  Cold-start (tier c): below ``min_tasks``
    completed traces, ``infer()`` returns None.
    """

    def __init__(self, theta_conf: float = 0.7, min_tasks: int = 30):
        self.theta_conf = theta_conf
        self.min_tasks = min_tasks
        self.counts: Dict[str, Dict[str, int]] = defaultdict(
            lambda: defaultdict(int))
        self.n_tasks = 0

    def record_trace(self, tools: Sequence[str]) -> None:
        self.n_tasks += 1
        seq = list(tools) + [TERMINAL]
        for a, b in zip(seq[:-1], seq[1:]):
            self.counts[a][b] += 1

    @property
    def warm(self) -> bool:
        return self.n_tasks >= self.min_tasks

    def transition_probs(self, tool: str) -> Dict[str, float]:
        nxt = self.counts.get(tool)
        if not nxt:
            return {}
        tot = sum(nxt.values())
        return {b: c / tot for b, c in nxt.items()}

    def predict_next(self, tool: str) -> Optional[str]:
        probs = self.transition_probs(tool)
        if not probs:
            return None
        best, p = max(probs.items(), key=lambda kv: kv[1])
        return best if best != TERMINAL else None

    def accuracy(self, traces: Sequence[Sequence[str]]) -> float:
        """Fraction of correctly predicted next-step transitions on
        held-out traces (Table 5's 'AEG Accuracy')."""
        hit = tot = 0
        for tr in traces:
            seq = list(tr) + [TERMINAL]
            for a, b in zip(seq[:-1], seq[1:]):
                probs = self.transition_probs(a)
                if not probs:
                    continue
                pred = max(probs.items(), key=lambda kv: kv[1])[0]
                hit += int(pred == b)
                tot += 1
        return hit / tot if tot else 0.0

    def infer(self, current_tool: str, n_more: int = 8,
              p_term_default: float = 0.05) -> Optional[AEG]:
        """Build a lookahead AEG rooted at the session's current tool.

        Returns None during cold-start (tier c fallback to request-level
        scheduling, costing at most ~8% TCT on the first min_tasks tasks
        per the paper).
        """
        if not self.warm:
            return None
        nodes: Dict[int, AEGNode] = {}
        tools: Dict[int, str] = {0: current_tool}
        cur = current_tool
        edges: List[Tuple[int, int, float]] = []
        for i in range(n_more):
            probs = self.transition_probs(cur)
            if not probs:
                break
            best, p = max(probs.items(), key=lambda kv: kv[1])
            keep = {b: q for b, q in probs.items()
                    if q >= self.theta_conf or b == best}
            if best == TERMINAL:
                break
            p_go = keep.get(best, p)
            tools[i + 1] = best
            edges.append((i, i + 1, p_go))
            cur = best
        return AEG.from_edges(tools, edges, p_term=p_term_default)
