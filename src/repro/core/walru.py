"""Workflow-Aware LRU eviction (paper §4.1, Eq. 1-3).

    P_evict(s) = alpha * R_hat(s) + beta * (1 - P_reuse(s)) + gamma * S_hat(s)

with alpha=0.3, beta=0.5, gamma=0.2 (Table 9) and all terms normalized
to [0,1].  Under memory pressure the pool evicts the max-P_evict entry
until the requested bytes fit.  Graceful degradation (§1.5(6)): with no
AEG available P_reuse falls back to 0.5, collapsing WA-LRU toward
size-tie-broken LRU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class EvictionWeights:
    alpha: float = 0.3     # recency
    beta: float = 0.5      # workflow-predicted reuse (dominant)
    gamma: float = 0.2     # size (tiebreaker)


@dataclass
class CacheEntry:
    session_id: str
    size_bytes: float
    t_last: float                    # last access time (s)
    tokens: float = 0.0              # cached context tokens
    node_id: int = 0                 # current AEG node of the session
    ttl_deadline: Optional[float] = None   # tool-call TTL (§4.2)
    pinned: bool = False             # actively decoding -> not evictable
    completed: bool = False          # task finished -> dead weight


class WALRUCache:
    """One worker's KV pool under WA-LRU.

    The pool tracks bytes only — actual KV block tables live in the
    serving engine; the simulator uses this class directly.  ``p_reuse_fn``
    is injected by the coordinator: (entry) -> probability from the AEG
    (Eq. 4).  Entries inside their tool-call TTL get their predicted
    reuse honored; expired entries lose the workflow bonus.
    """

    def __init__(self, capacity_bytes: float,
                 weights: EvictionWeights = EvictionWeights(),
                 p_reuse_fn: Optional[Callable[[CacheEntry], float]] = None):
        self.capacity = capacity_bytes
        self.weights = weights
        self.p_reuse_fn = p_reuse_fn
        self.entries: Dict[str, CacheEntry] = {}
        self.used = 0.0
        # instrumentation
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_evicted = 0.0
        self.tokens_regenerated = 0.0

    # -- bookkeeping ----------------------------------------------------
    def lookup(self, session_id: str, now: float) -> Optional[CacheEntry]:
        e = self.entries.get(session_id)
        if e is not None:
            e.t_last = now
            self.hits += 1
            return e
        self.misses += 1
        return None

    def contains(self, session_id: str) -> bool:
        return session_id in self.entries

    def insert(self, entry: CacheEntry, now: float) -> List[CacheEntry]:
        """Insert (or grow) an entry, evicting as needed.  Returns the
        evicted entries (the caller charges regeneration cost when an
        evicted session later resumes)."""
        evicted: List[CacheEntry] = []
        old = self.entries.pop(entry.session_id, None)
        if old is not None:
            self.used -= old.size_bytes
        need = entry.size_bytes
        while self.used + need > self.capacity and self.entries:
            victim = self.select_victim(now)
            if victim is None:
                break
            self.remove(victim.session_id)
            self.evictions += 1
            self.bytes_evicted += victim.size_bytes
            evicted.append(victim)
        if self.used + need <= self.capacity:
            self.entries[entry.session_id] = entry
            self.used += need
        return evicted

    def remove(self, session_id: str) -> Optional[CacheEntry]:
        e = self.entries.pop(session_id, None)
        if e is not None:
            self.used -= e.size_bytes
        return e

    def utilization(self) -> float:
        return self.used / self.capacity if self.capacity else 0.0

    # -- Eq. 1-3 ----------------------------------------------------------
    def p_evict(self, e: CacheEntry, now: float, tau_max: float,
                size_max: float) -> float:
        w = self.weights
        r_hat = min(1.0, max(0.0, (now - e.t_last) / max(tau_max, 1e-9)))
        s_hat = e.size_bytes / max(size_max, 1e-9)
        p_reuse = self._p_reuse(e, now)
        return w.alpha * r_hat + w.beta * (1.0 - p_reuse) + w.gamma * s_hat

    def _p_reuse(self, e: CacheEntry, now: float) -> float:
        if e.completed:
            return 0.0
        if e.ttl_deadline is not None and now > e.ttl_deadline:
            # TTL expired: drop the workflow bonus, keep a floor
            return 0.1
        if self.p_reuse_fn is not None:
            return max(0.0, min(1.0, self.p_reuse_fn(e)))
        return 0.5    # no AEG: graceful degradation toward LRU

    def select_victim(self, now: float) -> Optional[CacheEntry]:
        # Two indexed passes over the live dict — no candidate-list
        # rebuilds.  Eviction loops call this once per victim, so the
        # three list allocations the old version made per call dominated
        # eviction storms on big pools.  First pass: normalizers.
        tau_max = 0.0
        size_max = 0.0
        n = 0
        for e in self.entries.values():
            if e.pinned:
                continue
            n += 1
            age = now - e.t_last
            if age > tau_max:
                tau_max = age
            if e.size_bytes > size_max:
                size_max = e.size_bytes
        if n == 0:
            return None
        tau_max = tau_max or 1.0
        size_max = size_max or 1.0
        best: Optional[CacheEntry] = None
        best_p = -1.0
        for e in self.entries.values():
            if e.pinned:
                continue
            p = self.p_evict(e, now, tau_max, size_max)
            if best is None or p > best_p:
                best, best_p = e, p
        return best


# --- baseline policies (for Table 2 / ablations) ---------------------------
def _lru_victim(entries) -> Optional[CacheEntry]:
    """Single-pass oldest-unpinned scan (shared by the LRU variants)."""
    best: Optional[CacheEntry] = None
    for e in entries.values():
        if not e.pinned and (best is None or e.t_last < best.t_last):
            best = e
    return best


class LRUCache(WALRUCache):
    """Standard LRU: evict the least-recently-used entry."""

    def select_victim(self, now: float):
        return _lru_victim(self.entries)


class PrefixLRUCache(WALRUCache):
    """LRU + prefix caching (vLLM-APC-like): shared prefixes (system
    prompt + tool definitions) are modelled as a protected fraction of
    each entry; eviction is LRU over the session-specific remainder, and
    a re-admitted session only regenerates its non-prefix tokens.  The
    simulator applies the regeneration discount via ``prefix_fraction``.
    """

    def __init__(self, *args, prefix_fraction: float = 0.35, **kw):
        super().__init__(*args, **kw)
        self.prefix_fraction = prefix_fraction

    def select_victim(self, now: float):
        return _lru_victim(self.entries)
