"""sagalint driver: file walking, pragma suppression, scoping, CLI.

Usage::

    python -m repro.analysis.sagalint src/repro        # lint the tree
    python -m repro.analysis.sagalint --list-rules

Exit status 0 when no unsuppressed findings, 1 otherwise; diagnostics
are ``path:line:col: rule: message`` lines on stdout.

Scoping: determinism rules assume scheduler code, where byte-identical
replay is contractual.  Files inside a ``repro`` package are therefore
only determinism-checked under the scheduler subpackages (``core`` /
``cluster`` / ``serving`` / ``workflow``); ``train``, ``launch``,
``kernels``, ``models`` etc. legitimately read clocks or environment.
Files *outside* a ``repro`` package (test fixtures, scratch trees) get
every rule.  Lifecycle rules run everywhere — they only trigger on the
repo's own acquire/release vocabulary.

Suppression: ``# sagalint: ok(<rule>[, <rule>...]) <reason>`` on the
offending line, or alone on the line above.  The reason is mandatory —
a pragma without one, and a pragma that suppresses nothing, are
themselves findings (``pragma`` / ``pragma-unused``), so suppressions
stay explained and alive.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import io
import re
import sys
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

SCHED_PKGS = {"core", "cluster", "obs", "serving", "workflow"}

# Scoped rule exemptions (configuration, not pragmas): subpackages whose
# CHARTER exempts them from specific rules.  serving/frontend is the
# wall-clock asyncio driver + HTTP proxy — reading real time is its job,
# so det-clock is off THERE AND ONLY THERE; every other determinism and
# lifecycle rule still applies.  Keys are "/"-joined path suffixes under
# the repro package.
SCOPE_EXEMPT: Dict[str, frozenset] = {
    "serving/frontend": frozenset({"det-clock"}),
}

RULES: Dict[str, str] = {
    "det-hash": "builtin hash() on non-ints (use the FNV-1a helpers)",
    "det-set-order": "set/dict.keys() iteration order escaping into an "
                     "ordering-sensitive sink",
    "det-clock": "wall-clock reads in scheduler code",
    "det-rng": "module-global or unseeded RNG",
    "det-env": "os.environ / os.getenv reads in scheduler code",
    "life-leak": "CFG path acquiring a tracked resource without "
                 "release or handoff",
    "life-guard": "_on_* event handler ignoring its attempt/generation "
                  "stamp",
    "life-span": "CFG path with a tracer.begin(...) that reaches exit "
                 "without tracer.end(...) or handoff",
    "pragma": "malformed suppression pragma (missing reason)",
    "pragma-unused": "pragma that suppresses nothing",
    "parse-error": "file does not parse",
}

_PRAGMA_RE = re.compile(r"#\s*sagalint:\s*ok\(([^)]*)\)\s*(.*)$")


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule}: {self.message}"


@dataclasses.dataclass
class _Pragma:
    line: int
    rules: Tuple[str, ...]
    reason: str
    standalone: bool            # comment-only line: applies to line+1
    used: bool = False

    def covers(self, line: int) -> bool:
        return line == self.line or (self.standalone
                                     and line == self.line + 1)


def _comments(source: str) -> List[Tuple[int, str, bool]]:
    """(line, comment_text, standalone) for every real COMMENT token —
    tokenizing (rather than line-scanning) keeps pragma syntax inside
    string literals and docstrings inert."""
    out: List[Tuple[int, str, bool]] = []
    try:
        for tok in tokenize.generate_tokens(
                io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.string,
                            tok.start[1] == 0
                            or not tok.line[:tok.start[1]].strip()))
    except (tokenize.TokenError, IndentationError):
        pass                  # ast.parse already reported the file
    return out


def _parse_pragmas(source: str, path: str,
                   findings: List[Finding]) -> List[_Pragma]:
    pragmas: List[_Pragma] = []
    for i, text, standalone in _comments(source):
        m = _PRAGMA_RE.search(text)
        if m is None:
            if "sagalint:" in text:
                findings.append(Finding(
                    path, i, 0, "pragma",
                    "unparseable sagalint pragma — expected "
                    "'# sagalint: ok(<rule>) <reason>'"))
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",")
                      if r.strip())
        reason = m.group(2).strip()
        bad = [r for r in rules if r not in RULES]
        if bad:
            findings.append(Finding(
                path, i, 0, "pragma",
                f"pragma names unknown rule(s) {bad} — known: "
                f"{sorted(RULES)}"))
        if not reason:
            findings.append(Finding(
                path, i, 0, "pragma",
                "pragma without a reason — say why the flagged "
                "construct is safe"))
        pragmas.append(_Pragma(i, rules, reason, standalone))
    return pragmas


def _determinism_in_scope(path: Path) -> bool:
    parts = path.resolve().parts
    if "repro" not in parts:
        return True                    # fixtures etc.: all rules apply
    i = len(parts) - 1 - tuple(reversed(parts)).index("repro")
    return i + 1 < len(parts) - 1 and parts[i + 1] in SCHED_PKGS


def _scope_exempt_rules(path: Path) -> frozenset:
    """Rules switched off for this file by SCOPE_EXEMPT configuration."""
    parts = path.resolve().parts
    if "repro" not in parts:
        return frozenset()
    i = len(parts) - 1 - tuple(reversed(parts)).index("repro")
    rel = "/".join(parts[i + 1:-1])           # package dirs under repro
    out: frozenset = frozenset()
    for scope, rules in SCOPE_EXEMPT.items():
        if rel == scope or rel.startswith(scope + "/"):
            out = out | rules
    return out


def lint_file(path: Path) -> List[Finding]:
    # imported here: these modules import Finding from us
    from repro.analysis.determinism import DeterminismChecker
    from repro.analysis.lifecycle import LifecycleChecker

    pstr = str(path)
    findings: List[Finding] = []
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=pstr)
    except (SyntaxError, UnicodeDecodeError, OSError) as e:
        return [Finding(pstr, getattr(e, "lineno", 0) or 0, 0,
                        "parse-error", str(e))]
    pragmas = _parse_pragmas(source, pstr, findings)

    raw: List[Finding] = []
    exempt = _scope_exempt_rules(path)
    if _determinism_in_scope(path):
        det = DeterminismChecker(pstr)
        det.visit(tree)
        raw.extend(f for f in det.findings if f.rule not in exempt)
    life = LifecycleChecker(pstr)
    life.run(tree)
    raw.extend(life.findings)

    seen = set()
    for f in raw:
        key = (f.line, f.col, f.rule, f.message)
        if key in seen:
            continue
        seen.add(key)
        suppressed = False
        for p in pragmas:
            if f.rule in p.rules and p.covers(f.line) and p.reason:
                p.used = True
                suppressed = True
                break
        if not suppressed:
            findings.append(f)
    for p in pragmas:
        if p.reason and not p.used and \
                all(r in RULES for r in p.rules):
            findings.append(Finding(
                pstr, p.line, 0, "pragma-unused",
                f"pragma ok({', '.join(p.rules)}) suppresses nothing "
                "— the finding moved or was fixed; delete the pragma"))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def _iter_files(paths: Sequence[str]) -> Iterable[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(q for q in path.rglob("*.py")
                              if "__pycache__" not in q.parts)
        else:
            yield path


def lint_paths(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    n = 0
    for f in _iter_files(paths):
        n += 1
        findings.extend(lint_file(f))
    return findings, n


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="sagalint",
        description="determinism + resource-lifecycle linter for the "
                    "SAGA scheduler tree")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, doc in sorted(RULES.items()):
            print(f"{rule:15s} {doc}")
        return 0
    if not args.paths:
        ap.error("no paths given")
    findings, n_files = lint_paths(args.paths)
    for f in findings:
        print(f.render())
    print(f"sagalint: {len(findings)} finding(s) in {n_files} "
          f"file(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
