"""Determinism rule family.

The scheduler's contract is byte-identical replay across processes and
``PYTHONHASHSEED`` values.  Every rule here flags a construct that can
silently break it:

  * ``det-hash``     builtin ``hash()`` on non-ints — randomized per
                     process for str/bytes; use the repo's FNV-1a
                     helpers (``_fnv1a`` in ``cluster.simulator`` /
                     ``workflow.program``).
  * ``det-set-order`` iteration order of a ``set`` / ``dict.keys()``
                     escaping into an ordering-sensitive sink: a
                     ``min``/``max``/``sorted`` whose key is not a
                     provable total order, ``next(iter(s))`` /
                     ``s.pop()`` arbitrary-element selection, or a
                     ``for`` over a set whose body pushes work or
                     mutates shared state.
  * ``det-clock``    wall-clock reads (``time.time``,
                     ``datetime.now``, ...) — virtual time only.
  * ``det-rng``      module-global or unseeded RNG (``random.*``,
                     ``np.random.*``, no-arg ``Random()`` /
                     ``RandomState()`` / ``default_rng()``).
  * ``det-env``      ``os.environ`` / ``os.getenv`` reads — config
                     must flow through constructors, not ambient
                     process state.

Set-typedness is inferred flow-insensitively: set literals/calls,
``.keys()``, set-operator results, ``self`` attributes assigned or
annotated as sets anywhere in the class (including ``List[set]``
element access), annotated parameters, and locals assigned from any of
those.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.sagalint import Finding

CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.clock_gettime",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today", "date.today",
    # asyncio's wall clock by idiomatic receiver name — permitted only
    # inside repro/serving/frontend via sagalint's SCOPE_EXEMPT
    # configuration (the asyncio driver's charter), never by pragma
    "loop.time", "asyncio.get_event_loop.time",
    "asyncio.get_running_loop.time",
}

# random-module functions whose call implies the process-global stream
_RANDOM_FUNCS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "lognormvariate",
    "expovariate", "betavariate", "triangular", "vonmisesvariate",
    "paretovariate", "getrandbits", "seed",
}
_RNG_CTORS = {"Random", "RandomState", "default_rng", "Generator",
              "SeedSequence"}

# calls that enqueue/schedule work: a set-ordered loop feeding one of
# these makes dispatch order depend on hash iteration order
SINK_CALLS = {
    "_queue_push", "_enqueue", "_push", "_admit", "_dispatch_to",
    "_redispatch", "schedule", "heappush", "push",
}

_SET_OPS = (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested attribute access rooted at a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _ann_is_set(ann: Optional[ast.AST]) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Name):
        return ann.id in ("set", "Set", "frozenset", "FrozenSet")
    if isinstance(ann, ast.Subscript):        # Set[int], typing.Set[...]
        return _ann_is_set(ann.value)
    if isinstance(ann, ast.Attribute):        # typing.Set
        return ann.attr in ("Set", "FrozenSet")
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        s = ann.value.strip()
        return s.startswith(("set", "Set[", "Set", "frozenset"))
    return False


def _ann_is_setlist(ann: Optional[ast.AST]) -> bool:
    """List[set] / Sequence[Set[...]] — element access is a set."""
    if isinstance(ann, ast.Subscript):
        base = ann.value
        basename = base.id if isinstance(base, ast.Name) else \
            base.attr if isinstance(base, ast.Attribute) else ""
        if basename in ("List", "list", "Sequence", "Tuple", "tuple"):
            return _ann_is_set(ann.slice)
    return False


def _value_is_setlist(node: ast.AST) -> bool:
    if isinstance(node, ast.ListComp):
        return _value_makes_set(node.elt, set(), set())
    if isinstance(node, ast.List) and node.elts:
        return all(_value_makes_set(e, set(), set()) for e in node.elts)
    return False


def _value_makes_set(node: ast.AST, set_locals: Set[str],
                     set_attrs: Set[str]) -> bool:
    if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name) and f.id in ("set", "frozenset"):
            return True
        if isinstance(f, ast.Attribute) and f.attr == "keys":
            return True
        # set.copy()/union()/... preserve unorderedness
        if isinstance(f, ast.Attribute) and f.attr in (
                "copy", "union", "intersection", "difference",
                "symmetric_difference") and _value_makes_set(
                    f.value, set_locals, set_attrs):
            return True
        return False
    if isinstance(node, ast.BinOp) and isinstance(node.op, _SET_OPS):
        return (_value_makes_set(node.left, set_locals, set_attrs)
                or _value_makes_set(node.right, set_locals, set_attrs))
    if isinstance(node, ast.Name):
        return node.id in set_locals
    if isinstance(node, ast.Attribute):
        return (isinstance(node.value, ast.Name)
                and node.value.id == "self" and node.attr in set_attrs)
    return False


class _ClassTypes(ast.NodeVisitor):
    """Collect self-attributes that hold sets (or lists of sets)
    anywhere in a class body."""

    def __init__(self) -> None:
        self.set_attrs: Set[str] = set()
        self.setlist_attrs: Set[str] = set()

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        t = node.target
        if isinstance(t, ast.Attribute) and \
                isinstance(t.value, ast.Name) and t.value.id == "self":
            if _ann_is_set(node.annotation):
                self.set_attrs.add(t.attr)
            elif _ann_is_setlist(node.annotation):
                self.setlist_attrs.add(t.attr)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Attribute) and \
                    isinstance(t.value, ast.Name) and t.value.id == "self":
                if _value_makes_set(node.value, set(), self.set_attrs):
                    self.set_attrs.add(t.attr)
                elif _value_is_setlist(node.value):
                    self.setlist_attrs.add(t.attr)
        self.generic_visit(node)


class DeterminismChecker(ast.NodeVisitor):
    """One pass over a module; collects findings on ``self.findings``."""

    def __init__(self, path: str) -> None:
        self.path = path
        self.findings: List[Finding] = []
        self._class_types: Dict[str, _ClassTypes] = {}
        self._cls_stack: List[str] = []
        self._set_locals_stack: List[Set[str]] = [set()]

    # -- context --------------------------------------------------------
    @property
    def _types(self) -> Optional[_ClassTypes]:
        return self._class_types.get(self._cls_stack[-1]) \
            if self._cls_stack else None

    @property
    def _set_locals(self) -> Set[str]:
        return self._set_locals_stack[-1]

    def _emit(self, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(Finding(
            self.path, getattr(node, "lineno", 0),
            getattr(node, "col_offset", 0), rule, msg))

    def _unordered(self, node: ast.AST) -> bool:
        t = self._types
        if _value_makes_set(node, self._set_locals,
                            t.set_attrs if t else set()):
            return True
        # element of a list-of-sets attribute: self._active[w]
        if isinstance(node, ast.Subscript) and t is not None:
            v = node.value
            if isinstance(v, ast.Attribute) and \
                    isinstance(v.value, ast.Name) and \
                    v.value.id == "self" and v.attr in t.setlist_attrs:
                return True
        return False

    # -- scoping --------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        ct = _ClassTypes()
        ct.visit(node)
        self._class_types[node.name] = ct
        self._cls_stack.append(node.name)
        self.generic_visit(node)
        self._cls_stack.pop()

    def _visit_fn(self, node) -> None:
        locs: Set[str] = set()
        for a in node.args.args + node.args.kwonlyargs:
            if _ann_is_set(a.annotation):
                locs.add(a.arg)
        t = self._types
        attrs = t.set_attrs if t else set()
        # flow-insensitive: two fixpoint-ish sweeps pick up chained
        # assignments (a = set(); b = a)
        for _ in range(2):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) and \
                        _value_makes_set(sub.value, locs, attrs):
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name):
                            locs.add(tgt.id)
        self._set_locals_stack.append(locs)
        self.generic_visit(node)
        self._set_locals_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    # -- det-env --------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if _dotted(node) == "os.environ":
            self._emit(node, "det-env",
                       "os.environ read in scheduler code — pass "
                       "configuration through constructors so replay "
                       "does not depend on ambient process state")
        self.generic_visit(node)

    # -- call-shaped rules ----------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        name = _dotted(f)
        if isinstance(f, ast.Name) and f.id == "hash":
            if not (node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, int)):
                self._emit(node, "det-hash",
                           "builtin hash() is randomized per process "
                           "for str/bytes — use the FNV-1a helper "
                           "(_fnv1a) for stable hashing")
        elif name in CLOCK_CALLS:
            self._emit(node, "det-clock",
                       f"wall-clock read {name}() in scheduler code — "
                       "use the event loop's virtual clock")
        elif name == "os.getenv":
            self._emit(node, "det-env",
                       "os.getenv in scheduler code — pass "
                       "configuration through constructors")
        elif name is not None:
            self._check_rng(node, name)
        if isinstance(f, ast.Name) and f.id in ("min", "max", "sorted"):
            self._check_order_call(node, f.id)
        if isinstance(f, ast.Name) and f.id == "next" and node.args:
            inner = node.args[0]
            if isinstance(inner, ast.Call) and \
                    isinstance(inner.func, ast.Name) and \
                    inner.func.id == "iter" and inner.args and \
                    self._unordered(inner.args[0]):
                self._emit(node, "det-set-order",
                           "next(iter(<set>)) picks a hash-order-"
                           "dependent element — sort or track an "
                           "explicit index")
        if isinstance(f, ast.Attribute) and f.attr == "pop" and \
                not node.args and not node.keywords and \
                self._unordered(f.value):
            self._emit(node, "det-set-order",
                       "set.pop() removes a hash-order-dependent "
                       "element — pop from a sorted or indexed "
                       "structure instead")
        self.generic_visit(node)

    def _check_rng(self, node: ast.Call, name: str) -> None:
        parts = name.split(".")
        if parts[0] == "random" and len(parts) == 2:
            if parts[1] in _RANDOM_FUNCS:
                self._emit(node, "det-rng",
                           f"module-global {name}() shares one "
                           "process-wide stream — use a seeded "
                           "random.Random instance")
            elif parts[1] in _RNG_CTORS and not node.args \
                    and not node.keywords:
                self._emit(node, "det-rng",
                           f"unseeded {name}() draws entropy from the "
                           "OS — pass an explicit seed")
        elif parts[0] in ("np", "numpy") and len(parts) >= 3 \
                and parts[1] == "random":
            leaf = parts[-1]
            if leaf in _RNG_CTORS:
                if not node.args and not node.keywords:
                    self._emit(node, "det-rng",
                               f"unseeded {name}() — pass an explicit "
                               "seed")
            else:
                self._emit(node, "det-rng",
                           f"global-state {name}() — use a seeded "
                           "np.random.RandomState/default_rng "
                           "instance")
        elif isinstance(node.func, ast.Name) and \
                node.func.id in ("Random", "RandomState") and \
                not node.args and not node.keywords:
            self._emit(node, "det-rng",
                       f"unseeded {node.func.id}() — pass an explicit "
                       "seed")

    @staticmethod
    def _total_order_key(kw: ast.expr) -> bool:
        """A key proves a total order when it ends with the bare element
        itself: ``key=lambda s: s`` or ``key=lambda s: (f(s), s)`` —
        distinct elements then never tie."""
        if not isinstance(kw, ast.Lambda) or len(kw.args.args) != 1:
            return False
        p = kw.args.args[0].arg
        body = kw.body
        if isinstance(body, ast.Name) and body.id == p:
            return True
        return (isinstance(body, ast.Tuple) and body.elts
                and isinstance(body.elts[-1], ast.Name)
                and body.elts[-1].id == p)

    def _check_order_call(self, node: ast.Call, fname: str) -> None:
        if not node.args or not self._unordered(node.args[0]):
            return
        key = next((kw.value for kw in node.keywords
                    if kw.arg == "key"), None)
        if key is None:
            return      # direct element comparison over distinct keys
        if self._total_order_key(key):
            return
        self._emit(node, "det-set-order",
                   f"{fname}() over a set with a key that is not a "
                   "provable total order — ties resolve by hash "
                   "iteration order; append the element itself as a "
                   "tie-break: key=lambda s: (..., s)")

    # -- for-loops over unordered iterables ------------------------------
    def visit_For(self, node: ast.For) -> None:
        if self._unordered(node.iter):
            reason = self._order_sensitive_body(node.body)
            if reason is not None:
                self._emit(node, "det-set-order",
                           "iteration over a set "
                           f"{reason} — iterate sorted(...) or prove "
                           "order-independence with a pragma")
        self.generic_visit(node)

    @staticmethod
    def _order_sensitive_body(body: List[ast.stmt]) -> Optional[str]:
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    f = sub.func
                    callee = f.attr if isinstance(f, ast.Attribute) \
                        else f.id if isinstance(f, ast.Name) else None
                    if callee in SINK_CALLS:
                        return (f"dispatches work via {callee}() in "
                                "hash iteration order")
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    tgts = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    for t in tgts:
                        if isinstance(t, (ast.Attribute, ast.Subscript)):
                            return ("mutates shared state in hash "
                                    "iteration order")
        return None
