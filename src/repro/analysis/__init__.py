"""Static analysis + runtime sanitization for the SAGA scheduler tree.

The repo's two hard invariants are byte-identical replay (identical
seeds produce identical ``summarize()`` reprs across processes and
``PYTHONHASHSEED``) and conservation (admitted == finished, zero
slot/KV-block/AFS leak).  Both were enforced only after the fact — by
golden fingerprints and end-of-run ``check_conservation`` — which
localizes a violation to a whole run, not a line.  This package closes
that gap:

  * ``sagalint`` — an AST-based linter (``python -m
    repro.analysis.sagalint src/repro``) with two rule families:
    determinism (builtin ``hash``, unordered-iteration order leaks,
    wall-clock reads, unseeded RNG, ``os.environ`` in hot paths) and
    resource lifecycle (CFG walk for acquire-without-release paths,
    event handlers missing attempt-stamp guards).  Suppressible only
    via an explicit ``# sagalint: ok(<rule>) <reason>`` pragma.
  * the runtime sanitizer lives next to the runtime it audits
    (``repro.serving.sanitizer``): shadow block-refcount / slot
    ownership checks at every event-loop boundary, failing at the
    first bad event with the owning session/attempt named.

Everything here is stdlib-only (``ast`` + ``argparse``) so the CI lint
job runs with no third-party installs.

See ``docs/INVARIANTS.md`` for the rule catalogue with bad/good
examples and the pragma format.

(Import ``repro.analysis.sagalint`` directly for the API — this
``__init__`` stays empty so ``python -m repro.analysis.sagalint`` does
not double-import the driver module.)
"""
