"""Statement-level control-flow graphs for lifecycle analysis.

One node per *simple* statement; compound statements (``if`` / ``for``
/ ``while`` / ``with`` / ``try``) contribute a header node carrying
only their test/iterator expression — their bodies become separate
nodes, so a resource acquired in a branch is tracked along that branch
alone.  Two virtual exits: ``EXIT`` (fall-through / ``return``) and
``RAISE`` (``raise``).  Leak analysis treats ``raise`` as a non-leak
exit: crashing on a violated invariant is the intended behaviour of
guard code, not an escaped resource.

The graph is deliberately conservative where Python is dynamic:

  * every statement inside a ``try`` body may jump to each handler
    (any expression can raise), and ``finally`` runs on all paths;
  * loop headers branch both into the body and past it (zero
    iterations), and the body loops back to the header;
  * ``break`` / ``continue`` target the innermost enclosing loop.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

EXIT = -1
RAISE = -2


@dataclasses.dataclass
class Node:
    """One CFG node: the statement it came from plus the AST fragments
    that execute *at* this node (header nodes scan only their
    test/iter, not their bodies)."""
    node_id: int
    stmt: ast.stmt
    frags: List[ast.AST]

    @property
    def line(self) -> int:
        return self.stmt.lineno


@dataclasses.dataclass
class _LoopCtx:
    break_to: Set[int]
    continue_to: Set[int]


class CFG:
    def __init__(self, fn: ast.FunctionDef):
        self.fn = fn
        self.nodes: Dict[int, Node] = {}
        self.succ: Dict[int, Set[int]] = {}
        self._next_id = 0
        self.entry: Set[int] = self._seq(list(fn.body), {EXIT}, None)

    # -- construction ---------------------------------------------------
    def _new(self, stmt: ast.stmt, frags: List[ast.AST],
             succ: Set[int]) -> int:
        nid = self._next_id
        self._next_id += 1
        self.nodes[nid] = Node(nid, stmt, frags)
        self.succ[nid] = set(succ)
        return nid

    def _seq(self, stmts: List[ast.stmt], follow: Set[int],
             loop: Optional[_LoopCtx]) -> Set[int]:
        """Wire ``stmts`` so the last one continues to ``follow``;
        returns the entry set.  Built back-to-front so each statement
        already knows its successor."""
        entry = set(follow)
        for stmt in reversed(stmts):
            entry = self._stmt(stmt, entry, loop)
        return entry

    def _stmt(self, stmt: ast.stmt, follow: Set[int],
              loop: Optional[_LoopCtx]) -> Set[int]:
        if isinstance(stmt, (ast.Return, ast.Raise)):
            tgt = {RAISE} if isinstance(stmt, ast.Raise) else {EXIT}
            return {self._new(stmt, [stmt], tgt)}
        if isinstance(stmt, ast.Break):
            return {self._new(stmt, [], set(loop.break_to) if loop
                              else {EXIT})}
        if isinstance(stmt, ast.Continue):
            return {self._new(stmt, [], set(loop.continue_to) if loop
                              else {EXIT})}
        if isinstance(stmt, ast.If):
            body = self._seq(stmt.body, follow, loop)
            orelse = self._seq(stmt.orelse, follow, loop) \
                if stmt.orelse else set(follow)
            return {self._new(stmt, [stmt.test], body | orelse)}
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            frag = stmt.test if isinstance(stmt, ast.While) \
                else stmt.iter
            head = self._new(stmt, [frag], set(follow))
            inner = _LoopCtx(break_to=set(follow), continue_to={head})
            body = self._seq(stmt.body, {head}, inner)
            self.succ[head] |= body
            if stmt.orelse:
                self.succ[head] |= self._seq(stmt.orelse, follow, loop)
            return {head}
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            body = self._seq(stmt.body, follow, loop)
            return {self._new(stmt, list(stmt.items), body)}
        if isinstance(stmt, ast.Try):
            fin_entry = self._seq(stmt.finalbody, follow, loop) \
                if stmt.finalbody else set(follow)
            handler_entries: Set[int] = set()
            for h in stmt.handlers:
                handler_entries |= self._seq(h.body, fin_entry, loop)
            mark = self._next_id
            body = self._seq(stmt.body + stmt.orelse, fin_entry, loop)
            # any statement in the protected region may divert to a
            # handler mid-flight
            for nid in range(mark, self._next_id):
                self.succ[nid] |= handler_entries
            return body
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # nested definition: executing the def itself acquires
            # nothing; its body is analyzed as its own CFG by callers
            return {self._new(stmt, [], follow)}
        return {self._new(stmt, [stmt], follow)}

    # -- queries --------------------------------------------------------
    def reaches_exit(self, start: int,
                     barriers: Set[int]) -> Optional[Tuple[int, ...]]:
        """Is ``EXIT`` reachable from ``start``'s successors without
        passing through a barrier node?  Returns one witness path of
        node ids (excluding EXIT) or None.  ``RAISE`` does not count as
        an exit."""
        seen: Set[int] = set()
        stack: List[Tuple[int, Tuple[int, ...]]] = [
            (n, ()) for n in sorted(self.succ.get(start, ()))]
        while stack:
            nid, path = stack.pop()
            if nid == EXIT:
                return path
            if nid in (RAISE,) or nid in seen or nid in barriers:
                continue
            seen.add(nid)
            for nxt in sorted(self.succ.get(nid, ())):
                stack.append((nxt, path + (nid,)))
        return None
