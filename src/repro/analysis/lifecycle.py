"""Resource-lifecycle rule family.

The runtime and simulator share a vocabulary of acquire/release pairs;
a path that acquires one and exits a handler without releasing it or
handing it off is exactly the class of bug ``check_conservation`` only
catches at end-of-run:

  ================  ==========================  =========================
  family            acquire                     release
  ================  ==========================  =========================
  slot              ``start_session``           ``release_session`` /
                                                ``park_session`` /
                                                ``fail``
  blocks            ``park`` / ``import_kv`` /  ``free_session`` /
                    ``import_handoff`` /        ``evict_session`` /
                    ``stage_prefill`` /         ``_handoff_abort``
                    ``*pool*.alloc`` /
                    ``*pool*.extend`` /
                    ``*pool*.ensure_tail_room``
  afs-work          ``note_progress``           ``refund_work``
  inflight          ``X.inflight[sid] = ...``   ``X.inflight.pop`` /
                                                ``del X.inflight[...]``
  idle-set          ``on_worker_busy``          ``on_worker_idle``
  span              ``*tracer*.begin``          ``*tracer*.end``
  ================  ==========================  =========================

Paged serving moved block acquisition from park-time to admit-time
(allocate-at-admit: ownership spans admit→finish, and park/resume are
metadata-only flips that neither acquire nor release).  The alloc-side
names are too generic to match bare (``list.extend`` is everywhere), so
they only count when called through a receiver chain that passes a
``pool`` attribute or name — ``self.pool.alloc(sid)``,
``eng.pool.extend(...)``.

Rules:

  * ``life-leak``  — within one function whose body both acquires a
    family and releases it (or performs a registered handoff —
    scheduling a continuation event owns the release downstream), any
    CFG path from an acquire to function exit that passes neither is
    flagged.  ``raise`` exits are exempt: crashing on a violated
    invariant is not a leak.
  * ``life-guard`` — event handlers (``_on_*`` methods, the
    ``getattr(self, "_on_" + kind)`` dispatch convention) that receive
    a staleness stamp (a parameter named ``attempt`` / ``gen`` /
    ``generation``) but never test it: stale events from a cancelled
    attempt or a failed engine incarnation would then mutate fresh
    state.
  * ``life-span`` — the ``span`` family under the ``life-leak``
    analysis, reported under its own rule id: a ``tracer.begin(...)``
    on a path that exits without ``tracer.end(...)`` or a registered
    handoff is a span leak — ``Tracer.check_closed()`` would only
    catch it at end-of-run, like a leaked slot.  ``begin``/``end`` are
    far too generic to match bare, so the family is receiver-scoped:
    calls classify only through a chain passing a ``tracer`` name
    (``self.tracer.begin(...)``), mirroring the pool-scoped alloc
    names.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.cfg import CFG, Node
from repro.analysis.sagalint import Finding

FAMILIES: Dict[str, Dict[str, Set[str]]] = {
    "slot": {
        "acquire": {"start_session"},
        "release": {"release_session", "park_session", "fail"},
    },
    "blocks": {
        "acquire": {"park", "import_kv", "import_handoff",
                    "stage_prefill"},
        # _handoff_abort unwinds a disaggregated handoff attempt: it
        # evicts the staged prefill-side copy and returns the staging
        # reservation, so it is a blocks release in the runtime's
        # vocabulary
        "release": {"free_session", "evict_session", "_handoff_abort"},
    },
    "afs-work": {
        "acquire": {"note_progress"},
        "release": {"refund_work"},
    },
    "idle-set": {
        "acquire": {"on_worker_busy"},
        "release": {"on_worker_idle"},
    },
    # virtual-time span tracer (repro.obs.tracer): ``begin``/``end``
    # are too generic to match bare, so the optional "receivers" key
    # scopes classification to calls whose receiver chain passes a
    # ``tracer`` name — self.tracer.begin(...), sim.tracer.end(...)
    "span": {
        "acquire": {"begin"},
        "release": {"end"},
        "receivers": {"tracer"},
    },
}

# calls that transfer ownership of whatever this function acquired to a
# later event / another queue / the terminal completion path: the
# matching release happens there
HANDOFF_CALLS = {
    "schedule", "_push", "_queue_push", "_redispatch", "_dispatch_to",
    "_enqueue", "_admit", "resolve", "_finish_task",
}

# joining a live continuous-batching round (self._active[w].add(sid))
# also hands the slot off — the round loop owns its release from there
_JOIN_ATTRS = {"_active"}

# allocate-at-admit block acquires (paged serving): bare names are too
# generic (`list.extend`, arena `alloc` helpers), so they only classify
# when the call's receiver chain passes a KV pool
_POOL_SCOPED_ACQUIRES = {"alloc", "extend", "extend_parked",
                         "ensure_tail_room"}
_POOL_RECEIVERS = {"pool"}

STAMP_PARAMS = ("attempt", "gen", "generation")


def _callee(node: ast.Call) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _is_inflight_chain(node: ast.AST) -> bool:
    """Does the expression end in an attribute/name called 'inflight'?"""
    if isinstance(node, ast.Attribute):
        return node.attr == "inflight"
    if isinstance(node, ast.Name):
        return node.id == "inflight"
    return False


def _chain_mentions(node: ast.AST, names: Set[str]) -> bool:
    """Does an attribute/subscript chain pass through one of ``names``?
    (``self._active[w]`` mentions ``_active``.)"""
    while True:
        if isinstance(node, ast.Attribute):
            if node.attr in names:
                return True
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Name):
            return node.id in names
        else:
            return False


class _NodeActions:
    """Acquire/release/handoff classification of one CFG node."""

    def __init__(self, node: Node) -> None:
        self.acquires: Set[str] = set()
        self.releases: Set[str] = set()
        self.handoff = False
        for frag in node.frags:
            for sub in ast.walk(frag):
                self._classify(sub)

    def _classify(self, sub: ast.AST) -> None:
        if isinstance(sub, ast.Call):
            callee = _callee(sub)
            if callee in HANDOFF_CALLS:
                self.handoff = True
            if callee == "add" and isinstance(sub.func, ast.Attribute) \
                    and _chain_mentions(sub.func.value, _JOIN_ATTRS):
                self.handoff = True
            for fam, names in FAMILIES.items():
                recv = names.get("receivers")
                if recv is not None and not (
                        isinstance(sub.func, ast.Attribute)
                        and _chain_mentions(sub.func.value, recv)):
                    continue
                if callee in names["acquire"]:
                    self.acquires.add(fam)
                if callee in names["release"]:
                    self.releases.add(fam)
            if callee in _POOL_SCOPED_ACQUIRES \
                    and isinstance(sub.func, ast.Attribute) \
                    and _chain_mentions(sub.func.value, _POOL_RECEIVERS):
                self.acquires.add("blocks")
            # X.inflight.pop(...)
            if callee == "pop" and isinstance(sub.func, ast.Attribute) \
                    and _is_inflight_chain(sub.func.value):
                self.releases.add("inflight")
        elif isinstance(sub, ast.Assign):
            for t in sub.targets:
                if isinstance(t, ast.Subscript) and \
                        _is_inflight_chain(t.value):
                    self.acquires.add("inflight")
        elif isinstance(sub, ast.Delete):
            for t in sub.targets:
                if isinstance(t, ast.Subscript) and \
                        _is_inflight_chain(t.value):
                    self.releases.add("inflight")


class LifecycleChecker:
    def __init__(self, path: str) -> None:
        self.path = path
        self.findings: List[Finding] = []

    def run(self, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_leaks(node)
                self._check_guard(node)

    # -- life-leak -------------------------------------------------------
    def _check_leaks(self, fn: ast.FunctionDef) -> None:
        cfg = CFG(fn)
        actions = {nid: _NodeActions(n) for nid, n in cfg.nodes.items()}
        any_handoff = any(a.handoff for a in actions.values())
        families = sorted(
            {f for a in actions.values() for f in a.acquires})
        for fam in families:
            has_release = any(fam in a.releases
                              for a in actions.values())
            if not (has_release or any_handoff):
                # purely-acquiring helper: its caller owns the release;
                # nothing to pair against locally
                continue
            barriers = {nid for nid, a in actions.items()
                        if fam in a.releases or a.handoff}
            for nid, a in sorted(actions.items()):
                if fam not in a.acquires or nid in barriers:
                    continue
                if isinstance(cfg.nodes[nid].stmt, ast.Return):
                    # tail acquire: the resource (or its success flag)
                    # is returned — ownership escapes to the caller
                    continue
                witness = cfg.reaches_exit(nid, barriers)
                if witness is None:
                    continue
                node = cfg.nodes[nid]
                exit_line = cfg.nodes[witness[-1]].line \
                    if witness else node.line
                rel = " / ".join(sorted(FAMILIES[fam]["release"])) \
                    if fam in FAMILIES \
                    else "inflight.pop / del inflight[...]"
                rule = "life-span" if fam == "span" else "life-leak"
                self.findings.append(Finding(
                    self.path, node.line, node.stmt.col_offset,
                    rule,
                    f"'{fn.name}' acquires {fam} here but the path "
                    f"exiting at line {exit_line} neither releases it "
                    f"({rel}) nor hands it off to a scheduled "
                    "continuation"))

    # -- life-guard ------------------------------------------------------
    def _check_guard(self, fn: ast.FunctionDef) -> None:
        if not fn.name.startswith("_on_"):
            return
        params = [a.arg for a in fn.args.args + fn.args.kwonlyargs
                  if a.arg in STAMP_PARAMS]
        for p in params:
            if not self._validated(fn, p):
                self.findings.append(Finding(
                    self.path, fn.lineno, fn.col_offset, "life-guard",
                    f"event handler '{fn.name}' receives staleness "
                    f"stamp '{p}' but never validates it — a stale "
                    "event from a cancelled attempt / dead engine "
                    "incarnation would mutate fresh state"))

    @staticmethod
    def _validated(fn: ast.FunctionDef, param: str) -> bool:
        """The stamp counts as validated when it appears inside any
        branch test or comparison (the canonical guard is
        ``if rec is None or rec[1] != attempt: return``)."""
        tests: List[ast.AST] = []
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.If, ast.While, ast.IfExp)):
                tests.append(sub.test)
            elif isinstance(sub, ast.Assert):
                tests.append(sub.test)
            elif isinstance(sub, ast.Compare):
                tests.append(sub)
        for t in tests:
            for sub in ast.walk(t):
                if isinstance(sub, ast.Name) and sub.id == param:
                    return True
        return False
