"""AdamW in pure jax (f32 moments over bf16 params).

Memory layout matches the FSDP+TP sharding of the params: moment trees
reuse the param PartitionSpecs, so optimizer state is fully sharded
(ZeRO-style) — required to fit the 236B-class archs in 16 GB HBM chips.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return OptState(mu=jax.tree_util.tree_map(zeros, params),
                    nu=jax.tree_util.tree_map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def abstract_opt_state(abstract_params) -> OptState:
    return jax.eval_shape(init_opt_state, abstract_params)


def opt_pspecs(param_shardings) -> OptState:
    return OptState(mu=param_shardings, nu=param_shardings, step=None)


def adamw_update(params, grads, opt: OptState, *, lr: float = 3e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """One AdamW step.  Returns (new_params, new_opt, grad_norm)."""
    gsq = sum(jnp.sum(jnp.square(g.astype(F32)))
              for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    step = opt.step + 1
    c1 = 1.0 - b1 ** step.astype(F32)
    c2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(F32)
        newp = (p.astype(F32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt.mu)
    flat_v = jax.tree_util.tree_leaves(opt.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), gnorm
