"""Checkpoint / restart for the training substrate.

Saves the param + optimizer pytrees (np.savez, one file per host in a
real deployment; single file here), the data-pipeline cursor, and the
coordinator snapshot for serving-side state.  Restore rebuilds the exact
pytree structure from the abstract tree, so a job restarted on a
different mesh reshards transparently (arrays are saved unsharded;
jax.device_put with the new NamedShardings redistributes).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":     # npz cannot store bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None,
                    data_state: Optional[dict] = None,
                    extra: Optional[dict] = None) -> str:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"step_{step:08d}.npz"
    arrays = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays.update({f"opt/{k}": v
                       for k, v in _flatten(opt_state).items()})
    np.savez(path, **arrays)
    meta = {"step": step, "data_state": data_state or {},
            "extra": extra or {}}
    (d / f"step_{step:08d}.json").write_text(json.dumps(meta))
    (d / "LATEST").write_text(str(step))
    return str(path)


def latest_step(ckpt_dir: str) -> Optional[int]:
    f = Path(ckpt_dir) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore_checkpoint(ckpt_dir: str, abstract_params, abstract_opt=None,
                       step: Optional[int] = None
                       ) -> Tuple[int, Any, Any, dict]:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir)
    data = np.load(d / f"step_{step:08d}.npz")
    meta = json.loads((d / f"step_{step:08d}.json").read_text())

    def rebuild(abstract, prefix):
        paths = jax.tree_util.tree_flatten_with_path(abstract)
        leaves = []
        for path, leaf in paths[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[f"{prefix}/{key}"]
            leaves.append(np.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(paths[1], leaves)

    params = rebuild(abstract_params, "params")
    opt = rebuild(abstract_opt, "opt") if abstract_opt is not None else None
    return step, params, opt, meta
