"""Synthetic token data pipeline (deterministic, resumable).

Production shape: sharded host loading with a persisted cursor so
checkpoint/restart resumes mid-epoch without replaying or skipping
batches.  The generator is a counter-based PRNG (stateless per index),
so any batch can be regenerated from its global step alone — the
property that makes elastic re-sharding trivial at 1000-node scale.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataState:
    step: int = 0
    seed: int = 0


class SyntheticLM:
    """Markov-ish synthetic token stream: next-token structure exists so
    training loss visibly decreases (not pure noise)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.state = DataState(seed=seed)

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.state.seed * 1_000_003 + step)
                                    % (2 ** 31))
        V = self.cfg.vocab
        # structured stream: x_{t+1} = (a * x_t + b + noise) mod V
        a = 31
        x = np.zeros((self.batch, self.seq + 1), np.int64)
        x[:, 0] = rng.randint(0, V, size=self.batch)
        noise = rng.randint(0, 7, size=(self.batch, self.seq))
        for t in range(self.seq):
            x[:, t + 1] = (a * x[:, t] + 17 + noise[:, t]) % V
        return {"tokens": x[:, :-1].astype(np.int32),
                "labels": x[:, 1:].astype(np.int32)}

    def next(self) -> Dict[str, np.ndarray]:
        b = self._batch_at(self.state.step)
        self.state.step += 1
        return b

    def snapshot(self) -> dict:
        return dataclasses.asdict(self.state)

    def restore(self, snap: dict) -> None:
        self.state = DataState(**snap)
