"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} "
            "present — run under XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 (see launch/dryrun.py)")
    return jax.make_mesh(
        shape, axes, devices=devices[:need],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_worker_mesh(n_devices: int = 1, tp: int = 1):
    """Small mesh for the serving engine / CPU tests."""
    devices = jax.devices()[:n_devices]
    dp = max(1, n_devices // tp)
    return jax.make_mesh((dp, tp), ("data", "model"), devices=devices,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
