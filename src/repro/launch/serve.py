"""Serving launcher: bring up the multi-worker SAGA cluster and run a
synthetic agent workload against it (real forward passes).

    PYTHONPATH=src python -m repro.launch.serve --arch micro --tasks 6

On a real TPU deployment the same MultiWorkerServer runs one engine per
slice partition with `jax.distributed` initialization; here workers are
in-process (single host).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.server import AgentRequest, MultiWorkerServer

TOOLS = ["code_execution", "file_operations", "web_api", "database_query"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro")
    ap.add_argument("--tasks", type=int, default=6)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--observability", default="hints",
                    choices=["hints", "pattern", "none"])
    ap.add_argument("--baseline", action="store_true",
                    help="request-level scheduling instead of SAGA")
    args = ap.parse_args()

    load_all()
    cfg = get_config(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if args.baseline:
        saga = SAGAConfig(cache_policy="none", enable_affinity=False,
                          enable_ttl=False, enable_prefetch=False,
                          enable_afs=False, observability="none")
    else:
        saga = SAGAConfig(observability=args.observability)
    srv = MultiWorkerServer(cfg, params, n_workers=args.workers, saga=saga,
                            n_slots=3, max_len=512, pool_blocks=96)
    rng = np.random.RandomState(0)
    t0 = time.time()
    for i in range(args.tasks):
        steps = [(list(rng.randint(1, cfg.vocab, size=12)),
                  args.decode_tokens, TOOLS[s % len(TOOLS)],
                  float(rng.uniform(0.1, 1.5)))
                 for s in range(args.steps)]
        out = srv.run_task(AgentRequest(f"task-{i}", f"t{i % 2}", steps))
        print(f"task-{i}: ctx={out['ctx_tokens']} "
              f"regenerated={out['regen_tokens']} tokens")
    s = srv.stats()
    print(f"\n{'baseline' if args.baseline else 'SAGA'}: "
          f"prefilled={s['prefill_tokens']} regen={s['regen_tokens']} "
          f"decode_steps={s['decode_steps']} hits={s['coordinator_hits']} "
          f"({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
