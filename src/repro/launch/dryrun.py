import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede any other import (jax locks the device
# count on first init).  Do not set this flag anywhere else in the repo.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config, load_all      # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch.specs import SHAPES, cell_supported, input_specs  # noqa: E402
from repro.models import lm                                    # noqa: E402
from repro.models.sharding import ShardingEnv                  # noqa: E402

# --- TPU v5e hardware constants (targets; container runs CPU) -------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
HBM_GB = 16.0                # v5e HBM per chip

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8}
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(m) -> int:
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n * _BYTES[m.group(1)]


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by collectives, parsed from optimized HLO.

    For each collective op we take the largest shape literal on the line
    (the full tensor involved).  all-reduce counts 2x (reduce-scatter +
    all-gather ring phases).  ``-done`` lines of async pairs are skipped.
    NOTE: ops inside while-loop bodies are counted once — use the
    reduced-depth unrolled compiles for per-layer extrapolation.
    """
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLL_KINDS:
            if (f" {kind}(" in s or f" {kind}-start(" in s) \
                    and f"{kind}-done" not in s:
                sizes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(s)]
                if sizes:
                    out[kind] += max(sizes)
                    counts[kind] += 1
                break
    total = sum(v * (2 if k == "all-reduce" else 1) for k, v in out.items())
    return {"by_kind": out, "counts": counts, "weighted_total": total}


def make_step_fn(cfg, env, kind: str, seq: int):
    if kind == "train":
        from repro.train.optimizer import adamw_update

        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: lm.forward_train(p, batch, cfg, env))(params)
            params, opt, gnorm = adamw_update(params, grads, opt)
            return loss, gnorm, params, opt
        return train_step
    if kind == "prefill":
        def prefill_step(params, batch):
            return lm.prefill(params, batch, cfg, env, max_len=seq)
        return prefill_step

    def serve_step(params, tokens, cache, pos):
        return lm.decode_step(params, tokens, cache, pos, cfg, env)
    return serve_step


def _compile_once(cfg, shape_name, mesh, opts):
    env = ShardingEnv(mesh, opts=opts)
    info = SHAPES[shape_name]
    spec = input_specs(cfg, shape_name, env)
    fn = make_step_fn(cfg, env, spec["kind"], info["seq"])
    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=spec["in_shardings"],
                         out_shardings=spec.get("out_shardings"),
                         donate_argnums=spec.get("donate_argnums", ()))
        lowered = jitted.lower(*spec["args"])
        compiled = lowered.compile()
    dt = time.time() - t0
    cost = compiled.cost_analysis() or {}
    return {
        "compile_s": round(dt, 1),
        "memory": compiled.memory_analysis(),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(compiled.as_text()),
        "kind": spec["kind"],
    }


# --- depth reduction for per-layer slope measurement -----------------------
def _depth_points(cfg):
    if cfg.attn_period:                       # jamba: whole superblocks
        return [(cfg.attn_period, cfg.attn_period),
                (2 * cfg.attn_period, 2 * cfg.attn_period)]
    if cfg.enc_dec:                           # enc=dec=k; L = 2k
        return [(1, 2), (2, 4)]
    return [(1, 1), (2, 2)]


def _reduce_cfg(cfg, k):
    if cfg.enc_dec:
        return dataclasses.replace(cfg, n_enc_layers=k, n_dec_layers=k,
                                   n_layers=2 * k)
    return dataclasses.replace(cfg, n_layers=k)


def _full_depth(cfg) -> int:
    return (cfg.n_enc_layers + cfg.n_dec_layers) if cfg.enc_dec \
        else cfg.n_layers


def run_cell(arch: str, shape_name: str, mesh, opts: dict, *,
             slopes: bool = True):
    cfg = get_config(arch)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": why}
    info = SHAPES[shape_name]
    opts = dict(opts, remat=(info["kind"] == "train"))

    # 1) full-depth scan compile: THE lower+compile proof + memory picture
    full = _compile_once(cfg, shape_name, mesh,
                         dict(opts, unroll_layers=False))
    mem = full["memory"]
    peak_gb = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
               mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30

    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "kind": full["kind"], "mesh": list(mesh.devices.shape),
        "axis_names": list(mesh.axis_names),
        "n_chips": int(mesh.devices.size), "opts": dict(opts),
        "compile_s": full["compile_s"],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(peak_gb, 3),
            "fits_hbm_16gb": bool(peak_gb <= HBM_GB),
        },
        "scan_cost_raw": {"flops": full["flops"], "bytes": full["bytes"],
                          "collectives": full["collectives"]},
    }

    # 2) reduced-depth UNROLLED compiles -> exact per-layer slopes
    #    (XLA cost analysis counts while bodies once; unrolling the layer
    #     loop at two depths and extrapolating restores exact accounting)
    if slopes:
        (k1, l1), (k2, l2) = _depth_points(cfg)
        slope_opts = dict(opts, unroll_layers=True, unroll_pairs=True,
                          attn_block=2048)
        r1 = _compile_once(_reduce_cfg(cfg, k1), shape_name, mesh,
                           slope_opts)
        r2 = _compile_once(_reduce_cfg(cfg, k2), shape_name, mesh,
                           slope_opts)
        L = _full_depth(cfg)

        def extrap(a, b):
            return a + (b - a) / (l2 - l1) * (L - l1)

        flops = extrap(r1["flops"], r2["flops"])
        bytes_acc = extrap(r1["bytes"], r2["bytes"])
        coll_total = extrap(r1["collectives"]["weighted_total"],
                            r2["collectives"]["weighted_total"])
        coll_kind = {k: extrap(r1["collectives"]["by_kind"][k],
                               r2["collectives"]["by_kind"][k])
                     for k in _COLL_KINDS}
        result["slope_compile_s"] = [r1["compile_s"], r2["compile_s"]]
        result["slope_depths"] = [l1, l2]

        t_compute = flops / PEAK_FLOPS
        t_memory = bytes_acc / HBM_BW
        t_coll = coll_total / ICI_BW
        dominant = max([("compute", t_compute), ("memory", t_memory),
                        ("collective", t_coll)], key=lambda kv: kv[1])[0]

        total, active = cfg.param_counts()
        total += cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        B, S = info["batch"], info["seq"]
        if full["kind"] == "train":
            model_flops = 6 * active * B * S
        elif full["kind"] == "prefill":
            model_flops = 2 * active * B * S
        else:
            model_flops = 2 * active * B
        mf_chip = model_flops / mesh.devices.size

        result.update({
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_acc,
            "collective_bytes_per_device": coll_total,
            "collectives_by_kind": coll_kind,
            "roofline": {
                "compute_s": t_compute, "memory_s": t_memory,
                "collective_s": t_coll, "dominant": dominant,
                "step_lower_bound_s": max(t_compute, t_memory, t_coll),
            },
            "model_flops_per_chip": mf_chip,
            "useful_flops_ratio": (mf_chip / flops) if flops else 0.0,
            "params_total": total, "params_active": active,
        })
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    help="one of %s or 'all'" % list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attn-mode", default="full", choices=["full", "tri"])
    ap.add_argument("--moe-impl", default="ep", choices=["ep", "dense"])
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"])
    ap.add_argument("--no-fsdp", action="store_true",
                    help="serving mode: weights replicated over 'data' "
                         "(TP-only) — no FSDP gathers")
    ap.add_argument("--cache-2d", action="store_true",
                    help="shard KV-cache sequence over (model x data)")
    ap.add_argument("--rs-matmul", action="store_true",
                    help="explicit psum_scatter out-projections "
                         "(sequence-parallel reduce-scatter)")
    ap.add_argument("--serve-fullshard", action="store_true",
                    help="decode mode: batch replicated, KV sharded over "
                         "(model x data), weights fully sharded — no "
                         "weight gathers for >100B archs")
    ap.add_argument("--no-slopes", action="store_true",
                    help="skip reduced-depth slope compiles (multi-pod "
                         "pass only proves sharding)")
    args = ap.parse_args()

    load_all()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_tag = "multi" if multi else "single"
        slopes = not args.no_slopes and not multi   # roofline: single-pod
        opts = {"attn_mode": args.attn_mode, "moe_impl": args.moe_impl,
                "sp": not args.no_sp,
                "remat_policy": args.remat_policy,
                "fsdp": not args.no_fsdp,
                "rs_matmul": args.rs_matmul,
                "cache_2d": args.cache_2d,
                "serve_fullshard": args.serve_fullshard}
        for arch in archs:
            for shape in shapes:
                fname = outdir / f"{args.tag}.{arch}.{shape}.{mesh_tag}.json"
                if fname.exists() and not args.force:
                    print(f"[skip-existing] {fname}", flush=True)
                    continue
                print(f"=== {arch} x {shape} x {mesh_tag} ===", flush=True)
                t0 = time.time()
                try:
                    res = run_cell(arch, shape, mesh, opts, slopes=slopes)
                except Exception as e:  # record failures, keep sweeping
                    res = {"arch": arch, "shape": shape, "status": "error",
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                res["mesh_tag"] = mesh_tag
                res["tag"] = args.tag
                res["wall_s"] = round(time.time() - t0, 1)
                fname.write_text(json.dumps(res, indent=1))
                if res["status"] == "ok" and "roofline" in res:
                    r = res["roofline"]
                    print(f"  mem={res['memory']['peak_per_device_gb']}GB "
                          f"compute={r['compute_s']:.4f}s "
                          f"hbm={r['memory_s']:.4f}s "
                          f"ici={r['collective_s']:.4f}s "
                          f"dom={r['dominant']} "
                          f"useful={res['useful_flops_ratio']:.2f} "
                          f"wall={res['wall_s']}s", flush=True)
                elif res["status"] == "ok":
                    print(f"  compiled ok; mem="
                          f"{res['memory']['peak_per_device_gb']}GB "
                          f"wall={res['wall_s']}s", flush=True)
                else:
                    print(f"  {res['status']}: "
                          f"{res.get('reason', res.get('error'))}",
                          flush=True)


if __name__ == "__main__":
    main()
