"""Training launcher: FSDP+TP train loop with checkpoint/restart.

Runs for real on CPU with small configs (examples/train_small.py) and
lowers unchanged on the production mesh (launch/dryrun.py exercises the
identical train_step for every assigned arch).  Fault tolerance:
periodic checkpoints + data-cursor persistence; on restart the loop
resumes at the exact batch after the last checkpoint.
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, load_all
from repro.models import lm
from repro.models.sharding import ShardingEnv
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.optimizer import OptState, adamw_update, init_opt_state


def make_train_step(cfg, env, lr: float):
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm.forward_train(p, batch, cfg, env))(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        return loss, gnorm, params, opt
    return train_step


def train_loop(arch: str = "small-100m", *, steps: int = 50, batch: int = 8,
               seq: int = 128, lr: float = 3e-4, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 25, mesh=None, log_every: int = 5,
               resume: bool = False, seed: int = 0):
    load_all()
    cfg = get_config(arch)
    env = ShardingEnv(mesh, opts={"remat": False, "sp": mesh is not None,
                                  "moe_impl": "dense" if mesh is None
                                  else "ep"})
    data = SyntheticLM(cfg, batch, seq, seed=seed)

    start = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        ap = lm.abstract_params(cfg)
        from repro.train.optimizer import abstract_opt_state
        start, params, opt, meta = ckpt.restore_checkpoint(
            ckpt_dir, ap, abstract_opt_state(ap))
        data.restore(meta["data_state"])
        print(f"[train] resumed from step {start}")
    else:
        params = lm.init_params(cfg, jax.random.PRNGKey(seed))
        opt = init_opt_state(params)

    step_fn = jax.jit(make_train_step(cfg, env, lr), donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        b = data.next()
        batch_dev = {k: jnp.asarray(v) for k, v in b.items()}
        loss, gnorm, params, opt = step_fn(params, opt, batch_dev)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save_checkpoint(ckpt_dir, step + 1, params, opt,
                                 data_state=data.snapshot())
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="small-100m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    train_loop(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
               lr=args.lr, ckpt_dir=args.ckpt_dir, resume=args.resume)


if __name__ == "__main__":
    main()
