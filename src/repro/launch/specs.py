"""Input specs (ShapeDtypeStruct stand-ins) per (arch x shape) cell.

Every model input is a weak-type-correct, shardable ShapeDtypeStruct —
no device allocation ever happens in the dry-run.

Shape set (assigned):
  train_4k     seq=4096   global_batch=256   (training -> train_step)
  prefill_32k  seq=32768  global_batch=32    (inference prefill)
  decode_32k   seq=32768  global_batch=128   (one token, 32k KV cache)
  long_500k    seq=524288 global_batch=1     (long-context decode;
               sub-quadratic archs only: jamba / rwkv6 / mixtral-SWA)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.sharding import ShardingEnv

SDS = jax.ShapeDtypeStruct

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def subquadratic(cfg: ModelConfig) -> bool:
    return cfg.family in ("hybrid", "ssm") or cfg.sliding_window > 0


def cell_supported(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not subquadratic(cfg):
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip noted in "
                       "DESIGN.md)")
    return True, ""


def _batch_sds(cfg: ModelConfig, B: int, S: int, *, with_labels: bool):
    """Training/prefill batch ShapeDtypeStructs for every family."""
    d = cfg.d_model
    if cfg.enc_dec:
        out = {"frames": SDS((B, S, d), jnp.bfloat16),
               "tgt_tokens": SDS((B, max(S // 4, 8)), jnp.int32)}
        if with_labels:
            out["tgt_labels"] = SDS((B, max(S // 4, 8)), jnp.int32)
        return out
    if cfg.family == "vlm":
        Pn = min(cfg.n_frontend_tokens, S // 2)
        out = {"patches": SDS((B, Pn, d), jnp.bfloat16),
               "tokens": SDS((B, S - Pn), jnp.int32)}
        if with_labels:
            out["labels"] = SDS((B, S - Pn), jnp.int32)
        return out
    out = {"tokens": SDS((B, S), jnp.int32)}
    if with_labels:
        out["labels"] = SDS((B, S), jnp.int32)
    return out


def _batch_pspecs(cfg: ModelConfig, batch_sds, env: ShardingEnv):
    bt = env.batch_axes

    def spec(leaf):
        return env.named(leaf.shape, [bt] + [None] * (len(leaf.shape) - 1))

    return jax.tree_util.tree_map(spec, batch_sds)


def input_specs(cfg: ModelConfig, shape_name: str, env: ShardingEnv):
    """Returns dict(kind, args=(SDS...), in_shardings, out_shardings,
    static info) for the cell's step function."""
    info = SHAPES[shape_name]
    S, B, kind = info["seq"], info["batch"], info["kind"]
    param_sh = lm.param_shardings(cfg, env)

    if kind == "train":
        from repro.train import optimizer as opt
        ap = lm.abstract_params(cfg)
        aopt = opt.abstract_opt_state(ap)
        opt_sh = opt.opt_pspecs(param_sh)
        batch = _batch_sds(cfg, B, S, with_labels=True)
        batch_sh = _batch_pspecs(cfg, batch, env)
        return dict(kind=kind, args=(ap, aopt, batch),
                    in_shardings=(param_sh, opt_sh, batch_sh),
                    donate_argnums=(0, 1))

    if kind == "prefill":
        ap = lm.abstract_params(cfg)
        batch = _batch_sds(cfg, B, S, with_labels=False)
        batch_sh = _batch_pspecs(cfg, batch, env)
        cache_sh = lm.cache_pspecs(cfg, env, B, S)
        logits_sh = env.named((B, 1, cfg.vocab),
                              [env.batch_axes, None, "model"])
        return dict(kind=kind, args=(ap, batch),
                    in_shardings=(param_sh, batch_sh),
                    out_shardings=(logits_sh, cache_sh),
                    donate_argnums=())

    # decode: one new token with a KV cache of seq_len
    ap = lm.abstract_params(cfg)
    tgt_len = max(S // 4, 8) if cfg.enc_dec else S
    acache = lm.abstract_cache(cfg, B, tgt_len, src_len=S)
    cache_sh = lm.cache_pspecs(cfg, env, B, tgt_len, src_len=S)
    bt = None if env.opts.get("serve_fullshard") else env.batch_axes
    tokens = SDS((B, 1), jnp.int32)
    tokens_sh = env.named((B, 1), [bt, None])
    pos = SDS((), jnp.int32)
    pos_sh = env.named((), [])
    logits_sh = env.named((B, 1, cfg.vocab), [bt, None, "model"])
    return dict(kind=kind, args=(ap, tokens, acache, pos),
                in_shardings=(param_sh, tokens_sh, cache_sh, pos_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,))
