"""Multi-pod dry-run demo: lower + compile one (arch x shape) cell on the
2x16x16 = 512-chip production mesh and print the memory/cost analysis.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        [--arch command-r-35b] [--shape decode_32k]

(This script re-execs itself with the 512-host-device XLA flag; the full
sweep lives in repro/launch/dryrun.py.)
"""
import argparse
import os
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="command-r-35b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="multi", choices=["single", "multi"])
    args = ap.parse_args()
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.arch, "--shape", args.shape,
           "--mesh", args.mesh, "--tag", "demo", "--force",
           "--no-slopes" if args.mesh == "multi" else "--tag"]
    if cmd[-1] == "--tag":
        cmd = cmd[:-1]
    print("running:", " ".join(cmd))
    sys.exit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
