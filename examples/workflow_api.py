"""AgentProgram API demo: explicit-graph and dynamic-callback workflows
on the micro model.

Shows the two new submission flavors the unified API adds on top of
scripted requests (paper §3.1-§3.3):

  1. an explicit Agent Execution Graph with a retry loop — the branch
     structure is DECLARED to the scheduler (tier-a observability) and
     EXECUTED by a seeded resolver, so you can watch a retry edge being
     taken and the resumed step hitting its parked KV;
  2. a dynamic client callback that decides the next step from the real
     decoded tokens — the workflow's shape is not known in advance.

Both run through ``ServingRuntime.submit`` -> ``WorkflowHandle`` and,
for the graph flavor, the SAME spec also drives the discrete-event
cluster simulator — one submission API across both substrates.

    PYTHONPATH=src python examples/workflow_api.py
"""
import jax

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.configs import get_config, load_all
from repro.models import lm
from repro.serving.runtime import ServingRuntime
from repro.workflow import AgentProgram, StepSpec


def make_retry_graph(i: int) -> AgentProgram:
    """plan -> edit -> test (30% fail -> back to edit) -> commit."""
    nodes = {0: StepSpec("file_operations", 14, 3, tool_latency_s=0.05),
             1: StepSpec("code_execution", 10, 3, tool_latency_s=0.10),
             2: StepSpec("code_execution", 8, 2, tool_latency_s=0.20),
             3: StepSpec("database_query", 6, 2, tool_latency_s=0.05)}
    edges = [(0, 1, 0.98), (1, 2, 0.98),
             (2, 1, 0.30),              # retry: test failed, re-edit
             (2, 3, 0.68)]              # pass: commit
    return AgentProgram.graph(f"fix-{i}", f"team{i % 2}", nodes, edges,
                              seed=i, max_steps=12)


def dynamic_agent(ctx):
    """Client-side control flow: look at the last decoded token and
    decide what to do next (ctx.rng keeps replays deterministic)."""
    if ctx.step_idx < 0:                       # first step
        return StepSpec("code_execution", prompt_ids=[7, 8, 9, 10],
                        n_out=3, tool_latency_s=0.05)
    if ctx.step_idx >= 4:
        return None                            # agent decides: done
    last = ctx.outputs[-1][-1]
    if last % 3 == 0:
        return StepSpec("web_api", prompt_ids=[(last % 60) + 1] * 6,
                        n_out=2, tool_latency_s=0.1)
    return StepSpec("file_operations", prompt_ids=[(last % 60) + 1] * 4,
                    n_out=2, tool_latency_s=0.05)


def main() -> None:
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rt = ServingRuntime(cfg, params, n_workers=2, n_slots=2,
                        max_len=256, pool_blocks=96, seed=0)

    print("== explicit-graph programs (retry loop declared + executed)")
    handles = [rt.submit(make_retry_graph(i)) for i in range(6)]
    rt.run()
    rt.check_conservation()
    for h in handles:
        retried = any(b <= a for a, b in zip(h.path, h.path[1:]))
        print(f"  {h.session_id}: path={h.path}"
              f"{'  <- retry taken' if retried else ''}")
    s = rt.summarize()
    print(f"  cache hits {s['cache_hits']} (delta-only resumes), "
          f"regen {s['regen_tokens']} of {s['prefill_tokens']} "
          f"prefilled tokens")

    print("== dynamic-callback program (branches on decoded tokens)")
    h = rt.submit(AgentProgram.dynamic("dyn-agent", "team0",
                                       dynamic_agent,
                                       planned_tools=["code_execution"]))
    outs = h.result()                          # drives the virtual clock
    print(f"  {h.session_id}: {len(outs)} steps, "
          f"tools per step resolved at run time, tct={h.tct:.3f}s")

    print("== the same graph spec on the cluster simulator")
    sim = ClusterSim([make_retry_graph(i) for i in range(6)],
                     B.saga(), n_workers=2, seed=0)
    sim.run(horizon_s=3600)
    sim.check_conservation()
    ss = summarize(sim)
    same = all(sim.tasks[h.session_id].path == h.path for h in handles)
    print(f"  {ss['n_tasks']} programs finished, identical taken paths "
          f"across substrates: {same}")


if __name__ == "__main__":
    main()
