"""Wall-clock serving demo: the asyncio front end + OpenAI-compatible
HTTP proxy over real engines.

Starts ``AsyncServingDriver`` (real wall clock, compressed 20x) with
``SagaHTTPProxy`` on an ephemeral port, plays an OpenAI client against
it — a sticky multi-turn session (``X-Session-Id`` keeps park/resume on
the session's KV home engine), a streamed completion, a ``/metrics``
scrape — while a background agent fleet submitted through ``SagaClient``
keeps the engines busy.  See docs/SERVING_API.md.

    PYTHONPATH=src python examples/serve_frontend.py
"""
import asyncio
import json

import jax

from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.frontend import AsyncServingDriver, SagaHTTPProxy
from repro.serving.runtime import RuntimePerf, ServingRuntime


async def http(port, method, path, body=None, headers=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    head = f"{method} {path} HTTP/1.1\r\nHost: demo\r\nConnection: close\r\n"
    for k, v in (headers or {}).items():
        head += f"{k}: {v}\r\n"
    writer.write((head + f"Content-Length: {len(payload)}\r\n\r\n")
                 .encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    return data.split(b"\r\n\r\n", 1)[1]


async def main():
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rt = ServingRuntime(cfg, params, n_workers=2, n_slots=6, max_len=256,
                        pool_blocks=144, saga=SAGAConfig(), seed=0,
                        perf=RuntimePerf(prefill_tokens_per_s=8000.0 / 64))
    driver = AsyncServingDriver(rt, time_scale=0.05, executor=True)
    proxy = await SagaHTTPProxy(driver).start()
    pump = asyncio.create_task(driver.serve_forever())
    print(f"proxy listening on {proxy.base_url}")

    # background fleet through the unified client API
    fleet = SagaClient.for_driver(driver)
    handles = [fleet.submit(r) for r in runtime_requests(
        n_sessions=6, vocab=cfg.vocab, seed=0, n_steps=2, max_ctx=200)]

    # a sticky two-turn chat session: the second request is hinted to
    # the engine whose pool holds the first request's KV
    chat = {"model": "saga-micro", "max_tokens": 8,
            "messages": [{"role": "user", "content": "plan the fix"},
                         {"role": "assistant", "content": "running tests"},
                         {"role": "user", "content": "apply the patch"}],
            "saga": {"tool_gap_s": 0.2, "step_tokens": 4}}
    for i in range(2):
        raw = await http(proxy.port, "POST", "/v1/chat/completions",
                         chat, {"X-Session-Id": "demo-session"})
        resp = json.loads(raw)
        print(f"completion {i}: engine={resp['saga']['engine']} "
              f"steps={resp['saga']['steps']} "
              f"content={resp['choices'][0]['message']['content']!r}")

    raw = await http(proxy.port, "POST", "/v1/chat/completions",
                     dict(chat, stream=True),
                     {"X-Session-Id": "demo-session"})
    n_chunks = raw.count(b"chat.completion.chunk")
    print(f"streamed completion: {n_chunks} SSE chunks")

    await asyncio.gather(*(h.wait() for h in handles))
    metrics = (await http(proxy.port, "GET", "/metrics")).decode()
    depth = [l for l in metrics.splitlines()
             if l.startswith(("saga_queue_depth", "saga_kv_pool_blocks_used",
                              "saga_afs_deviation_max"))]
    print("metrics sample:\n  " + "\n  ".join(depth))

    driver.stop()
    await pump
    await proxy.stop()
    rt.check_conservation()
    print(f"done: {rt.n_done} sessions, "
          f"{driver.wall_stats['events']} events, "
          f"{driver.wall_stats['wall_elapsed_s']:.1f}s wall "
          f"({rt.ev.now:.1f}s virtual), conservation clean")


if __name__ == "__main__":
    asyncio.run(main())
