"""End-to-end driver: serve a small model with batched multi-step agent
requests through the full SAGA stack (REAL forward passes on CPU).

Two runs over the same agent workload:
  1. SAGA (workflow-atomic: session affinity + WA-LRU + TTL park/resume)
  2. request-level (vLLM-v0.6.0-style: KV discarded between steps)

The printed numbers are actual prefilled-token counts from the jitted
engine — the paper's central quantity, measured, not simulated.

    PYTHONPATH=src python examples/serve_agents.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.server import AgentRequest, MultiWorkerServer


def make_request(i, vocab, n_steps, rng):
    steps = []
    tools = ["code_execution", "file_operations", "web_api"]
    for s in range(n_steps):
        prompt = list(rng.randint(1, vocab, size=16))
        steps.append((prompt, 8, tools[s % 3], float(rng.uniform(0.1, 2.0))))
    return AgentRequest(f"agent-{i}", f"tenant{i % 2}", steps)


def main():
    load_all()
    cfg = get_config("micro")          # swap for "small-100m" if patient
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    requests = [make_request(i, cfg.vocab, n_steps=5, rng=rng)
                for i in range(6)]

    configs = {
        "SAGA (workflow-atomic)": SAGAConfig(),
        "request-level baseline": SAGAConfig(
            cache_policy="none", enable_affinity=False, enable_ttl=False,
            enable_prefetch=False, enable_afs=False, observability="none"),
    }
    results = {}
    for name, saga in configs.items():
        srv = MultiWorkerServer(cfg, params, n_workers=2, saga=saga,
                                n_slots=3, max_len=512, pool_blocks=96)
        # SagaClient is the submission surface; run_task is a shim now
        client = SagaClient.for_server(srv)
        t0 = time.time()
        for req in requests:
            client.submit(req)
            client.run()
        stats = client.stats()
        stats["wall_s"] = time.time() - t0
        results[name] = stats
        print(f"{name}: prefilled={stats['prefill_tokens']} tokens "
              f"(regenerated={stats['regen_tokens']}), "
              f"decoded={stats['decode_steps']} steps, "
              f"cache hits={stats['coordinator_hits']}, "
              f"{stats['wall_s']:.1f}s wall")

    saga_t = results["SAGA (workflow-atomic)"]["prefill_tokens"]
    base_t = results["request-level baseline"]["prefill_tokens"]
    print(f"\nprefill-work reduction: {base_t / max(saga_t, 1):.2f}x "
          "(this is the mechanism behind the paper's 1.64x TCT gain)")


if __name__ == "__main__":
    main()
