"""Simulate the paper's 64-GPU cluster: SAGA vs the full baseline matrix
on SWE-bench agents, with a worker crash injected mid-run.

    PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.cluster import baselines as B
from repro.cluster.faults import crash_recover_plan
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload


def main():
    tasks = swebench_workload(n_tasks=150, rate_per_min=5.0, seed=0)
    print(f"{len(tasks)} SWE-bench agent tasks, 16 workers (64 GPUs), "
          "one worker crash at t~500s\n")
    plan = crash_recover_plan(16, horizon_s=1500.0, n_faults=1,
                              downtime_s=120.0, seed=1)
    header = (f"{'system':18s} {'TCT':>7s} {'p99':>7s} {'SLO':>5s} "
              f"{'hit':>5s} {'regen%':>7s} {'migr':>5s}")
    print(header)
    for name in ["vllm", "vllm_apc", "sglang", "llumnix",
                 "trt_scaffolding", "kvflow", "saga"]:
        sim = ClusterSim(tasks, B.ALL_BASELINES[name](), n_workers=16,
                         seed=0, fault_plan=plan)
        sim.run(horizon_s=86400)
        s = summarize(sim)
        print(f"{name:18s} {s['tct_mean']:6.0f}s {s['tct_p99']:6.0f}s "
              f"{s['slo_attainment']:5.2f} {s['cache_hit_rate']:5.2f} "
              f"{s['regen_time_frac']:7.2f} "
              f"{s['migrations_per_task']:5.2f}")
    print("\nAll tasks completed despite the crash (cache loss -> "
          "regeneration; affinity re-routes).")


if __name__ == "__main__":
    main()
