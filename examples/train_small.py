"""Train a ~100M-parameter dense LM for a few hundred steps on CPU with
checkpoint/restart — the training-substrate end-to-end driver.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="small-100m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    _, _, losses = train_loop(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=1e-3, ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10,
        resume=True)
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
