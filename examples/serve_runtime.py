"""Concurrent serving demo: many agent sessions interleaved on real
engines through the event-driven runtime.

Submits a trace-driven SWE-bench/WebArena/BurstGPT-style agent mix to
``ServingRuntime`` — every decode step is a REAL batched forward pass on
the micro model — and contrasts workflow-atomic SAGA with the
request-level baseline: regenerated prefill tokens, virtual
task-completion time, and how continuous batching compresses forward
passes (decode rounds << decoded tokens).

    PYTHONPATH=src python examples/serve_runtime.py
"""
import time

import jax

from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.runtime import RuntimePerf, ServingRuntime


def main():
    load_all()
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    reqs = runtime_requests(n_sessions=12, vocab=cfg.vocab, seed=0,
                            n_steps=4, max_ctx=200)
    # token counts are scaled 64x down from the paper's traces; the
    # virtual prefill rate scales with them (see benchmarks/serve_bench)
    perf = RuntimePerf(prefill_tokens_per_s=8000.0 / 64.0)

    configs = {
        "SAGA (workflow-atomic)": SAGAConfig(),
        "request-level baseline": SAGAConfig(
            cache_policy="none", enable_affinity=False, enable_ttl=False,
            enable_prefetch=False, enable_afs=False,
            enable_stealing=False, observability="none"),
    }
    for name, saga in configs.items():
        rt = ServingRuntime(cfg, params, n_workers=2, saga=saga,
                            n_slots=4, max_len=256, pool_blocks=128,
                            perf=perf, seed=0)
        client = SagaClient.for_runtime(rt)
        t0 = time.time()
        for r in reqs:
            client.submit(r)
        client.run()
        client.check_conservation()
        s = client.summarize()
        print(f"{name}: {s['n_done']} sessions, "
              f"tct_mean={s['tct_mean']:.2f}s (virtual), "
              f"regen={s['regen_tokens']} tokens, "
              f"{s['decode_rounds']} batched rounds for "
              f"{s['decoded_tokens']} decoded tokens, "
              f"hits={s['cache_hits']}, steals={s['steals']}, "
              f"prefetch copies={s['prefetch_copies']}, "
              f"{time.time() - t0:.1f}s wall")


if __name__ == "__main__":
    main()
