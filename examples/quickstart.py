"""Quickstart: SAGA's core mechanism in 60 lines.

Builds an Agent Execution Graph for a coding agent, replays a bursty
multi-session trace through WA-LRU vs LRU vs the Bélády oracle, and
prints the empirical competitive ratios (the paper's Table 2 pipeline).

    PYTHONPATH=src python examples/quickstart.py
"""
import random

from repro.core.aeg import AEG, ToolStats
from repro.core.belady import Access, BeladyOracle, competitive_ratio, \
    replay_policy
from repro.core.ttl import ToolTTLPolicy
from repro.core.walru import EvictionWeights, LRUCache, WALRUCache


def make_trace(n_tasks=40, steps=10, seed=0):
    rng = random.Random(seed)
    events = []
    for i in range(n_tasks):
        t = rng.uniform(0, 120.0)
        for s in range(steps):
            t += 0.5 + rng.choice([0.2, 0.2, 0.4, 3.0, 12.0])  # tool gap
            events.append(Access(
                t=t, session=f"task{i}", tokens=2000.0 + 900.0 * s,
                bytes_=10.0 * (1 + s), node_id=s,
                tool=rng.choice(["code_execution", "web_api"]),
                last=(s == steps - 1)))
    events.sort(key=lambda a: a.t)
    return events


def main():
    trace = make_trace()
    # capacity: live working set + 20% headroom (the contended regime)
    live, peak = {}, 0.0
    for a in trace:
        live.pop(a.session, None) if a.last else live.update(
            {a.session: a.bytes_})
        peak = max(peak, sum(live.values()))
    cap = 1.2 * peak

    # --- workflow knowledge: one AEG per task (here: a ReAct chain) ----
    aeg = AEG.linear_chain(["code_execution"] * 11, p_term=0.03)
    stats = ToolStats()
    stats.observe("code_execution", 700, 0.3)
    stats.observe("web_api", 700, 2.0)

    def p_reuse(entry):
        if entry.completed:
            return 0.0
        return aeg.p_reuse(min(entry.node_id, 9), entry.tokens, stats)

    ttl = ToolTTLPolicy()
    for tool, lat in [("code_execution", 0.3), ("web_api", 2.0)] * 20:
        ttl.observe(tool, lat * random.Random(0).uniform(0.3, 4.0))

    opt = BeladyOracle(cap).replay(trace)
    walru = replay_policy(
        trace, WALRUCache(cap, EvictionWeights(), p_reuse_fn=p_reuse),
        ttl_policy=ttl)
    lru = replay_policy(trace, LRUCache(cap))

    print(f"regeneration cost (tokens): OPT={opt:,.0f} "
          f"WA-LRU={walru:,.0f} LRU={lru:,.0f}")
    print(f"competitive ratio: WA-LRU={competitive_ratio(walru, opt):.2f} "
          f"LRU={competitive_ratio(lru, opt):.2f}  (paper: 1.31 vs 2.84)")


if __name__ == "__main__":
    main()
