"""SagaClient facade + config/schema contract tests: one submit surface
across all four substrates, equivalence with the deprecated entry
points, SAGAConfig.validate's actionable errors, and the documented
stats()/summarize() key vocabulary held against live runtimes."""
import asyncio

import jax
import numpy as np
import pytest

from repro.cluster.workload import swebench_workload
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.frontend import AsyncServingDriver, FakeClock
from repro.serving.runtime import AgentRequest, ServingRuntime
from repro.serving.schema import (validate_stats, validate_summary,
                                  validate_wall_stats)
from repro.serving.server import MultiWorkerServer

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))

TOOLS = ["code_execution", "web_api", "file_operations"]


def _mk_requests(n, n_steps=2, seed=0):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab, size=8))),
                  4, TOOLS[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def _mk_runtime(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    return ServingRuntime(CFG, PARAMS, seed=0, **kw)


# -- the four backends --------------------------------------------------
def test_runtime_backend_matches_raw_runtime():
    raw = _mk_runtime()
    for r in _mk_requests(5):
        raw.submit(r)
    raw.run()

    client = SagaClient.for_runtime(_mk_runtime())
    handles = [client.submit(r) for r in _mk_requests(5)]
    client.run()
    client.check_conservation()
    assert repr(client.summarize()) == repr(raw.summarize())
    assert all(h.done and h.status == "done" for h in handles)
    assert client.handles[handles[0].session_id] is handles[0]
    assert client.stats()["decode_steps"] > 0


def test_server_backend_and_deprecated_run_task_shim():
    """for_server(submit+run) and the deprecated blocking run_task see
    the same runtime; the shim still works and agrees byte-for-byte."""
    srv_a = MultiWorkerServer(CFG, PARAMS, n_workers=2, n_slots=4,
                              max_len=256, pool_blocks=96)
    for r in _mk_requests(3):
        srv_a.run_task(r)

    srv_b = MultiWorkerServer(CFG, PARAMS, n_workers=2, n_slots=4,
                              max_len=256, pool_blocks=96)
    client = SagaClient.for_server(srv_b)
    assert client.runtime is srv_b.runtime
    for r in _mk_requests(3):
        h = client.submit(r)
        client.run()
        assert h.done
    assert repr(client.summarize()) == repr(srv_a.runtime.summarize())


def test_driver_backend():
    rt = _mk_runtime()
    client = SagaClient.for_driver(AsyncServingDriver(rt,
                                                      clock=FakeClock()))
    drv = client._driver

    async def go():
        hs = [client.submit(r) for r in _mk_requests(4)]
        assert client.run() is None        # driver runs via its coroutine
        await drv.run()
        for h in hs:
            assert (await h.wait()).state == "done"
        return hs

    hs = asyncio.run(go())
    assert all(h.done for h in hs)
    assert client.runtime is rt
    client.check_conservation()
    validate_wall_stats(drv.wall_stats)


def test_simulation_backend():
    tasks = swebench_workload(n_tasks=8, seed=1)
    client = SagaClient.for_simulation(SAGAConfig(), n_workers=4, seed=1)
    handles = [client.submit(t, slo=3600.0) for t in tasks]
    assert all(h.status == "pending" for h in handles)
    client.run()
    client.check_conservation()
    s = client.summarize()
    assert s["n_tasks"] == 8 and s["tct_mean"] > 0.0
    for h in handles:
        assert h.done and h.status == "done"
        assert h.tct > 0.0
        assert h.slo_met is not None
    # a sim client is one-shot: the simulator took its tasks at build
    with pytest.raises(RuntimeError, match="already ran"):
        client.submit(tasks[0])


def test_submit_tenant_and_slo_overrides():
    rt = _mk_runtime()
    client = SagaClient.for_runtime(rt)
    req = _mk_requests(1)[0]
    h = client.submit(req, tenant="override", slo=12.5)
    assert req.tenant == "t0"                  # caller's object untouched
    ses = rt.sessions[h.session_id]
    assert ses.inst.program.tenant == "override"
    assert ses.slo_s == 12.5
    client.run()
    assert h.done


def test_client_requires_exactly_one_backend():
    with pytest.raises(ValueError, match="for_runtime"):
        SagaClient()
    with pytest.raises(ValueError, match="for_runtime"):
        SagaClient(_runtime=object(), _server=object())


# -- SAGAConfig.validate ------------------------------------------------
def test_config_is_keyword_only():
    with pytest.raises(TypeError):
        SAGAConfig(0.5)


def test_config_validate_accepts_defaults_and_chains():
    cfg = SAGAConfig()
    assert cfg.validate() is cfg
    SAGAConfig(theta=5.0).validate()           # engine-count units: legal


def test_config_validate_lists_every_error():
    with pytest.raises(ValueError) as ei:
        SAGAConfig(alpha=1.5, theta=0.0, cache_policy="belady",
                   th_low=0.9, th_high=0.2).validate()
    msg = str(ei.value)
    assert "alpha=1.5 must be in [0.0, 1.0]" in msg
    assert "theta=0.0 must be > 0" in msg
    assert "cache_policy='belady' not one of" in msg
    assert "th_low=0.9 must not exceed th_high=0.2" in msg


def test_config_validate_cross_field_rules():
    with pytest.raises(ValueError, match="enable_preemption"):
        SAGAConfig(preempt_deficit=1.0).validate()
    with pytest.raises(ValueError, match="enable_afs"):
        SAGAConfig(enable_preemption=True, enable_afs=False).validate()
    SAGAConfig(enable_preemption=True, enable_afs=True,
               preempt_deficit=1.0).validate()


def test_config_validate_roles():
    cfg = SAGAConfig()
    with pytest.raises(ValueError, match="unknown engine roles"):
        cfg.validate(roles=["decode", "gpu"], n_workers=2)
    with pytest.raises(ValueError, match="2 roles for 3 engines"):
        cfg.validate(roles=["unified", "unified"], n_workers=3)
    with pytest.raises(ValueError, match="disaggregate=True"):
        cfg.validate(roles=["prefill", "decode"], n_workers=2)
    with pytest.raises(ValueError, match="all-prefill"):
        SAGAConfig(disaggregate=True).validate(
            roles=["prefill", "prefill"], n_workers=2)
    SAGAConfig(disaggregate=True).validate(
        roles=["prefill", "decode"], n_workers=2)


def test_bad_config_fails_loudly_at_construction():
    with pytest.raises(ValueError, match="invalid SAGAConfig"):
        _mk_runtime(saga=SAGAConfig(alpha=-1.0))


# -- stats()/summarize() schema ----------------------------------------
def _run_requests(**kw):
    rt = _mk_runtime(**kw)
    for r in _mk_requests(4):
        rt.submit(r)
    rt.run()
    return rt


def test_schema_default_mode():
    rt = _run_requests()
    validate_stats(rt.stats())
    validate_summary(rt.summarize())


def test_schema_fault_and_disagg_modes():
    rt = _run_requests(saga=SAGAConfig(enable_afs=True,
                                       enable_preemption=True))
    validate_stats(rt.stats())
    validate_summary(rt.summarize(), fault=True)

    rt = _run_requests(n_workers=3, n_slots=3,
                       saga=SAGAConfig(disaggregate=True))
    validate_stats(rt.stats())
    validate_summary(rt.summarize(), disagg=True)


def test_schema_rejects_drift():
    rt = _run_requests()
    s = rt.stats()
    s["new_counter"] = 7
    with pytest.raises(AssertionError, match="not in the schema"):
        validate_stats(s)
    s = rt.stats()
    del s["steals"]
    with pytest.raises(AssertionError, match="missing documented"):
        validate_stats(s)
    summ = rt.summarize()
    summ["extra"] = 1.0
    with pytest.raises(AssertionError, match="schema expectation"):
        validate_summary(summ)
    # conditional keys may not appear in default mode
    with pytest.raises(AssertionError, match="schema expectation"):
        validate_summary(rt.summarize(), fault=True)
