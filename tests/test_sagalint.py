"""sagalint analyzer tests: per-rule fixtures (positive, suppressed,
negative), the CFG early-return lifecycle leak, self-check that the
repo tree lints clean, and the seeded-bug demo — reverting a real
attempt-stamp guard in the runtime is caught by lint."""
import re
from pathlib import Path

import pytest

from repro.analysis.sagalint import lint_file, lint_paths, main

REPO = Path(__file__).resolve().parents[1]


def _lint(tmp_path, source, name="fix.py"):
    p = tmp_path / name
    p.write_text(source)
    return lint_file(p)


def _rules(findings):
    return [f.rule for f in findings]


# -- determinism rules -------------------------------------------------
def test_det_hash(tmp_path):
    fs = _lint(tmp_path, (
        "def f(x):\n"
        "    a = hash(x)\n"
        "    b = hash(7)\n"
        "    c = hash('salt')  # sagalint: ok(det-hash) demo\n"
        "    return a, b, c\n"))
    assert _rules(fs) == ["det-hash"]
    assert fs[0].line == 2
    assert "FNV" in fs[0].message


def test_det_clock(tmp_path):
    fs = _lint(tmp_path, (
        "import time\n"
        "def f(self):\n"
        "    t = time.time()\n"
        "    u = self.clock.now()\n"          # instance call: fine
        "    return t, u, time.sleep\n"))
    assert _rules(fs) == ["det-clock"]
    assert fs[0].line == 3


def test_det_rng(tmp_path):
    fs = _lint(tmp_path, (
        "import random\n"
        "import numpy as np\n"
        "def f(self):\n"
        "    a = random.random()\n"
        "    b = random.Random()\n"
        "    c = random.Random(0)\n"          # seeded: fine
        "    d = np.random.rand(3)\n"
        "    e = np.random.RandomState()\n"
        "    g = np.random.RandomState(0)\n"  # seeded: fine
        "    h = self.rng.gauss(0, 1)\n"      # instance stream: fine
        "    return a, b, c, d, e, g, h\n"))
    assert _rules(fs) == ["det-rng"] * 4
    assert [f.line for f in fs] == [4, 5, 7, 8]


def test_det_env(tmp_path):
    fs = _lint(tmp_path, (
        "import os\n"
        "def f():\n"
        "    a = os.environ.get('X')\n"
        "    b = os.getenv('Y')\n"
        "    # sagalint: ok(det-env) fixture demo of a standalone pragma\n"
        "    c = os.environ['Z']\n"
        "    return a, b, c\n"))
    assert _rules(fs) == ["det-env", "det-env"]
    assert [f.line for f in fs] == [3, 4]


SET_ORDER_SRC = """\
class C:
    def __init__(self):
        self.active = set()
        self.q = []

    def bad_key(self):
        return sorted(self.active, key=lambda s: len(s))

    def good_tiebreak(self):
        return sorted(self.active, key=lambda s: (len(s), s))

    def good_plain(self):
        return sorted(self.active)

    def bad_pick(self):
        return next(iter(self.active))

    def bad_pop(self):
        return self.active.pop()

    def bad_spray(self):
        for s in self.active:
            self._queue_push(0, s)

    def good_spray(self):
        for s in sorted(self.active):
            self._queue_push(0, s)

    def _queue_push(self, p, s):
        self.q.append((p, s))
"""


def test_det_set_order(tmp_path):
    fs = _lint(tmp_path, SET_ORDER_SRC)
    assert _rules(fs) == ["det-set-order"] * 4
    assert [f.line for f in fs] == [7, 16, 19, 22]


def test_set_order_shared_state_mutation(tmp_path):
    fs = _lint(tmp_path, (
        "class C:\n"
        "    def __init__(self):\n"
        "        self.dirty = set()\n"
        "        self.rows = {}\n"
        "    def flush(self):\n"
        "        for t in self.dirty:\n"
        "            self.rows[t] = 1.0\n"))
    assert _rules(fs) == ["det-set-order"]


# -- lifecycle rules ---------------------------------------------------
LEAK_SRC = """\
class D:
    def handle(self, sid, w):
        self.inflight[sid] = (w, 0)
        ok = self.engine.poke(sid)
        if not ok:
            return
        self.inflight.pop(sid)
"""

NO_LEAK_SRC = LEAK_SRC.replace(
    "            return\n",
    "            self.inflight.pop(sid)\n            return\n")


def test_life_leak_early_return(tmp_path):
    fs = _lint(tmp_path, LEAK_SRC)
    assert _rules(fs) == ["life-leak"]
    assert fs[0].line == 3                   # the acquire
    assert "line 6" in fs[0].message         # the leaking exit
    assert not _lint(tmp_path, NO_LEAK_SRC, "ok.py")


def test_life_leak_handoff_and_raise_exempt(tmp_path):
    fs = _lint(tmp_path, (
        "class D:\n"
        "    def ok_handoff(self, sid, w):\n"
        "        self.inflight[sid] = (w, 0)\n"
        "        if not self.engine.poke(sid):\n"
        "            self.ev.schedule(0.0, 'retry', (sid,))\n"
        "            return\n"
        "        self.inflight.pop(sid)\n"
        "    def ok_crash(self, sid, w):\n"
        "        self.inflight[sid] = (w, 0)\n"
        "        if not self.engine.poke(sid):\n"
        "            raise RuntimeError('invariant')\n"
        "        self.inflight.pop(sid)\n"))
    assert not fs


def test_life_leak_slot_family(tmp_path):
    fs = _lint(tmp_path, (
        "class D:\n"
        "    def admit(self, sid, w):\n"
        "        slot = self.engines[w].start_session(sid)\n"
        "        if slot is None:\n"
        "            return False\n"
        "        if not self.healthy(w):\n"
        "            return False\n"           # slot leaks here
        "        self.engines[w].release_session(sid)\n"
        "        return True\n"))
    assert _rules(fs) == ["life-leak"]
    assert "slot" in fs[0].message


def test_life_leak_pool_scoped_alloc(tmp_path):
    """Allocate-at-admit vocabulary: ``*.pool.alloc`` acquires blocks
    (a path dropping them without free/evict/handoff leaks), while a
    bare ``list.extend`` never classifies as a block acquire."""
    fs = _lint(tmp_path, (
        "class D:\n"
        "    def admit(self, sid, w):\n"
        "        self.engines[w].pool.alloc(sid)\n"
        "        if not self.healthy(w):\n"
        "            return False\n"            # blocks leak here
        "        self.engines[w].pool.free_session(sid)\n"
        "        return True\n"))
    assert _rules(fs) == ["life-leak"]
    assert "blocks" in fs[0].message
    assert not _lint(tmp_path, (
        "class D:\n"
        "    def gather(self, items):\n"
        "        out = []\n"
        "        for it in items:\n"
        "            out.extend(it)\n"
        "            if not it:\n"
        "                return None\n"
        "        self.pool.free_session('x')\n"
        "        return out\n"), "ok.py")


def test_life_span_leak(tmp_path):
    """Receiver-scoped span family: a ``tracer.begin`` whose early exit
    neither ends the span nor hands it off reports under the dedicated
    ``life-span`` rule id."""
    fs = _lint(tmp_path, (
        "class D:\n"
        "    def step(self, sid):\n"
        "        span = self.tracer.begin('s', 'step', self.now)\n"
        "        if not self.healthy(sid):\n"
        "            return\n"                  # span leaks here
        "        self.tracer.end(span, self.now)\n"))
    assert _rules(fs) == ["life-span"]
    assert "span" in fs[0].message
    # suppressible like any rule
    assert not _lint(tmp_path, (
        "class D:\n"
        "    def step(self, sid):\n"
        "        span = self.tracer.begin('s', 'step', self.now)"
        "  # sagalint: ok(life-span) caller closes via _tr_open\n"
        "        if not self.healthy(sid):\n"
        "            return\n"
        "        self.tracer.end(span, self.now)\n"), "sup.py")


def test_life_span_negative_paths(tmp_path):
    """No finding when every path ends the span, when the early exit
    hands off to a scheduled continuation, when a purely-acquiring
    helper defers the end to its caller (the ``_tr_begin`` wrapper
    shape), or when bare ``begin``/``end`` lack a tracer receiver."""
    assert not _lint(tmp_path, (
        "class D:\n"
        "    def ok_all_paths(self, sid):\n"
        "        span = self.tracer.begin('s', 'step', self.now)\n"
        "        if not self.healthy(sid):\n"
        "            self.tracer.end(span, self.now, status='dropped')\n"
        "            return\n"
        "        self.tracer.end(span, self.now)\n"
        "    def ok_handoff(self, sid):\n"
        "        self.tracer.begin('s', 'step', self.now)\n"
        "        if not self.healthy(sid):\n"
        "            self.ev.schedule(0.0, 'retry', (sid,))\n"
        "            return\n"
        "        self.tracer.end(0, self.now)\n"
        "    def ok_pure_helper(self, sid):\n"
        "        self._open[sid] = self.tracer.begin('s', 'x', self.now)\n"
        "    def ok_bare_names(self, tx):\n"
        "        h = tx.begin()\n"
        "        if h is None:\n"
        "            return\n"
        "        tx.end()\n"))


GUARD_SRC = """\
class D:
    def _on_step_done(self, sid, attempt=-1):
        ses = self.sessions[sid]
        ses.count += 1
"""

GUARDED_SRC = """\
class D:
    def _on_step_done(self, sid, attempt=-1):
        rec = self.inflight.get(sid)
        if rec is None or rec[1] != attempt:
            return
        self.sessions[sid].count += 1
"""


def test_life_guard(tmp_path):
    fs = _lint(tmp_path, GUARD_SRC)
    assert _rules(fs) == ["life-guard"]
    assert "attempt" in fs[0].message
    assert not _lint(tmp_path, GUARDED_SRC, "ok.py")
    sup = GUARD_SRC.replace(
        "    def _on_step_done(self, sid, attempt=-1):\n",
        "    # sagalint: ok(life-guard) fixture: idempotent handler\n"
        "    def _on_step_done(self, sid, attempt=-1):\n")
    assert not _lint(tmp_path, sup, "sup.py")


# -- pragma hygiene ----------------------------------------------------
def test_pragma_requires_reason_and_use(tmp_path):
    fs = _lint(tmp_path, (
        "import os\n"
        "def f():\n"
        "    return os.getenv('X')  # sagalint: ok(det-env)\n"))
    assert sorted(_rules(fs)) == ["det-env", "pragma"]
    fs = _lint(tmp_path, (
        "def g():\n"
        "    return 1  # sagalint: ok(det-hash) nothing here\n"),
        "unused.py")
    assert _rules(fs) == ["pragma-unused"]
    fs = _lint(tmp_path, (
        "def h():\n"
        "    return 2  # sagalint: ok(not-a-rule) whatever\n"),
        "unknown.py")
    assert "pragma" in _rules(fs)


def test_pragma_in_docstring_is_inert(tmp_path):
    fs = _lint(tmp_path, (
        '"""Docs: suppress with # sagalint: ok(det-hash) reason."""\n'
        "def f():\n"
        "    return 1\n"))
    assert not fs


# -- scoping -----------------------------------------------------------
def test_scheduler_scope(tmp_path):
    src = "import os\nX = os.getenv('A')\n"
    core = tmp_path / "repro" / "core"
    core.mkdir(parents=True)
    launch = tmp_path / "repro" / "launch"
    launch.mkdir(parents=True)
    (core / "mod.py").write_text(src)
    (launch / "mod.py").write_text(src)
    assert _rules(lint_file(core / "mod.py")) == ["det-env"]
    assert not lint_file(launch / "mod.py")


# -- whole-tree self-check ---------------------------------------------
def test_repo_lints_clean(capsys):
    assert main([str(REPO / "src" / "repro")]) == 0


def test_cli_fixture_diagnostics(tmp_path, capsys):
    p = tmp_path / "bad.py"
    p.write_text("def f(x):\n    return hash(x)\n")
    assert main([str(p)]) == 1
    out = capsys.readouterr().out
    assert re.search(r"bad\.py:2:\d+: det-hash:", out)


# -- seeded known bugs -------------------------------------------------
RUNTIME = REPO / "src" / "repro" / "serving" / "runtime.py"


def test_reverted_attempt_guard_is_caught(tmp_path):
    """Deleting the stale-attempt guard from ``_on_prefill_done`` (the
    exact bug an engine-failure race would reintroduce) must trip
    life-guard."""
    src = RUNTIME.read_text()
    guard = ("        rec = self.inflight.get(sid)\n"
             "        if rec is None or rec[1] != attempt:\n"
             "            return       # stale: the attempt was "
             "cancelled by a fault\n")
    assert guard in src, "runtime guard moved; update this test"
    broken = src.replace(guard, "        rec = self.inflight.get(sid)\n")
    p = tmp_path / "runtime.py"
    p.write_text(broken)
    fs = [f for f in lint_file(p) if f.rule == "life-guard"]
    assert fs and any("_on_prefill_done" in f.message for f in fs)
    # the pristine copy stays clean outside the tree too
    q = tmp_path / "runtime_ok.py"
    q.write_text(src)
    assert not [f for f in lint_file(q) if f.rule == "life-guard"]


def test_fnv_replaced_by_hash_is_caught(tmp_path):
    """Swapping an FNV-1a call for builtin hash() in the simulator's
    routing path must trip det-hash."""
    sim = (REPO / "src" / "repro" / "cluster" / "simulator.py")
    src = sim.read_text()
    assert re.search(r"(?<!def )_fnv1a\(", src)
    broken = re.sub(r"(?<!def )_fnv1a\(", "hash(", src)
    p = tmp_path / "simulator.py"
    p.write_text(broken)
    assert "det-hash" in _rules(lint_file(p))
    q = tmp_path / "simulator_ok.py"
    q.write_text(src)
    assert "det-hash" not in _rules(lint_file(q))


def test_lint_paths_counts(tmp_path):
    (tmp_path / "a.py").write_text("A = 1\n")
    (tmp_path / "b.py").write_text("def f(x):\n    return hash(x)\n")
    findings, n = lint_paths([str(tmp_path)])
    assert n == 2
    assert _rules(findings) == ["det-hash"]


def test_parse_error_reported(tmp_path):
    fs = _lint(tmp_path, "def broken(:\n")
    assert _rules(fs) == ["parse-error"]
    with pytest.raises(SystemExit):
        main([])


# -- scoped configuration exemptions -----------------------------------
LOOP_TIME_SRC = (
    "import asyncio\n"
    "def f(self):\n"
    "    loop = asyncio.get_running_loop()\n"
    "    return loop.time()\n")


def test_loop_time_flagged_in_scheduler_scope(tmp_path):
    """loop.time() is a det-clock read everywhere in scheduler code..."""
    p = tmp_path / "repro" / "serving" / "mod.py"
    p.parent.mkdir(parents=True)
    p.write_text(LOOP_TIME_SRC)
    assert "det-clock" in _rules(lint_file(p))


def test_loop_time_permitted_in_frontend_scope(tmp_path):
    """...except under serving/frontend, whose SCOPE_EXEMPT charter is
    to read the wall clock — configuration, not per-line pragmas."""
    p = tmp_path / "repro" / "serving" / "frontend" / "mod.py"
    p.parent.mkdir(parents=True)
    p.write_text(LOOP_TIME_SRC)
    fs = lint_file(p)
    assert "det-clock" not in _rules(fs)
    # the exemption is det-clock ONLY: other determinism rules survive
    p.write_text(LOOP_TIME_SRC + "def g(x):\n    return hash(x)\n")
    assert _rules(lint_file(p)) == ["det-hash"]


def test_frontend_scope_is_exact_prefix(tmp_path):
    """A look-alike package elsewhere gets no exemption."""
    p = tmp_path / "repro" / "cluster" / "frontend" / "mod.py"
    p.parent.mkdir(parents=True)
    p.write_text(LOOP_TIME_SRC)
    assert "det-clock" in _rules(lint_file(p))


def test_repo_frontend_actually_reads_the_clock():
    """The shipped wall-clock driver uses the exempted idiom (if this
    stops being true, drop the SCOPE_EXEMPT entry)."""
    src = (REPO / "src" / "repro" / "serving" / "frontend"
           / "clock.py").read_text()
    assert "loop.time()" in src
    from repro.analysis.sagalint import lint_paths
    findings, n = lint_paths([str(REPO / "src" / "repro" / "serving"
                                  / "frontend")])
    assert n >= 5
    assert findings == []
