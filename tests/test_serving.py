"""Real-JAX serving engine tests: paged pool invariants, park/resume
exactness, SAGA-vs-request-level on actual forward passes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.kvcache import PagedKVPool
from repro.serving.server import AgentRequest, MultiWorkerServer

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))


# --- paged pool --------------------------------------------------------------
def test_pool_alloc_free_invariants():
    pool = PagedKVPool(2, num_blocks=8, block_size=4, n_kv_heads=2,
                       head_dim=8)
    k = jnp.ones((2, 10, 2, 8), jnp.bfloat16)
    assert pool.park("a", k, k, 10)
    assert pool.used_blocks() == 3           # ceil(10/4)
    assert pool.session_bytes("a") == 3 * pool.bytes_per_block
    got = pool.resume("a")
    assert got is not None and got[2] == 10
    pool.free_session("a")
    assert pool.used_blocks() == 0
    assert len(set(pool.free)) == 8          # no double-free


def test_pool_park_roundtrip_exact():
    pool = PagedKVPool(3, num_blocks=16, block_size=4, n_kv_heads=2,
                       head_dim=8)
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 11, 2, 8),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (3, 11, 2, 8),
                          jnp.bfloat16)
    pool.park("s", k, v, 11)
    k2, v2, n = pool.resume("s")
    assert n == 11
    assert jnp.array_equal(k2, k[:, :11]) and jnp.array_equal(v2, v[:, :11])


def test_pool_rejects_when_full():
    pool = PagedKVPool(1, num_blocks=2, block_size=4, n_kv_heads=1,
                       head_dim=4)
    k = jnp.ones((1, 8, 1, 4), jnp.bfloat16)
    assert pool.park("a", k, k, 8)
    assert not pool.park("b", k, k, 8)       # caller must evict


# --- engine park/resume exactness ------------------------------------------------
def test_park_resume_preserves_generation():
    """Decoding with a parked+resumed cache matches uninterrupted decode."""
    rng = np.random.RandomState(0)
    prompt = rng.randint(1, CFG.vocab, size=24).astype(np.int32)

    eng1 = Engine(CFG, PARAMS, n_slots=1, max_len=128, pool_blocks=32)
    s1 = eng1.start_session("x", prompt, cached_hit=False)
    out_straight = eng1.decode({s1: int(prompt[-1])}, n_steps=8)[s1]

    eng2 = Engine(CFG, PARAMS, n_slots=1, max_len=128, pool_blocks=32)
    s2 = eng2.start_session("x", prompt, cached_hit=False)
    first = eng2.decode({s2: int(prompt[-1])}, n_steps=4)[s2]
    eng2.park_session("x")
    ctx = np.concatenate([prompt, np.asarray(first, np.int32)])
    s2b = eng2.start_session("x", ctx, cached_hit=True)
    rest = eng2.decode({s2b: int(ctx[-1])}, n_steps=4)[s2b]
    assert out_straight == first + rest


def test_resume_prefills_only_delta():
    rng = np.random.RandomState(1)
    prompt = rng.randint(1, CFG.vocab, size=20).astype(np.int32)
    eng = Engine(CFG, PARAMS, n_slots=1, max_len=128, pool_blocks=32)
    s = eng.start_session("x", prompt, cached_hit=False)
    assert eng.prefill_tokens == 20
    eng.decode({s: int(prompt[-1])}, n_steps=2)
    eng.park_session("x")
    ctx = np.concatenate([prompt, rng.randint(1, CFG.vocab, size=6)
                          .astype(np.int32)])
    eng.start_session("x", ctx, cached_hit=True)
    # only the 6 new tokens prefilled (the 2 decoded are in cache... the
    # delta is ctx beyond parked len = 20+2 -> 4 new tokens prefilled)
    assert eng.prefill_tokens == 20 + (len(ctx) - 22)


# --- multi-worker server ------------------------------------------------------------
def _mk_req(i, vocab, n_steps=3, rng=None):
    rng = rng or np.random.RandomState(i)
    steps = []
    for _ in range(n_steps):
        steps.append((list(rng.randint(1, vocab, size=8)), 4,
                      "code_execution", 0.2))
    return AgentRequest(f"sess{i}", "tenant0", steps)


def test_server_saga_reduces_regeneration():
    saga_cfg = SAGAConfig()
    req_cfg = SAGAConfig(cache_policy="none", enable_affinity=False,
                         enable_ttl=False, enable_prefetch=False,
                         enable_afs=False, observability="none")
    results = {}
    for name, cfg in [("saga", saga_cfg), ("reqlevel", req_cfg)]:
        srv = MultiWorkerServer(CFG, PARAMS, n_workers=2, saga=cfg,
                                n_slots=2, max_len=256, pool_blocks=64)
        for i in range(3):
            srv.run_task(_mk_req(i, CFG.vocab))
        results[name] = srv.stats()
    assert results["saga"]["regen_tokens"] < \
        results["reqlevel"]["regen_tokens"]
    assert results["saga"]["coordinator_hits"] > 0
    assert results["reqlevel"]["coordinator_hits"] == 0
    # identical decode work either way (policies change prefill only)
    assert results["saga"]["decode_steps"] == \
        results["reqlevel"]["decode_steps"]


def test_server_stats_surface_lifecycle_counters():
    """``MultiWorkerServer.stats()`` must expose the runtime's full
    counter set: the copy-byte counters (park/resume/migration) and the
    fault/preemption lifecycle counters, matching the runtime's own
    values — the server is a thin wrapper, not a filter."""
    srv = MultiWorkerServer(CFG, PARAMS, n_workers=2, n_slots=2,
                            max_len=256, pool_blocks=64)
    for i in range(2):
        srv.run_task(_mk_req(i, CFG.vocab))
    st = srv.stats()
    for key in ("park_copy_bytes", "resume_copy_bytes",
                "migration_copy_bytes", "steals", "migrations",
                "prefetch_copies", "faults_injected",
                "cancelled_attempts", "preemptions", "afs_dev_max"):
        assert key in st, f"server stats missing {key}"
        assert st[key] == srv.runtime.stats()[key]
    # a clean serial run injects no faults and preempts nothing
    assert st["faults_injected"] == 0
    assert st["cancelled_attempts"] == 0
    assert st["preemptions"] == 0
