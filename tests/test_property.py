"""Property-based tests (hypothesis) for SAGA's invariants."""
import math

import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: pip install hypothesis (or .[test])")

from hypothesis import given, settings, strategies as st

from repro.core.aeg import AEG, ToolStats
from repro.core.afs import AFSScheduler, TaskProgress
from repro.core.belady import Access, BeladyOracle, replay_policy
from repro.core.ttl import ToolTTLPolicy, memory_pressure
from repro.core.walru import CacheEntry, EvictionWeights, LRUCache, \
    WALRUCache

sizes = st.floats(min_value=1.0, max_value=100.0)
times = st.floats(min_value=0.0, max_value=1000.0)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), sizes, times), min_size=1,
                max_size=60), st.floats(min_value=10.0, max_value=500.0))
def test_walru_capacity_invariant(ops, capacity):
    """used <= capacity after any insert sequence; used equals the sum of
    entry sizes."""
    c = WALRUCache(capacity)
    t = 0.0
    for sid, size, dt in ops:
        t += dt
        c.insert(CacheEntry(f"s{sid}", size, t), now=t)
        assert c.used <= capacity + 1e-9
        assert abs(c.used - sum(e.size_bytes
                                for e in c.entries.values())) < 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_p_evict_bounded(r, reuse, s):
    c = WALRUCache(100.0, EvictionWeights(), p_reuse_fn=lambda e: reuse)
    e = CacheEntry("x", s * 100.0, (1 - r) * 100.0)
    v = c.p_evict(e, now=100.0, tau_max=100.0, size_max=100.0)
    assert -1e-9 <= v <= 1.0 + 1e-9


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=0.001, max_value=100.0), min_size=1,
                max_size=200), st.floats(0.0, 1.0))
def test_ttl_bounds(history, pressure):
    """Algorithm 1: 0 <= ttl <= TTL_max; monotone non-increasing in
    memory pressure."""
    pol = ToolTTLPolicy(ttl_max_s=300.0)
    for v in history:
        pol.observe("t", v)
    ttl_hi = pol.ttl("t", 0.0)
    ttl_lo = pol.ttl("t", pressure)
    assert 0.0 <= ttl_lo <= ttl_hi <= 300.0


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 2.0))
def test_memory_pressure_range(u):
    m = memory_pressure(u)
    assert 0.0 <= m <= 1.0
    assert memory_pressure(min(u + 0.05, 2.0)) >= m   # monotone


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(2, 10), st.integers(0, 10_000),
       st.floats(min_value=50.0, max_value=2000.0))
def test_belady_is_lower_bound(n_tasks, steps, seed, capacity):
    """No online policy beats the offline-optimal replay."""
    import random
    rng = random.Random(seed)
    trace = []
    for i in range(n_tasks):
        t = rng.uniform(0, 10)
        for s in range(steps):
            t += rng.uniform(0.1, 2.0)
            trace.append(Access(t=t, session=f"s{i}",
                                tokens=100.0 * (s + 1),
                                bytes_=20.0 * (s + 1), node_id=s,
                                last=(s == steps - 1)))
    trace.sort(key=lambda a: a.t)
    opt = BeladyOracle(capacity).replay(trace)
    lru = replay_policy(trace, LRUCache(capacity))
    wal = replay_policy(trace, WALRUCache(capacity))
    assert opt <= lru + 1e-6
    assert opt <= wal + 1e-6


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.floats(1.0, 100.0), st.floats(1.0, 100.0)),
                min_size=1, max_size=10))
def test_afs_shares_are_a_distribution(tasks):
    afs = AFSScheduler()
    for i, (work, slack) in enumerate(tasks):
        afs.add_task(TaskProgress(f"t{i}", f"ten{i % 3}",
                                  deadline=slack, work_remain_s=work))
    shares = afs.recompute(now=0.0)
    assert abs(sum(shares.values()) - 1.0) < 1e-6
    assert all(v >= 0 for v in shares.values())


@settings(max_examples=30, deadline=None)
@given(st.floats(100.0, 100000.0), st.floats(1.0, 5000.0))
def test_overlap_in_unit_interval(n_cur, n_obs):
    aeg = AEG.linear_chain(["t"] * 3)
    stats = ToolStats()
    stats.observe("t", n_obs, 0.1)
    ov = aeg.overlap(n_cur, 1, stats)
    assert 0.0 <= ov < 1.0
