"""Pallas kernel validation: shape/dtype sweeps vs ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _pallas_interpret_unavailable():
    """Probe the Pallas interpret path this whole suite depends on.
    Some toolchains (CPU-only runners with older wheels, new Python
    versions before Pallas catches up) cannot execute kernel bodies at
    all — in that case the suite self-skips through pytest's own skip
    machinery with the probe's reason, instead of CI ignoring the file
    wholesale and silently dropping coverage where it WOULD run."""
    try:
        from jax.experimental import pallas as pl

        def k(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        x = jnp.arange(8, dtype=jnp.float32)
        out = pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)
        if float(out[1]) != 2.0:
            return "pallas interpret mode produced a wrong result"
        return None
    except Exception as e:          # pragma: no cover - env dependent
        return f"pallas interpret mode unavailable: " \
               f"{type(e).__name__}: {e}"


_SKIP_REASON = _pallas_interpret_unavailable()
if _SKIP_REASON:                    # pragma: no cover - env dependent
    pytest.skip(_SKIP_REASON, allow_module_level=True)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --- flash attention ----------------------------------------------------------
@pytest.mark.parametrize("B,Sq,Sk,H,K,D", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 256, 4, 4, 64),
    (2, 128, 128, 8, 2, 128),
    (1, 384, 384, 6, 3, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 64)])
def test_flash_attention(B, Sq, Sk, H, K, D, dtype, causal, window):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    if not causal and Sq != Sk:
        pytest.skip("cross shapes covered by causal sweep")
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Sq, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, K, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    assert err < TOL[dtype], err


# --- paged decode attention ------------------------------------------------------
@pytest.mark.parametrize("B,H,K,dh,block,nblocks,nb", [
    (2, 4, 2, 64, 16, 32, 4),
    (3, 8, 8, 128, 32, 64, 3),
    (1, 8, 4, 64, 8, 16, 5),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, K, dh, block, nblocks, nb, dtype):
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_decode_ref
    key = jax.random.PRNGKey(0)
    rng = np.random.RandomState(0)
    q = jax.random.normal(key, (B, H, dh), dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (nblocks, block, K, dh), dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (nblocks, block, K, dh), dtype)
    tables = np.stack([rng.choice(nblocks, size=nb, replace=False)
                       for _ in range(B)]).astype(np.int32)
    lens = rng.randint(1, nb * block + 1, size=B).astype(np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(tables),
                          jnp.asarray(lens))
    ref = paged_decode_ref(q, kp, vp, jnp.asarray(tables),
                           jnp.asarray(lens))
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                ref.astype(jnp.float32))))
    assert err < TOL[dtype], err


# --- rwkv6 -------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,dh,chunk", [
    (2, 128, 2, 16, 32), (1, 64, 4, 64, 64), (2, 96, 2, 32, 32),
])
def test_wkv6(B, T, H, dh, chunk):
    from repro.kernels.rwkv6.ops import wkv6
    from repro.kernels.rwkv6.ref import wkv6_ref
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (B, T, H, dh)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, dh)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, dh))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 3),
                                         (B, T, H, dh))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, dh)) * 0.3
    out = wkv6(r, k, v, w, u, chunk=chunk)
    ref = wkv6_ref(r, k, v, w, u)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3


# --- mamba scan -----------------------------------------------------------------------
@pytest.mark.parametrize("B,T,di,ds,bd,chunk", [
    (2, 64, 32, 8, 32, 32), (1, 128, 64, 16, 32, 64), (2, 96, 48, 8, 16, 32),
])
def test_mamba_scan(B, T, di, ds, bd, chunk):
    from repro.kernels.mamba_scan.ops import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, T, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5),
                                           (B, T, di))) * 0.1
    Bc = jax.random.normal(jax.random.fold_in(key, 6), (B, T, ds))
    Cc = jax.random.normal(jax.random.fold_in(key, 7), (B, T, ds))
    A_log = jnp.log(jnp.broadcast_to(
        jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, ds)))
    D = jnp.ones((di,), jnp.float32)
    out = mamba_scan(x, dt, Bc, Cc, A_log, D, block_d=bd, chunk=chunk)
    ref = mamba_scan_ref(x, dt, Bc, Cc, A_log, D)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


# --- kernels vs model layers (integration) ---------------------------------------------
def test_flash_matches_model_chunked_attention():
    """The Pallas kernel, the chunked-jnp distributed path, and the dense
    oracle all agree."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.models.layers import attention_dense, chunked_attention, \
        expand_kv
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 256, 2, 64))
    a = flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, expand_kv(k, 4), expand_kv(v, 4), causal=True)
    c = chunked_attention(q, expand_kv(k, 4), expand_kv(v, 4), causal=True,
                          mode="tri")
    d = chunked_attention(q, expand_kv(k, 4), expand_kv(v, 4), causal=True,
                          bwd_safe=True)
    e = attention_dense(q, k, v, causal=True)
    for name, x in [("pallas", a), ("chunked", b), ("tri", c),
                    ("bwd_safe", d)]:
        err = float(jnp.max(jnp.abs(x - e)))
        assert err < 2e-5, (name, err)
