"""Asyncio wall-clock front end tests: fake-clock byte-identity with
the virtual-time loop (inline and executor-threaded), the OpenAI proxy
round trip with sticky session headers landing park/resume on one
engine, pluggable LB strategies, /metrics shape, and a soak-style
conservation gate over real wall clock."""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.client import SagaClient
from repro.serving.frontend import (AsyncServingDriver, FakeClock,
                                    LeastLoaded, RoundRobin, SagaHTTPProxy,
                                    Strategy, get_strategy,
                                    register_strategy)
from repro.serving.runtime import AgentRequest, ServingRuntime
from repro.serving.schema import validate_wall_stats

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))

TOOLS = ["code_execution", "web_api", "file_operations"]


def _mk_requests(n, n_steps=2, seed=0, prompt_len=8, n_out=4):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab,
                                            size=prompt_len))),
                  n_out, TOOLS[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def _mk_runtime(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    kw.setdefault("saga", SAGAConfig())
    return ServingRuntime(CFG, PARAMS, seed=0, **kw)


def _virtual_summary(reqs):
    rt = _mk_runtime()
    for r in reqs:
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    return repr(rt.summarize())


def _driver_summary(reqs, *, executor):
    rt = _mk_runtime()
    drv = AsyncServingDriver(rt, clock=FakeClock(), executor=executor)
    client = SagaClient.for_driver(drv)

    async def go():
        for r in reqs:
            client.submit(r)
        await drv.run()

    asyncio.run(go())
    rt.check_conservation()
    validate_wall_stats(drv.wall_stats)
    assert drv.wall_stats["events"] > 0
    return repr(rt.summarize())


# -- byte-identity ------------------------------------------------------
def test_fake_clock_reproduces_virtual_run_byte_identically():
    """The driver pops the same heap through the same handlers with the
    same termination condition, so a fake-clock run must reproduce the
    virtual-time summarize() repr byte for byte."""
    want = _virtual_summary(_mk_requests(6))
    assert _driver_summary(_mk_requests(6), executor=False) == want


def test_fake_clock_byte_identity_with_executor_thread():
    """Handler execution on the worker thread stays strictly serial, so
    threading must not perturb a single byte either."""
    want = _virtual_summary(_mk_requests(6))
    assert _driver_summary(_mk_requests(6), executor=True) == want


# -- HTTP proxy ---------------------------------------------------------
async def _http(port, method, path, body=None, headers=None):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    payload = json.dumps(body).encode() if body is not None else b""
    head = f"{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n"
    for k, v in (headers or {}).items():
        head += f"{k}: {v}\r\n"
    head += f"Content-Length: {len(payload)}\r\n\r\n"
    writer.write(head.encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    status = int(data.split(b" ", 2)[1])
    hdr_blob, _, rest = data.partition(b"\r\n\r\n")
    hdrs = {}
    for line in hdr_blob.split(b"\r\n")[1:]:
        k, _, v = line.decode("latin-1").partition(":")
        hdrs[k.strip().lower()] = v.strip()
    return status, hdrs, rest


CHAT = {"model": "t", "max_tokens": 4,
        "messages": [{"role": "user", "content": "step one prompt"},
                     {"role": "assistant", "content": "ok"},
                     {"role": "user", "content": "step two prompt"}],
        "saga": {"tool_gap_s": 0.05, "step_tokens": 3}}


def test_http_round_trip_sticky_session_and_metrics():
    """Two completions on one X-Session-Id land on the same engine (the
    proxy hints the session's KV home); the multi-turn body parks on
    its tool gap; /metrics and /healthz expose the fleet."""
    rt = _mk_runtime()
    drv = AsyncServingDriver(rt, time_scale=0.01)
    proxy_holder = {}

    async def go():
        proxy = await SagaHTTPProxy(drv, strategy="round-robin").start()
        proxy_holder["p"] = proxy
        pump = asyncio.create_task(drv.serve_forever())
        out = []
        for i in range(2):
            status, hdrs, body = await _http(
                proxy.port, "POST", "/v1/chat/completions", CHAT,
                {"X-Session-Id": "cli-A", "X-Task-Id": f"task-{i}",
                 "X-Program-Id": "prog-A", "X-Tenant": "tenantA"})
            out.append((status, hdrs, json.loads(body)))
        # a distinct client session goes through the strategy instead
        status_b, hdrs_b, _ = await _http(
            proxy.port, "POST", "/v1/chat/completions", CHAT,
            {"X-Session-Id": "cli-B"})
        st_m, _, metrics = await _http(proxy.port, "GET", "/metrics")
        st_h, _, health = await _http(proxy.port, "GET", "/healthz")
        st_r, _, lifecycle = await _http(
            proxy.port, "GET",
            "/v1/requests/" + out[1][2]["saga"]["session_id"])
        drv.stop()
        await pump
        await proxy.stop()
        return out, (status_b, hdrs_b), (st_m, metrics), \
            (st_h, health), (st_r, lifecycle)

    out, b, met, health, life = asyncio.run(go())
    for status, hdrs, resp in out:
        assert status == 200
        assert resp["object"] == "chat.completion"
        assert resp["choices"][0]["message"]["content"].startswith("tok")
        assert resp["usage"]["completion_tokens"] > 0
        assert resp["saga"]["steps"] == 2        # two user turns parked
        assert hdrs["x-session-id"] == "cli-A"
        assert hdrs["x-program-id"] == "prog-A"
    assert out[0][1]["x-task-id"] == "task-0"
    # sticky: request 2 followed request 1's KV home
    assert out[1][1]["x-engine"] == out[0][1]["x-engine"]
    assert b[0] == 200
    assert met[0] == 200
    text = met[1].decode()
    for family in ("saga_queue_depth", "saga_engine_alive",
                   "saga_kv_pool_blocks_used", "saga_kv_pool_blocks_total",
                   "saga_kv_handoff_bytes", "saga_afs_deviation_max",
                   "saga_sessions_done", "saga_runtime_prefill_tokens"):
        assert family in text, f"/metrics missing {family}"
    assert 'saga_queue_depth{engine="1"}' in text
    assert health[0] == 200
    assert json.loads(health[1])["engines"] == 2
    assert life[0] == 200
    lc = json.loads(life[1])
    assert lc["phase"] == "done"
    assert lc["tenant"] == "tenantA"
    assert "parked" in lc["phase_wall_s"]        # the tool gap was real
    assert lc["first_token_wall"] is not None
    rt.check_conservation()


def test_http_streaming_sse():
    rt = _mk_runtime()
    drv = AsyncServingDriver(rt, time_scale=0.01)

    async def go():
        proxy = await SagaHTTPProxy(drv).start()
        pump = asyncio.create_task(drv.serve_forever())
        status, hdrs, body = await _http(
            proxy.port, "POST", "/v1/chat/completions",
            dict(CHAT, stream=True), {"X-Session-Id": "s"})
        drv.stop()
        await pump
        await proxy.stop()
        return status, hdrs, body

    status, hdrs, body = asyncio.run(go())
    assert status == 200
    assert hdrs["transfer-encoding"] == "chunked"
    assert b"chat.completion.chunk" in body
    assert b'"finish_reason": "stop"' in body
    assert body.rstrip().endswith(b"0")          # final chunk terminator
    assert b"data: [DONE]" in body


# -- strategies ---------------------------------------------------------
def test_strategy_picks():
    loads, alive = [3.0, 1.0, 2.0], [True, True, True]
    roles = ["unified", "unified", "unified"]
    assert get_strategy("saga-affinity").pick("k", loads, alive,
                                              roles) is None
    assert LeastLoaded().pick("k", loads, alive, roles) == 1
    rr = RoundRobin()
    assert [rr.pick("k", loads, alive, roles) for _ in range(4)] == \
        [0, 1, 2, 0]
    # dead and prefill-role engines are never picked
    assert LeastLoaded().pick("k", loads, [True, False, True],
                              ["prefill", "unified", "unified"]) == 2
    rr2 = RoundRobin()
    assert [rr2.pick("k", loads, [True, False, True],
                     ["unified", "unified", "unified"])
            for _ in range(3)] == [0, 2, 0]
    assert LeastLoaded().pick("k", loads, [False] * 3, roles) is None


def test_strategy_registry_and_custom_plugin():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("nope")

    class Pinned(Strategy):
        name = "pin-last-test"

        def pick(self, session_key, loads, alive, roles):
            ok = self._eligible(loads, alive, roles)
            return ok[-1] if ok else None

    register_strategy(Pinned)
    assert get_strategy("pin-last-test").pick(
        "k", [0.0, 0.0], [True, True], ["unified", "unified"]) == 1
    with pytest.raises(ValueError, match="taken"):
        register_strategy(Pinned)


def test_route_hint_is_one_shot_first_placement():
    """route_hint pins the first dispatch; later steps follow the
    scheduler (here: affinity keeps them home)."""
    rt = _mk_runtime()
    h = rt.submit(AgentRequest("s0", "t0", [
        ([5, 6, 7], 4, "web_api", 0.05),
        ([8, 9], 4, "web_api", 0.05)]), route_hint=1)
    rt.run()
    assert h.done
    # hinted first placement became the session's home, so the resume
    # after the tool gap was an affinity cache hit on the same engine
    assert rt.sessions["s0"].engine == 1
    assert rt.stats()["coordinator_hits"] == 1


# -- wall-clock soak (small) -------------------------------------------
def test_wall_clock_soak_conserves():
    """Real WallClock + executor thread + compressed time scale: every
    session completes, no slot/block leaks, pacing stats sane."""
    rt = _mk_runtime(n_slots=6)
    drv = AsyncServingDriver(rt, time_scale=0.002, executor=True)
    client = SagaClient.for_driver(drv)
    reqs = _mk_requests(24, seed=3)

    async def go():
        handles = [client.submit(r) for r in reqs]
        await drv.run()
        return handles

    handles = asyncio.run(go())
    assert all(h.done for h in handles)
    rt.check_conservation()
    rt.verify_pool_mirrors()
    for eng in rt.engines:
        assert eng.pool.audit_blocks() == []
    validate_wall_stats(drv.wall_stats)
    assert drv.wall_stats["submitted"] == 24
    assert drv.wall_stats["wall_elapsed_s"] > 0.0


def test_driver_rejects_bad_time_scale_and_double_run():
    rt = _mk_runtime()
    with pytest.raises(ValueError, match="time_scale"):
        AsyncServingDriver(rt, time_scale=0.0)

    drv = AsyncServingDriver(rt, clock=FakeClock())

    async def go():
        drv._begin()
        with pytest.raises(RuntimeError, match="already running"):
            await drv.run()
        drv._end()

    asyncio.run(go())
