"""Competitive-ratio harness tests (paper §7, Table 2)."""
import random

import pytest

from repro.core.belady import Access, BeladyOracle, competitive_ratio, \
    replay_policy
from repro.core.ttl import ToolTTLPolicy
from repro.core.walru import EvictionWeights, LRUCache, PrefixLRUCache, \
    WALRUCache
from repro.core.aeg import AEG, ToolStats


def _agent_trace(n_tasks=20, steps=8, seed=0, entry_bytes=10.0,
                 interleave=True):
    """Interleaved multi-session workflow trace with growing contexts."""
    rng = random.Random(seed)
    events = []
    for i in range(n_tasks):
        t0 = rng.uniform(0, 50.0)
        t = t0
        for s in range(steps):
            t += rng.uniform(0.1, 3.0)
            tokens = 1000.0 + 600.0 * s
            events.append(Access(
                t=t, session=f"s{i}", tokens=tokens,
                bytes_=entry_bytes * (1 + s), node_id=s,
                tool=rng.choice(["code_execution", "web_api"]),
                last=(s == steps - 1), prefix_tokens=300.0))
    events.sort(key=lambda a: a.t)
    return events


def _mk_walru(capacity, trace):
    """WA-LRU wired with an oracle-ish AEG reuse signal."""
    aeg = AEG.linear_chain(["code_execution"] * 9, p_term=0.02)
    stats = ToolStats()
    stats.observe("code_execution", 500, 0.3)
    stats.observe("web_api", 500, 1.0)
    sessions_alive = {a.session for a in trace if not a.last}

    def p_reuse(entry):
        if entry.completed:
            return 0.0
        return aeg.p_reuse(min(entry.node_id, 8), entry.tokens, stats)

    return WALRUCache(capacity, EvictionWeights(), p_reuse_fn=p_reuse)


@pytest.mark.parametrize("capacity", [120.0, 250.0])
def test_cr_at_least_one(capacity):
    trace = _agent_trace()
    opt = BeladyOracle(capacity).replay(trace)
    for cache in [_mk_walru(capacity, trace), LRUCache(capacity)]:
        cost = replay_policy(trace, cache, ttl_policy=ToolTTLPolicy())
        assert competitive_ratio(cost, opt) >= 1.0 - 1e-9


def test_walru_beats_lru_on_workflow_traces():
    trace = _agent_trace(n_tasks=30, steps=10, seed=1)
    capacity = 400.0
    opt = BeladyOracle(capacity).replay(trace)
    wal = replay_policy(trace, _mk_walru(capacity, trace),
                        ttl_policy=ToolTTLPolicy())
    lru = replay_policy(trace, LRUCache(capacity))
    assert wal <= lru
    # WA-LRU within a small factor of OPT on workflow traces (Thm 3)
    assert competitive_ratio(wal, opt) < competitive_ratio(lru, opt) + 1e-9


def test_prefix_cache_between_lru_and_walru():
    trace = _agent_trace(n_tasks=30, steps=10, seed=2)
    capacity = 400.0
    lru = replay_policy(trace, LRUCache(capacity))
    prefix = replay_policy(trace, PrefixLRUCache(capacity))
    assert prefix <= lru                     # radix prefix always helps


def test_belady_zero_cost_when_everything_fits():
    trace = _agent_trace(n_tasks=5, steps=4)
    opt = BeladyOracle(1e9).replay(trace)
    # only cold-start prefills (first access per session)
    first_costs = sum(a.tokens for a in trace
                      if a.node_id == 0)
    assert opt == pytest.approx(first_costs)
