"""Unified AgentProgram API tests (simulator side, no JAX).

Covers the three program flavors on ``ClusterSim``, the Task adapter's
byte-identity, branch/retry execution and determinism, the coordinator's
taken-edge threading, and the workload satellites (O(1) context sums,
``poisson_arrivals`` zero-rate guard, ``cv_scale`` plumbing)."""
import os
import random
import subprocess
import sys
from pathlib import Path

import pytest

from repro.cluster import baselines as B
from repro.cluster.faults import chaos_plan
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import (Step, Task, burstgpt_workload,
                                    poisson_arrivals,
                                    swebench_retry_programs,
                                    swebench_workload,
                                    webarena_branch_programs,
                                    webarena_workload)
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.workflow import (AgentProgram, DynamicContext, StepSpec,
                            as_instance)

SRC = str(Path(__file__).resolve().parents[1] / "src")

RETRY_NODES = {0: StepSpec("code_execution", 2000, 200, obs_tokens=900),
               1: StepSpec("file_operations", 300, 150, obs_tokens=500),
               2: StepSpec("code_execution", 250, 200, obs_tokens=1200),
               3: StepSpec("database_query", 200, 100, obs_tokens=300)}
RETRY_EDGES = [(0, 1, 0.97), (1, 2, 0.97), (2, 1, 0.30), (2, 3, 0.67),
               (3, 1, 0.10)]


def _retry_programs(n=12, max_steps=40):
    return [AgentProgram.graph(f"g{i}", f"t{i % 3}", RETRY_NODES,
                               RETRY_EDGES, seed=i, arrival_s=i * 2.0,
                               max_steps=max_steps)
            for i in range(n)]


def _took_retry(path):
    return any(b <= a for a, b in zip(path, path[1:]))


# --- program semantics -------------------------------------------------

def test_scripted_instance_shares_task_steps():
    task = swebench_workload(n_tasks=2, rate_per_min=4.0, seed=0)[0]
    inst = as_instance(task)
    assert inst.steps is task.steps
    assert inst.context_after(3) == task.context_after(3)
    assert inst.context_before(3) == task.context_before(3)
    assert inst.tools() == task.tools()
    assert inst.resolve_next(0) is task.steps[1]
    assert inst.resolve_next(task.n_steps - 1) is None


def test_graph_path_deterministic_and_memoized():
    prog = AgentProgram.graph("g", "t", RETRY_NODES, RETRY_EDGES,
                              seed=3, max_steps=40)
    a, b = prog.instantiate(), prog.instantiate()
    for inst in (a, b):
        i = 0
        while inst.resolve_next(i) is not None:
            i += 1
    assert a.path == b.path
    # memoized: re-resolving an already-resolved index never re-rolls
    assert a.resolve_next(0) is a.steps[1]


def test_graph_retry_edge_executes():
    """With p(retry) > 0, some seed in a small pool takes the backward
    edge — branches execute, they are not just prediction metadata."""
    paths = []
    for i in range(12):
        inst = AgentProgram.graph(f"g{i}", "t", RETRY_NODES, RETRY_EDGES,
                                  seed=i, max_steps=40).instantiate()
        j = 0
        while inst.resolve_next(j) is not None:
            j += 1
        paths.append(inst.path)
    assert any(_took_retry(p) for p in paths)
    assert all(len(p) <= 40 for p in paths)


def test_graph_max_steps_caps_cycles():
    nodes = {0: StepSpec("web_api", 100, 50)}
    inst = AgentProgram.graph("loop", "t", nodes, [(0, 0, 1.0)],
                              max_steps=5).instantiate()
    i = 0
    while inst.resolve_next(i) is not None:
        i += 1
    assert inst.n_steps == 5


def test_graph_validates_edges():
    with pytest.raises(ValueError):
        AgentProgram.graph("g", "t", {0: StepSpec("a", 1, 1)},
                           [(0, 9, 0.5)])
    with pytest.raises(ValueError):
        AgentProgram.graph("g", "t", {0: StepSpec("a", 1, 1),
                                      1: StepSpec("a", 1, 1)},
                           [(0, 1, 0.8), (0, 0, 0.4)])


def test_dynamic_callback_sees_history_and_rng():
    seen = []

    def cb(ctx: DynamicContext):
        seen.append((ctx.step_idx, len(ctx.history), ctx.last_tool))
        assert isinstance(ctx.rng, random.Random)
        if ctx.step_idx >= 1:
            return None
        return StepSpec("web_api", 100, 50, tool_latency_s=0.1)

    inst = AgentProgram.dynamic("d", "t", cb).instantiate()
    i = 0
    while inst.resolve_next(i) is not None:
        i += 1
    assert inst.n_steps == 2
    assert seen[0] == (-1, 0, "")          # pre-first-step call
    assert seen[1][0] == 0 and seen[1][1] == 1


# --- simulator execution ----------------------------------------------

def test_branching_program_completes_on_sim():
    progs = _retry_programs()
    sim = ClusterSim(progs, B.saga(), n_workers=4, seed=0)
    sim.run(horizon_s=36000)
    sim.check_conservation()
    s = summarize(sim)
    assert s["n_tasks"] == len(progs)
    assert any(_took_retry(sim.tasks[p.program_id].path) for p in progs)
    # executed path length lands in the metrics
    for p in progs:
        assert sim.metrics[p.program_id].steps == \
            len(sim.tasks[p.program_id].path)


def test_branching_program_sim_deterministic():
    runs = []
    for _ in range(2):
        sim = ClusterSim(_retry_programs(), B.saga(), n_workers=4, seed=0)
        sim.run(horizon_s=36000)
        runs.append((repr(summarize(sim)),
                     [sim.tasks[f"g{i}"].path for i in range(12)]))
    assert runs[0] == runs[1]


def test_same_spec_same_path_across_instances():
    """The taken path depends only on (program_id, seed): a simulator
    instance and a bare re-instantiation resolve identical branches."""
    progs = _retry_programs(n=6)
    sim = ClusterSim(progs, B.saga(), n_workers=2, seed=5)
    sim.run(horizon_s=36000)
    sim.check_conservation()
    for p in _retry_programs(n=6):
        ref = p.instantiate()
        i = 0
        while ref.resolve_next(i) is not None:
            i += 1
        assert sim.tasks[p.program_id].path == ref.path


@pytest.mark.parametrize("routing", ["session", "least", "group",
                                     "sticky"])
def test_branching_conservation_under_chaos(routing):
    """Satellite: branching programs + chaos faults conserve for every
    routing mode (cancelled/retried steps must not re-roll branches)."""
    pol = B.saga()
    pol.routing = routing
    progs = _retry_programs(n=10, max_steps=30)
    plan = chaos_plan(4, 400.0, n_events=12, seed=1)
    sim = ClusterSim(progs, pol, n_workers=4, seed=2, fault_plan=plan)
    sim.run(horizon_s=72000)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == 10


def test_mixed_tasks_and_programs_one_sim():
    tasks = swebench_workload(n_tasks=4, rate_per_min=6.0, seed=1)
    progs = _retry_programs(n=4)
    sim = ClusterSim(list(tasks) + progs, B.saga(), n_workers=4, seed=0)
    sim.run(horizon_s=72000)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == 8


def test_dynamic_program_on_sim():
    def cb(ctx):
        if ctx.step_idx >= 3:
            return None
        tool = "code_execution" if ctx.rng.random() < 0.5 else "web_api"
        return StepSpec(tool, 200, 100, obs_tokens=400,
                        tool_latency_s=0.2)

    progs = [AgentProgram.dynamic(f"d{i}", "t0", cb,
                                  planned_tools=["code_execution"] * 4,
                                  seed=i, arrival_s=float(i))
             for i in range(4)]
    sim = ClusterSim(progs, B.saga(), n_workers=2, seed=0)
    sim.run(horizon_s=36000)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == 4


def test_generated_branching_mixes_run():
    progs = swebench_retry_programs(n_programs=6, seed=0) + \
        webarena_branch_programs(n_programs=6, seed=0)
    assert len(progs) == 12
    sim = ClusterSim(progs, B.saga(), n_workers=4, seed=1)
    sim.run(horizon_s=720000)
    sim.check_conservation()
    paths = [sim.tasks[p.program_id].path for p in progs]
    # the webarena conditional actually branches across the pool
    web = paths[6:]
    assert any(1 in p for p in web) or any(4 in p for p in web)


# --- coordinator threading --------------------------------------------

def test_coordinator_follows_taken_edge():
    co = GlobalCoordinator(SAGAConfig(), 2, 1e12)
    prog = AgentProgram.graph("g", "t", RETRY_NODES, RETRY_EDGES, seed=0)
    inst = prog.instantiate()
    co.register_task("g", "t", inst.tools(), 100.0, 10.0, 0.0,
                     aeg=inst.declared_aeg(), step_cost_s=1.0,
                     entry_node=0)
    info = co.sessions["g"]
    assert info.declared and info.node_id == 0
    w0 = co.afs.tasks["g"].work_remain_s
    co.on_step_end("g", 0, 3100.0, 1000.0, "code_execution", 1.0,
                   next_node=2)
    assert info.node_id == 2               # the taken edge, not +1
    # Eq. 9 re-estimate landed from the declared branch structure
    assert co.afs.tasks["g"].work_remain_s != w0
    assert co.afs.tasks["g"].work_remain_s == pytest.approx(
        inst.declared_aeg().work_remaining_steps(2) * 1.0)


def test_request_level_baseline_stays_blind():
    """observability='none' systems must not see a declared graph."""
    cfg = SAGAConfig(observability="none")
    co = GlobalCoordinator(cfg, 2, 1e12)
    inst = AgentProgram.graph("g", "t", RETRY_NODES, RETRY_EDGES,
                              seed=0).instantiate()
    co.register_task("g", "t", inst.tools(), 100.0, 10.0, 0.0,
                     aeg=inst.declared_aeg(), step_cost_s=1.0)
    assert co.sessions["g"].aeg is None
    assert not co.sessions["g"].declared


def test_declared_aeg_survives_snapshot_roundtrip():
    """Checkpoint/restart must preserve the declared graph itself —
    Eq. 9 re-estimation and prefetch targeting run on it after restore
    (a restored coordinator used to rebuild a fake linear chain)."""
    co = GlobalCoordinator(SAGAConfig(), 2, 1e12)
    inst = AgentProgram.graph("s", "t", RETRY_NODES, RETRY_EDGES,
                              seed=0).instantiate()
    co.register_task("s", "t", inst.tools(), 100.0, 10.0, 0.0,
                     aeg=inst.declared_aeg(), step_cost_s=2.5,
                     entry_node=0)
    snap = co.snapshot()
    co2 = GlobalCoordinator(SAGAConfig(), 2, 1e12)
    co2.restore(snap)
    info = co2.sessions["s"]
    assert info.declared and info.step_cost_s == 2.5
    ref = inst.declared_aeg()
    assert info.aeg.successors(2) == ref.successors(2)
    assert info.aeg.work_remaining_steps(1) == \
        ref.work_remaining_steps(1)
    # taken-edge advancement + Eq. 9 still work on the restored graph
    co2.on_step_end("s", 0, 3100.0, 1000.0, "code_execution", 1.0,
                    next_node=2)
    assert info.node_id == 2


def test_undeclared_snapshot_falls_back_to_hints():
    co = GlobalCoordinator(SAGAConfig(), 2, 1e12)
    co.register_task("s", "t", ["a", "b"], 100.0, 10.0, 0.0)
    snap = co.snapshot()
    co2 = GlobalCoordinator(SAGAConfig(), 2, 1e12)
    co2.restore(snap)
    assert not co2.sessions["s"].declared
    assert co2.sessions["s"].aeg is not None   # linear-chain fallback


# --- workload satellites ----------------------------------------------

def test_poisson_zero_rate_returns_empty():
    rng = random.Random(0)
    assert poisson_arrivals(0.0, 600.0, rng) == []
    assert poisson_arrivals(5.0, 0.0, rng) == []
    assert poisson_arrivals(-1.0, 600.0, rng) == []


def test_burstgpt_zero_load_factor():
    assert burstgpt_workload(horizon_s=60.0, load_factor=0.0) == []


def test_cv_scale_plumbed_through_generators():
    """cv_scale=0 collapses tool latencies to their medians for every
    generator (it used to be silently ignored by webarena/burstgpt)."""
    for gen in (lambda cv: webarena_workload(n_tasks=3, seed=0,
                                             cv_scale=cv),
                lambda cv: burstgpt_workload(horizon_s=40.0, seed=0,
                                             load_factor=0.2,
                                             cv_scale=cv)):
        wide = [s.tool_latency_s for t in gen(1.0) for s in t.steps]
        tight = [s.tool_latency_s for t in gen(0.0) for s in t.steps]
        assert len(set(round(x, 9) for x in tight)) <= 4  # per-tool medians
        assert len(set(wide)) > len(set(tight))


def test_task_context_cumsum_matches_naive():
    task = swebench_workload(n_tasks=5, rate_per_min=30.0, seed=2)[0]

    def naive_after(i):
        ctx = task.prefix_tokens
        for s in task.steps[:i + 1]:
            ctx += s.new_prompt_tokens + s.out_tokens + s.obs_tokens
        return ctx

    def naive_before(i):
        ctx = task.prefix_tokens
        for s in task.steps[:i]:
            ctx += s.new_prompt_tokens + s.out_tokens + s.obs_tokens
        return ctx + task.steps[i].new_prompt_tokens

    for i in range(task.n_steps):
        assert task.context_after(i) == naive_after(i)     # bit-exact
        assert task.context_before(i) == naive_before(i)

    # cache invalidates when the step list grows
    n = task.n_steps
    task.steps.append(Step(10.0, 5.0, "web_api", 20.0, 0.1))
    assert task.context_after(n) == naive_after(n)


# --- cross-process byte-identity --------------------------------------

_BRANCH_SNIPPET = """
from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_retry_programs
progs = swebench_retry_programs(n_programs=8, seed=0)
sim = ClusterSim(progs, B.saga(), n_workers=4, seed=0)
sim.run(horizon_s=720000)
sim.check_conservation()
print(repr(summarize(sim)))
print([sim.tasks[p.program_id].path for p in progs])
"""


def test_branching_summary_identical_across_processes():
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _BRANCH_SNIPPET],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert "tct_mean" in outs[0]
