"""Cluster-simulator behaviour tests: end-to-end scheduling dynamics,
fault tolerance, straggler mitigation, elasticity."""
import pytest

from repro.cluster import baselines as B
from repro.cluster.faults import crash_recover_plan
from repro.cluster.perf import PerfModel
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import burstgpt_workload, swebench_workload


def _run(policy, tasks, n_workers=8, fault_plan=None, seed=0):
    sim = ClusterSim(tasks, policy, n_workers=n_workers, seed=seed,
                     fault_plan=fault_plan)
    sim.run(horizon_s=36000)
    return sim, summarize(sim)


@pytest.fixture(scope="module")
def small_swe():
    return swebench_workload(n_tasks=60, rate_per_min=2.5, seed=0)


def test_all_tasks_complete(small_swe):
    sim, s = _run(B.saga(), small_swe)
    assert s["n_tasks"] == len(small_swe)
    assert all(m.finish >= m.arrival for m in sim.metrics.values())


def test_saga_beats_request_level(small_swe):
    _, saga = _run(B.saga(), small_swe)
    _, vllm = _run(B.vllm(), small_swe)
    assert saga["tct_mean"] < vllm["tct_mean"]
    assert saga["regen_time_frac"] < vllm["regen_time_frac"]
    assert saga["cache_hit_rate"] > 0.7
    assert vllm["cache_hit_rate"] == 0.0


def test_ablation_ordering(small_swe):
    """Removing session affinity hurts the most (Table 4)."""
    _, full = _run(B.saga(), small_swe)
    _, no_aff = _run(B.saga_ablation("affinity"), small_swe)
    assert no_aff["tct_mean"] >= full["tct_mean"] - 1e-6


def test_worker_failure_recovery(small_swe):
    """Tasks survive worker crashes: cache loss -> regeneration, not
    task loss."""
    plan = crash_recover_plan(8, horizon_s=1200.0, n_faults=2, seed=1)
    sim, s = _run(B.saga(), small_swe, fault_plan=plan)
    assert s["n_tasks"] == len(small_swe)     # nothing lost
    _, clean = _run(B.saga(), small_swe)
    assert s["regen_tokens_total"] >= clean["regen_tokens_total"]


def test_elastic_scale_up(small_swe):
    plan = [(60.0, "scale_up", 0), (120.0, "scale_up", 0)]
    sim, s = _run(B.saga(), small_swe, n_workers=4, fault_plan=plan)
    assert sim.n_workers == 6
    assert s["n_tasks"] == len(small_swe)


def test_work_stealing_reduces_imbalance():
    """With a hotspot routing policy, stealing drains hot queues."""
    tasks = swebench_workload(n_tasks=50, rate_per_min=6.0, seed=3)
    pol_steal = B.saga()
    pol_nosteal = B.saga_ablation("stealing")
    _, with_steal = _run(pol_steal, tasks, n_workers=6)
    _, no_steal = _run(pol_nosteal, tasks, n_workers=6)
    assert with_steal["tct_p99"] <= no_steal["tct_p99"] * 1.25


def test_multi_tenant_fairness_direction():
    """SAGA protects light tenants far better than request-level FCFS
    (Table 6's qualitative claim)."""
    tasks = burstgpt_workload(horizon_s=420.0, seed=0, load_factor=0.2)
    _, saga = _run(B.saga(), tasks, n_workers=16)
    _, vllm = _run(B.vllm(), tasks, n_workers=16)
    assert saga["slo_attainment"] > vllm["slo_attainment"]
    assert saga["slo_by_tenant"].get("light", 0) >= \
        vllm["slo_by_tenant"].get("light", 0)


@pytest.mark.slow
def test_bfs_dfs_tradeoff():
    """Table 8: DFS minimizes evictions (depth-first admission keeps the
    working set tiny under memory pressure); BFS floods the pool."""
    tasks = swebench_workload(n_tasks=60, rate_per_min=10.0, seed=4)
    perf = PerfModel(kv_pool_bytes=40e9)      # pressured pool
    dfs_pol = B.strategy("dfs")
    dfs_pol.admission_max_tasks = 8
    sim_d = ClusterSim(tasks, dfs_pol, n_workers=8, perf=perf, seed=0)
    sim_d.run(horizon_s=36000)
    dfs = summarize(sim_d)
    sim_b = ClusterSim(tasks, B.strategy("bfs"), n_workers=8, perf=perf,
                       seed=0)
    sim_b.run(horizon_s=36000)
    bfs = summarize(sim_b)
    assert dfs["evict_rate"] <= bfs["evict_rate"] + 1e-9
    assert bfs["cache_hit_rate"] <= dfs["cache_hit_rate"] + 1e-9


def test_deterministic_given_seed(small_swe):
    _, a = _run(B.saga(), small_swe, seed=7)
    _, b = _run(B.saga(), small_swe, seed=7)
    assert a["tct_mean"] == b["tct_mean"]
