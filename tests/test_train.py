"""Training substrate tests: loss decreases, checkpoint/restore is exact,
optimizer semantics."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, load_all
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.optimizer import adamw_update, init_opt_state

load_all()


def test_adamw_moves_params_and_clips():
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p, jnp.float32) * 100.0, params)
    newp, newopt, gnorm = adamw_update(params, grads, opt, lr=1e-2,
                                       grad_clip=1.0)
    assert float(gnorm) > 1.0                  # clipping engaged
    assert int(newopt.step) == 1
    moved = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(newp),
                                jax.tree_util.tree_leaves(params)))
    assert moved > 0


def test_loss_decreases_on_structured_data():
    from repro.launch.train import train_loop
    _, _, losses = train_loop("micro", steps=20, batch=4, seq=32,
                              lr=2e-3, log_every=100)
    assert losses[-1] < losses[0]


def test_checkpoint_roundtrip_exact():
    cfg = get_config("micro")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 3, params, opt,
                             data_state={"step": 3, "seed": 0})
        ap = lm.abstract_params(cfg)
        from repro.train.optimizer import abstract_opt_state
        step, p2, o2, meta = ckpt.restore_checkpoint(
            d, ap, abstract_opt_state(ap))
        assert step == 3
        assert meta["data_state"]["step"] == 3
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_data_pipeline_deterministic_resume():
    cfg = get_config("micro")
    d1 = SyntheticLM(cfg, batch=2, seq=16, seed=5)
    batches = [d1.next() for _ in range(4)]
    snap = d1.snapshot()
    nxt = d1.next()
    d2 = SyntheticLM(cfg, batch=2, seq=16, seed=5)
    d2.restore(snap)
    nxt2 = d2.next()
    np.testing.assert_array_equal(nxt["tokens"], nxt2["tokens"])
