"""Runtime sanitizer tests: sanitize on/off byte-identity, and injected
double-release / leak corruptions caught at the first event boundary
after they happen, with the owning session and attempt named."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, load_all
from repro.models import lm
from repro.serving.runtime import AgentRequest, ServingRuntime
from repro.serving.sanitizer import SanitizerError

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
TOOLS = ["code_execution", "web_api", "file_operations"]


def _mk_requests(n, n_steps=3, seed=0):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab, size=8))), 4,
                  TOOLS[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def _mk_runtime(sanitize, n=5):
    rt = ServingRuntime(CFG, PARAMS, seed=0, n_workers=2, n_slots=2,
                        max_len=256, pool_blocks=96, sanitize=sanitize)
    for r in _mk_requests(n):
        rt.submit(r)
    return rt


def _advance_until(rt, cond, step=0.05, limit=60.0):
    """Advance the virtual clock in small horizons until ``cond(rt)``
    holds at an event boundary."""
    t = step
    while t < limit:
        rt.run(horizon_s=t)
        if cond(rt):
            return
        t += step
    raise AssertionError("condition never reached")


def test_sanitized_run_is_byte_identical():
    a = _mk_runtime(sanitize=False)
    a.run()
    a.check_conservation()
    b = _mk_runtime(sanitize=True)
    b.run()
    b.check_conservation()
    assert repr(a.summarize()) == repr(b.summarize())
    assert b._san is not None and b._san.events_checked > 0
    assert a._san is None


def test_env_var_gate(monkeypatch):
    monkeypatch.setenv("SAGA_SANITIZE", "1")
    assert ServingRuntime(CFG, PARAMS, n_workers=1, n_slots=2,
                          max_len=256, pool_blocks=48)._san is not None
    monkeypatch.setenv("SAGA_SANITIZE", "0")
    assert ServingRuntime(CFG, PARAMS, n_workers=1, n_slots=2,
                          max_len=256, pool_blocks=48)._san is None
    monkeypatch.delenv("SAGA_SANITIZE")
    assert ServingRuntime(CFG, PARAMS, n_workers=1, n_slots=2,
                          max_len=256, pool_blocks=48)._san is None


def _first_parked(rt):
    # paged pools hold tables for resident (decoding) sessions too;
    # these tests corrupt *parked* state, so skip the resident set
    for w, eng in enumerate(rt.engines):
        for sid in sorted(set(eng.pool.tables) - eng.pool.resident):
            return w, sid
    return None


def test_injected_double_release_caught():
    """Blocks returned to the free list while their table entry lives —
    the state an erroneous extra ``free.extend`` (release without
    clearing the table) produces — fails at the next event, naming the
    owning session and attempt."""
    rt = _mk_runtime(sanitize=True)
    _advance_until(rt, lambda r: _first_parked(r) is not None)
    w, sid = _first_parked(rt)
    rt.engines[w].pool.free.extend(rt.engines[w].pool.tables[sid])
    with pytest.raises(SanitizerError) as ei:
        rt.run()
    msg = str(ei.value)
    assert "double-release" in msg
    assert f"{sid!r}" in msg
    assert f"attempt={rt.sessions[sid].attempt}" in msg
    assert "after event" in msg and f"engine {w}" in msg


def test_injected_block_leak_caught():
    """A session's block table dropped without freeing the blocks —
    they now live in no table and not on the free list — fails at the
    next event instead of end-of-run."""
    rt = _mk_runtime(sanitize=True)
    _advance_until(rt, lambda r: _first_parked(r) is not None)
    w, sid = _first_parked(rt)
    rt.engines[w].pool.tables.pop(sid)
    with pytest.raises(SanitizerError) as ei:
        rt.run()
    msg = str(ei.value)
    assert "leaked" in msg and f"engine {w}" in msg
    assert "after event" in msg


def test_injected_slot_leak_caught():
    """A decode session knocked out of the continuous-batching set
    without its slot being released would decode never again yet hold
    the slot forever — caught at the next event with session/attempt
    named."""
    rt = _mk_runtime(sanitize=True)
    _advance_until(rt, lambda r: any(r._active[w]
                                     for w in range(r.n_workers)))
    w = next(w for w in range(rt.n_workers) if rt._active[w])
    sid = sorted(rt._active[w])[0]
    rt._active[w].discard(sid)
    with pytest.raises(SanitizerError) as ei:
        rt.run()
    msg = str(ei.value)
    assert "decode batch != slot owners" in msg
    assert f"{sid!r}" in msg
    assert f"attempt={rt.sessions[sid].attempt}" in msg


def test_clean_chaos_run_passes_sanitized():
    """Fault injection + recovery under per-event auditing: the
    lifecycle machinery itself must never trip the sanitizer."""
    plan = [(0.3, "fail", 0), (0.8, "recover", 0), (1.1, "slow", 1),
            (1.6, "heal", 1)]
    rt = ServingRuntime(CFG, PARAMS, seed=0, n_workers=2, n_slots=2,
                        max_len=256, pool_blocks=96, fault_plan=plan,
                        sanitize=True)
    for r in _mk_requests(6):
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    assert rt._san.events_checked > 0
