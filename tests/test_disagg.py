"""Disaggregated prefill/decode pool tests: unified-vs-disagg token
identity, chaos conservation with a prefill engine dying mid-handoff,
cross-process byte-identical summaries, and role plumbing."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.disagg import (ROLE_DECODE, ROLE_PREFILL,
                                  default_roles)
from repro.serving.runtime import (AgentRequest, RuntimePerf,
                                   ServingRuntime)

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

TOOLS = ["code_execution", "web_api", "file_operations"]


def _mk_requests(n, n_steps=3, seed=0, prompt_len=8, n_out=4):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab,
                                            size=prompt_len))),
                  n_out, TOOLS[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def _run(reqs, disagg, **kw):
    """Ample-slot config: like ``test_interleaved_matches_serial``, the
    exactness tests run in the regime where no session is ever evicted
    or diverted off its KV home — under overload the policies trade
    regeneration (low-order float bits differ from incrementally-built
    KV) for throughput, which the benchmarks measure, not these gates."""
    kw.setdefault("n_workers", 3)
    kw.setdefault("n_slots", 8)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    kw.setdefault("sanitize", True)
    kw.setdefault("saga", SAGAConfig(disaggregate=disagg))
    rt = ServingRuntime(CFG, PARAMS, seed=0, **kw)
    for r in reqs:
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    rt.verify_pool_mirrors()
    return rt


def test_disagg_matches_unified_tokens():
    """Splitting engines into prefill/decode roles must not change a
    single output token: the staged KV is a delta prefill of the same
    context tokens through the same jitted functions, and the handoff
    copies blocks bit-exactly."""
    reqs = _mk_requests(6)
    uni = _run(_mk_requests(6), disagg=False)
    dis = _run(reqs, disagg=True)
    assert uni.n_done == dis.n_done == len(reqs)
    for r in reqs:
        a = uni.sessions[r.session_id].step_outputs
        b = dis.sessions[r.session_id].step_outputs
        assert a == b, f"outputs diverged for {r.session_id}"
    s = dis.summarize()
    assert s["handoffs"] > 0
    assert s["speculative_prefills"] > 0
    assert s["handoff_bytes"] > 0.0
    assert dis.stats()["kv_handoff_bytes"] > 0
    # the unified summary must not grow disagg keys (fingerprint guard)
    assert "handoffs" not in uni.summarize()
    # prefill engines end empty: staging is transient by construction
    for w in dis._prefill_ids:
        assert not dis.engines[w].pool.tables
        assert not dis.co.pools[w].entries


def test_disagg_chaos_prefill_death_mid_handoff():
    """Killing the prefill engine while jobs are in flight must cancel
    the attempts (stale ``pf_done``/``handoff_done`` events), reclaim
    blocks on both sides, and re-prefill on recovery — with zero leaks
    and token-for-token identical outputs, because the staged KV is a
    pure function of the context tokens."""
    # slow the prefill stream down so the fault window reliably lands
    # while handoff jobs are mid-lifecycle
    perf = RuntimePerf(prefill_tokens_per_s=200.0)
    plan = [(0.2, "fail", 0), (0.9, "recover", 0)]
    reqs = _mk_requests(6, n_steps=4, seed=7)
    calm = _run(_mk_requests(6, n_steps=4, seed=7), disagg=True,
                n_workers=4, perf=perf)
    chaos = _run(reqs, disagg=True, n_workers=4, perf=perf,
                 fault_plan=plan)
    assert chaos.n_done == len(reqs)
    s = chaos.summarize()
    assert s["handoffs_cancelled"] > 0, \
        "fault plan never hit a mid-flight handoff"
    for r in reqs:
        a = calm.sessions[r.session_id].step_outputs
        b = chaos.sessions[r.session_id].step_outputs
        assert a == b, f"outputs diverged for {r.session_id}"
    for w in chaos._prefill_ids:
        assert not chaos.engines[w].pool.tables


def test_disagg_conservation_under_contention():
    """Overloaded disagg cluster (queueing, deferral, stealing on the
    decode side, preemption enabled) conserves at every event — the
    sanitizer audits the cross-pool in-transit state after each one."""
    perf = RuntimePerf(prefill_tokens_per_s=500.0,
                       prefill_round_interference=0.15)
    saga = SAGAConfig(disaggregate=True, enable_preemption=True)
    rt = _run(_mk_requests(10, n_steps=4, seed=3), disagg=True,
              n_workers=4, n_slots=2, pool_blocks=64, saga=saga,
              perf=perf,
              fault_plan=[(0.15, "fail", 0), (0.3, "fail", 2),
                          (0.7, "recover", 0), (0.9, "scale_up", 0),
                          (1.2, "recover", 2)])
    assert rt.n_done == 10
    assert rt.summarize()["handoffs"] > 0


def test_role_validation():
    with pytest.raises(ValueError):
        default_roles(1)
    # prefill roles without the config flag are a misconfiguration
    with pytest.raises(ValueError):
        ServingRuntime(CFG, PARAMS, n_workers=2, n_slots=2,
                       max_len=256, pool_blocks=32,
                       roles=[ROLE_PREFILL, ROLE_DECODE])
    # an all-prefill cluster has nowhere to decode
    with pytest.raises(ValueError):
        ServingRuntime(CFG, PARAMS, n_workers=2, n_slots=2,
                       max_len=256, pool_blocks=32,
                       saga=SAGAConfig(disaggregate=True),
                       roles=[ROLE_PREFILL, ROLE_PREFILL])


_RUN_SNIPPET = """
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.cluster.workload import runtime_requests
from repro.models import lm
from repro.serving.runtime import ServingRuntime
import jax
load_all()
cfg = get_config("micro")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rt = ServingRuntime(cfg, params, n_workers=3, n_slots=3, max_len=256,
                    pool_blocks=96, seed=0,
                    saga=SAGAConfig(disaggregate=True))
for r in runtime_requests(n_sessions=5, vocab=cfg.vocab, seed=4,
                          n_steps=2, max_ctx=200):
    rt.submit(r)
rt.run()
rt.check_conservation()
print(repr(rt.summarize()))
"""


def test_disagg_summary_identical_across_processes():
    """Disaggregated runs inherit the determinism contract: handoff
    scheduling, placement and transfer windows are RNG- and hash-order
    free, so summaries are byte-identical across PYTHONHASHSEED."""
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _RUN_SNIPPET],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert "'handoffs':" in outs[0] and "'n_done': 5" in outs[0]
