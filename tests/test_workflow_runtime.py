"""AgentProgram API on the real-inference serving runtime: adapter
byte-identity, branching (retry-edge) execution with delta-only resume,
dynamic callbacks over real decoded tokens, WorkflowHandle, and
cross-substrate path identity."""
import jax
import numpy as np
import pytest

from repro.cluster.workload import runtime_programs, runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.runtime import (AgentRequest, ServingRuntime,
                                   WorkflowHandle)
from repro.workflow import AgentProgram, StepSpec

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))

# captured BEFORE the AgentProgram redesign (commit be4899f): the
# runtime summary depends only on token COUNTS and the virtual clock,
# never on model output values, so these bytes are platform-stable
GOLDEN_SAGA_RT = (
    "{'n_sessions': 5, 'n_done': 5, 'tct_mean': 1.6161618389241164, "
    "'tct_p50': 0.33992874794463335, 'tct_p99': 4.720808438089012, "
    "'makespan': 5.501518963220529, 'prefill_tokens': 460, "
    "'regen_tokens': 323, 'decode_rounds': 20, 'decoded_tokens': 25, "
    "'cache_hits': 5, 'cache_misses': 5, 'steals': 0, 'migrations': 0, "
    "'prefetch_issued': 0, 'prefetch_correct': 0, 'prefetch_copies': 0, "
    "'prefetch_wasted_bytes': 0.0}")

RT_NODES = {0: StepSpec("code_execution", 12, 3, tool_latency_s=0.1),
            1: StepSpec("file_operations", 8, 2, tool_latency_s=0.05),
            2: StepSpec("code_execution", 6, 2, tool_latency_s=0.1),
            3: StepSpec("database_query", 6, 2, tool_latency_s=0.05)}
RT_EDGES = [(0, 1, 0.97), (1, 2, 0.97), (2, 1, 0.45), (2, 3, 0.52)]


def _rt(**kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    return ServingRuntime(CFG, PARAMS, seed=0, **kw)


def _graph_prog(i, seed=None):
    return AgentProgram.graph(f"wf{i}", f"t{i % 2}", RT_NODES, RT_EDGES,
                              seed=i if seed is None else seed,
                              max_steps=12)


def _took_retry(path):
    return any(b <= a for a, b in zip(path, path[1:]))


def test_golden_runtime_summary_unchanged():
    rt = _rt()
    for r in runtime_requests(n_sessions=5, vocab=CFG.vocab, seed=4,
                              n_steps=2, max_ctx=200):
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    assert repr(rt.summarize()) == GOLDEN_SAGA_RT


def test_request_vs_scripted_program_byte_identical():
    """Submitting an AgentRequest and its compiled scripted program must
    be indistinguishable down to the summary bytes."""
    reqs = runtime_requests(n_sessions=6, vocab=CFG.vocab, seed=9,
                            n_steps=3, max_ctx=200)
    rt_a = _rt()
    for r in reqs:
        rt_a.submit(r)
    rt_a.run()
    rt_a.check_conservation()
    rt_b = _rt()
    for r in reqs:
        rt_b.submit(AgentProgram.from_request(r))
    rt_b.run()
    rt_b.check_conservation()
    assert repr(rt_a.summarize()) == repr(rt_b.summarize())
    for r in reqs:
        assert rt_a.sessions[r.session_id].step_outputs == \
            rt_b.sessions[r.session_id].step_outputs


def test_branching_program_retry_with_delta_resume():
    """A taken retry edge re-executes its node on the runtime; the
    resumed steps hit the parked KV and prefill only the delta."""
    rt = _rt()
    handles = [rt.submit(_graph_prog(i)) for i in range(6)]
    rt.run()
    rt.check_conservation()
    assert all(h.done for h in handles)
    retried = [h for h in handles if _took_retry(h.path)]
    assert retried, "no retry edge taken in the seed pool"
    s = rt.summarize()
    assert s["cache_hits"] > 0                    # delta-only resumes
    assert s["regen_tokens"] < s["prefill_tokens"]
    for h in handles:                   # one output list per taken step
        assert len(h.step_outputs) == len(h.path)


def test_branching_program_runtime_deterministic():
    outs = []
    for _ in range(2):
        rt = _rt()
        hs = [rt.submit(_graph_prog(i)) for i in range(6)]
        rt.run()
        outs.append((repr(rt.summarize()), [h.path for h in hs],
                     [h.step_outputs for h in hs]))
    assert outs[0] == outs[1]


def test_same_program_same_path_on_both_substrates():
    """The acceptance contract: ONE branching spec, identical taken
    paths on the simulator and the serving runtime (edge draws come
    from the path stream only, so realization differences — token ids,
    latencies — never skew the branch structure)."""
    from repro.cluster import baselines as B
    from repro.cluster.simulator import ClusterSim

    progs = [_graph_prog(i) for i in range(6)]
    sim = ClusterSim([_graph_prog(i) for i in range(6)], B.saga(),
                     n_workers=2, seed=0)
    sim.run(horizon_s=36000)
    sim.check_conservation()
    rt = _rt()
    handles = [rt.submit(p) for p in progs]
    rt.run()
    rt.check_conservation()
    for p, h in zip(progs, handles):
        assert sim.tasks[p.program_id].path == h.path
    assert any(_took_retry(h.path) for h in handles)


def test_dynamic_program_decides_from_real_tokens():
    """The dynamic callback branches on the actual decoded token ids —
    the tier-b/c path where the client, not a script, drives the
    workflow."""
    decisions = []

    def cb(ctx):
        if ctx.step_idx < 0:
            return StepSpec("code_execution", prompt_ids=[5, 6, 7, 8],
                            n_out=2, tool_latency_s=0.05)
        if ctx.step_idx >= 3:
            return None
        last = ctx.outputs[-1][-1]          # real decoded token id
        tool = "web_api" if last % 2 == 0 else "file_operations"
        decisions.append(tool)
        return StepSpec(tool, prompt_ids=[(last % 50) + 1] * 4, n_out=2,
                        tool_latency_s=0.05)

    rt = _rt()
    h = rt.submit(AgentProgram.dynamic("dyn0", "t0", cb,
                                       planned_tools=["code_execution"]))
    outs = h.result()
    rt.check_conservation()
    assert h.done and len(outs) == 4
    assert len(decisions) == 3
    # replay: identical model + seed -> identical decisions
    decisions2 = []

    def cb2(ctx):
        if ctx.step_idx < 0:
            return StepSpec("code_execution", prompt_ids=[5, 6, 7, 8],
                            n_out=2, tool_latency_s=0.05)
        if ctx.step_idx >= 3:
            return None
        last = ctx.outputs[-1][-1]
        tool = "web_api" if last % 2 == 0 else "file_operations"
        decisions2.append(tool)
        return StepSpec(tool, prompt_ids=[(last % 50) + 1] * 4, n_out=2,
                        tool_latency_s=0.05)

    rt2 = _rt()
    h2 = rt2.submit(AgentProgram.dynamic("dyn0", "t0", cb2,
                                         planned_tools=["code_execution"]))
    assert h2.result() == outs
    assert decisions2 == decisions


def test_workflow_handle_api():
    rt = _rt()
    h = rt.submit(_graph_prog(0))
    assert isinstance(h, WorkflowHandle)
    assert h.status == "new" and not h.done
    with pytest.raises(RuntimeError):
        _ = h.tct
    outs = h.result()
    assert h.done and h.status == "done"
    assert h.tct >= 0.0
    assert outs == h.step_outputs and len(outs) == len(h.path)


def test_generated_runtime_programs_conserve():
    rt = _rt(n_slots=3, pool_blocks=128)
    handles = [rt.submit(p) for p in runtime_programs(n_sessions=6,
                                                      seed=1)]
    rt.run()
    rt.check_conservation()
    rt.verify_pool_mirrors()
    assert all(h.done for h in handles)


def test_program_too_big_for_engine_rejected():
    big = AgentProgram.graph(
        "big", "t", {0: StepSpec("web_api", 4000, 8)}, [], max_steps=4)
    rt = _rt()
    with pytest.raises(ValueError):
        rt.submit(big)


def test_context_cap_truncation_is_flagged():
    """A graph that outgrows the engine context ends early with
    ``truncated=True`` (the taken path is a prefix of the unconstrained
    one, so cross-substrate path identity is explicitly off)."""
    nodes = {0: StepSpec("web_api", 40, 8, tool_latency_s=0.05)}
    loop = AgentProgram.graph("looper", "t", nodes, [(0, 0, 1.0)],
                              max_steps=30)
    rt = _rt()
    h = rt.submit(loop)
    h.result()
    rt.check_conservation()
    assert h.done and h.truncated
    assert len(h.path) < 30
    unconstrained = loop.instantiate()
    i = 0
    while unconstrained.resolve_next(i) is not None:
        i += 1
    assert unconstrained.path[:len(h.path)] == h.path  # strict prefix


def test_cluster_task_runs_on_runtime():
    """A cluster-sim Task submits to the runtime: token ids are realized
    from the adapter's seed, oversized tails truncate (flagged) instead
    of crashing mid-event-loop."""
    from repro.cluster.workload import Step, Task
    steps = [Step(12.0, 3.0, "code_execution", 6.0, 0.1),
             Step(8.0, 2.0, "file_operations", 4.0, 0.05),
             Step(2000.0, 40.0, "web_api", 10.0, 0.05)]  # won't fit
    task = Task("clu-task", "t0", "swebench", 0.0, steps,
                prefix_tokens=0.0)
    rt = _rt()
    h = rt.submit(task)
    outs = h.result()
    rt.check_conservation()
    assert h.done and h.truncated and len(outs) == 2
