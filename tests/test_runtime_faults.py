"""Fault-tolerant serving runtime: engine fault injection through the
attempt-stamped in-flight registry (cancellation, orphan buffer,
scale-out) and AFS preemption of running decodes (mid-step park with
delta-only resume), with the simulator's conservation and determinism
contracts upheld on real engines."""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.cluster.faults import (chaos_plan, preemption_storm_plan,
                                  straggler_plan)
from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.core.prefetch import SpeculativePrefetcher
from repro.core.aeg import AEG
from repro.models import lm
from repro.serving.runtime import AgentRequest, ServingRuntime

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _rt(saga=None, fault_plan=None, **kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    return ServingRuntime(CFG, PARAMS, seed=0, saga=saga,
                          fault_plan=fault_plan, **kw)


def _trace_reqs(n=8, seed=4, n_steps=3):
    return runtime_requests(n_sessions=n, vocab=CFG.vocab, seed=seed,
                            n_steps=n_steps, max_ctx=200)


def _steps(rng, n_prompt, n_out, tool="code_execution", gap=0.05,
           n_steps=1):
    return [(list(map(int, rng.randint(1, CFG.vocab, n_prompt))), n_out,
             tool, gap) for _ in range(n_steps)]


# -- engine fault injection ---------------------------------------------

def test_chaos_conservation_on_real_engines():
    """The CI-facing property: under a randomized fail/recover/scale-up
    plan, every session still finishes and no slot, block, queue entry,
    or in-flight attempt leaks — the simulator's conservation contract
    on actual engines."""
    rt = _rt(fault_plan=chaos_plan(2, 8.0, n_events=10, seed=1))
    for r in _trace_reqs():
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    rt.verify_pool_mirrors()
    s = rt.summarize()
    assert s["n_done"] == 8
    assert s["faults_injected"] >= 1
    # chaos costs regeneration vs the same run without faults
    clean = _rt()
    for r in _trace_reqs():
        clean.submit(r)
    clean.run()
    clean.check_conservation()
    assert s["regen_tokens"] > clean.summarize()["regen_tokens"]


def test_storm_and_straggler_plans_drive_runtime():
    """The simulator's other fault plans are reused verbatim on the
    serving substrate."""
    for plan in (preemption_storm_plan(2, 8.0, n_storms=2,
                                       downtime_s=1.0, seed=2),
                 straggler_plan(2, 8.0, n_stragglers=1, slow_for_s=2.0,
                                seed=3)):
        rt = _rt(fault_plan=plan)
        for r in _trace_reqs(n=6):
            rt.submit(r)
        rt.run()
        rt.check_conservation()
        assert rt.n_done == 6


def test_straggler_slows_virtual_service():
    """A slow engine's decode rounds dilate on the virtual clock, so a
    permanently-slowed single-engine run must finish strictly later."""
    rng = np.random.RandomState(0)
    reqs = _steps(rng, 8, 40)
    fast = _rt(n_workers=1)
    fast.submit(AgentRequest("a", "t0", list(reqs)))
    fast.run()
    slow = _rt(n_workers=1, fault_plan=[(0.0, "slow", 0)])
    slow.submit(AgentRequest("a", "t0", list(reqs)))
    slow.run()
    slow.check_conservation()
    assert slow.sessions["a"].tct > fast.sessions["a"].tct * 2.0


def test_fail_cancels_inflight_attempt_and_retries_identically():
    """Kill the only engine mid-decode: the attempt is cancelled via the
    registry (the stale prefill_done/round events are dropped), the
    context rolls back to the step start, the session parks in the
    orphan buffer, and after recovery the retried step re-prefills the
    same prompt — so its outputs are token-for-token identical to a
    fault-free run."""
    rng = np.random.RandomState(1)
    steps = _steps(rng, 8, 40)
    clean = _rt(n_workers=1)
    clean.submit(AgentRequest("a", "t0", list(steps)))
    clean.run()

    rt = _rt(n_workers=1,
             fault_plan=[(0.5, "fail", 0), (0.8, "recover", 0)])
    rt.submit(AgentRequest("a", "t0", list(steps)))
    rt.run()
    rt.check_conservation()
    s = rt.summarize()
    assert s["cancelled_attempts"] == 1 and s["faults_injected"] == 1
    assert rt.sessions["a"].step_outputs == clean.sessions["a"].step_outputs
    assert len(rt.sessions["a"].step_outputs[0]) == 40
    # the retry regenerated (fresh prefill of the same prompt)
    assert s["regen_tokens"] > clean.summarize()["regen_tokens"]
    assert rt.sessions["a"].tct > clean.sessions["a"].tct


def test_all_engines_dead_strands_sessions_visibly():
    """With every engine down and nothing scheduled to revive one, the
    run must terminate (no infinite epoch ticking) and conservation must
    report the stranded sessions rather than pass silently."""
    rt = _rt(fault_plan=[(0.01, "fail", 0), (0.01, "fail", 1)])
    rng = np.random.RandomState(2)
    rt.submit(AgentRequest("a", "t0", _steps(rng, 8, 4)))
    rt.run()
    assert rt.n_done == 0
    try:
        rt.check_conservation()
    except RuntimeError as e:
        assert "never finished" in str(e)
    else:
        raise AssertionError("conservation passed on a stranded session")


def test_orphans_readmitted_on_recover_and_scale_up():
    """Kill both engines mid-run; sessions orphan, then a recover and an
    elastic scale-up each readmit them.  Everything finishes and the new
    engine participates."""
    plan = [(0.2, "fail", 0), (0.2, "fail", 1),
            (0.6, "recover", 0), (0.9, "scale_up", 0)]
    rt = _rt(fault_plan=plan)
    for r in _trace_reqs(n=6):
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    assert rt.n_done == 6
    assert rt.n_workers == 3 and len(rt.engines) == 3
    assert rt.summarize()["faults_injected"] == 2


def test_prefetch_jobs_cancelled_when_source_engine_dies():
    """An in-flight replication sourced from a dead engine can never
    land: it must be cancelled and its bytes counted as waste (only
    supersession used to cancel jobs)."""
    p = SpeculativePrefetcher(bandwidth_Bps=1e9)
    aeg = AEG.linear_chain(["code_execution", "web_api"])
    assert p.maybe_issue("s0", aeg, 0, 100.0, 0.0, 0.0,
                         worker=0) is not None
    assert p.maybe_issue("s1", aeg, 0, 40.0, 0.0, 0.0,
                         worker=1) is not None
    assert p.cancel_worker(1) == 1
    assert p.wasted_bytes == 40.0
    assert "s1" not in p.inflight and "s0" in p.inflight
    assert p.cancel_worker(1) == 0           # idempotent
    # runtime path: a fail event cancels the coordinator's jobs too
    rt = _rt(fault_plan=[(0.05, "fail", 0), (0.3, "recover", 0)])
    for r in _trace_reqs(n=6):
        rt.submit(r)
    rt.run()
    rt.check_conservation()


# -- AFS preemption of running decodes ----------------------------------

def _starvation_runtime(preempt, deficit=0.0):
    """One engine / two slots; a hog tenant's two long decodes occupy
    both slots before a starved tenant's higher-aggregate-demand burst
    arrives."""
    saga = SAGAConfig(enable_preemption=preempt, preempt_deficit=deficit)
    rt = _rt(n_workers=1, saga=saga)
    rng = np.random.RandomState(3)
    hog_steps = [_steps(rng, 8, 150) for _ in range(2)]
    st_steps = [_steps(rng, 6, 40, tool="web_api") for _ in range(8)]
    for i, st in enumerate(hog_steps):
        rt.submit(AgentRequest(f"hog{i}", "hogT", st))
    for i, st in enumerate(st_steps):
        rt.submit(AgentRequest(f"st{i}", "stT", st, arrival_s=0.2))
    rt.run()
    rt.check_conservation()
    rt.verify_pool_mirrors()
    return rt, hog_steps


def test_preemption_parks_running_decode_and_bounds_deviation():
    """With preemption enabled the starved tenant is admitted into a
    preempted slot: preemptions fire, the starved tenant's mean TCT
    improves, and the Thm. 2 max fair-share deviation is strictly
    tighter than admission-only ordering."""
    base, _ = _starvation_runtime(False)
    pre, _ = _starvation_runtime(True)
    assert base.preempted == 0
    assert pre.preempted >= 1
    assert pre.co.afs.preemptions >= 1
    st_mean = lambda rt: sum(rt.sessions[f"st{i}"].tct
                             for i in range(8)) / 8
    assert st_mean(pre) < st_mean(base)
    assert pre.afs_dev_max < base.afs_dev_max
    s = pre.summarize()
    assert s["preemptions"] == pre.preempted
    assert s["afs_dev_max"] == pre.afs_dev_max


def test_preempted_then_resumed_outputs_token_identical():
    """A preempted decode resumes from its parked KV mid-step: its
    outputs must be token-for-token identical to an uncontended run
    (the pool is sized so the parked copy survives)."""
    pre, hog_steps = _starvation_runtime(True)
    solo = _rt(n_workers=1)
    for i, st in enumerate(hog_steps):
        solo.submit(AgentRequest(f"hog{i}", "hogT", st))
    solo.run()
    for i in range(2):
        assert pre.sessions[f"hog{i}"].step_outputs == \
            solo.sessions[f"hog{i}"].step_outputs
    # the resume was delta-only: total regeneration is exactly the
    # first-admission prompt prefills (2 hogs x 8 + 8 starved x 6) —
    # every preempted hog's decoded prefix came back from the pool
    assert pre.summarize()["regen_tokens"] == 2 * 8 + 8 * 6


def test_preempt_deficit_threshold_gates_preemption():
    """An impossible deficit threshold must disable preemption entirely
    (the hysteresis knob is honored)."""
    rt, _ = _starvation_runtime(True, deficit=1e9)
    assert rt.preempted == 0


def test_fail_while_preempted_mid_step_regenerates_and_finishes():
    """The engine dies while a preempted victim waits in the queue
    mid-step: its parked prefix is lost, and on recovery it regenerates
    the whole context (decoded prefix included, §3.1) and still
    completes the interrupted step's full token budget."""
    saga = SAGAConfig(enable_preemption=True)
    rt = _rt(n_workers=1, saga=saga,
             fault_plan=[(1.2, "fail", 0), (1.5, "recover", 0)])
    rng = np.random.RandomState(3)
    hog_steps = [_steps(rng, 8, 150) for _ in range(2)]
    st_steps = [_steps(rng, 6, 40, tool="web_api") for _ in range(8)]
    for i, st in enumerate(hog_steps):
        rt.submit(AgentRequest(f"hog{i}", "hogT", st))
    for i, st in enumerate(st_steps):
        rt.submit(AgentRequest(f"st{i}", "stT", st, arrival_s=0.2))
    rt.run()
    rt.check_conservation()
    assert rt.n_done == 10
    assert rt.preempted >= 1
    assert rt.summarize()["faults_injected"] == 1
    for i in range(2):
        assert len(rt.sessions[f"hog{i}"].step_outputs[0]) == 150


# -- determinism under faults + preemption ------------------------------

_RUN_SNIPPET = """
from repro.cluster.faults import chaos_plan
from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.coordinator import SAGAConfig
from repro.models import lm
from repro.serving.runtime import ServingRuntime
import jax
load_all()
cfg = get_config("micro")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
saga = SAGAConfig(enable_preemption=True)
rt = ServingRuntime(cfg, params, n_workers=2, n_slots=2, max_len=256,
                    pool_blocks=96, seed=0, saga=saga,
                    fault_plan=chaos_plan(2, 8.0, n_events=8, seed=1))
for r in runtime_requests(n_sessions=6, vocab=cfg.vocab, seed=4,
                          n_steps=2, max_ctx=200):
    rt.submit(r)
rt.run()
rt.check_conservation()
print(repr(rt.summarize()))
"""


def test_fault_preemption_summary_identical_across_processes():
    """Identical-seed dual runs with chaos faults AND preemption enabled
    stay byte-identical across processes with different PYTHONHASHSEED —
    the determinism contract extends to the fault/preemption paths."""
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _RUN_SNIPPET],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert "'n_done': 6" in outs[0]
    assert "afs_dev_max" in outs[0]     # fault-mode keys present
