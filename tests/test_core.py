"""Unit tests for SAGA's core mechanisms (paper equations + algorithms)."""
import math

import pytest

from repro.core.aeg import AEG, PatternInferencer, ToolStats
from repro.core.affinity import SessionRouter
from repro.core.afs import AFSScheduler, TaskProgress
from repro.core.belady import Access, BeladyOracle, competitive_ratio, \
    replay_policy
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.core.prefetch import SpeculativePrefetcher
from repro.core.stealing import WorkStealer
from repro.core.ttl import ToolTTLPolicy, fit_lognormal, memory_pressure, \
    percentile
from repro.core.walru import CacheEntry, EvictionWeights, LRUCache, \
    WALRUCache


# --- AEG (Eq. 4-5) ----------------------------------------------------------
def test_aeg_linear_chain_structure():
    aeg = AEG.linear_chain(["a", "b", "c"], p_term=0.1)
    assert aeg.most_likely_successor(0) == 1
    assert aeg.successors(0)[0][1] == pytest.approx(0.9)
    assert aeg.successors(2) == []          # chain end


def test_overlap_eq5():
    aeg = AEG.linear_chain(["code_execution"] * 3)
    stats = ToolStats()
    stats.observe("code_execution", 500, 0.2)
    # overlap = n_cur / (n_cur + E[n_obs])
    assert aeg.overlap(1000, 1, stats) == pytest.approx(1000 / 1500)
    assert aeg.overlap(0, 1, stats) == 0.0


def test_p_reuse_monotone_in_context():
    aeg = AEG.linear_chain(["a"] * 4, p_term=0.05)
    stats = ToolStats()
    stats.observe("a", 400, 0.1)
    assert aeg.p_reuse(0, 8000, stats) > aeg.p_reuse(0, 500, stats)
    assert 0.0 <= aeg.p_reuse(0, 8000, stats) <= 1.0


def test_retry_edges():
    aeg = AEG.linear_chain(["a", "b", "c"], p_term=0.0,
                           retry_probs={1: 0.3})
    succs = dict(aeg.successors(1))
    assert succs[2] == pytest.approx(0.7)
    assert succs[0] == pytest.approx(0.3)


# --- pattern inference (§3.3) -----------------------------------------------
def test_pattern_inference_cold_start():
    inf = PatternInferencer(min_tasks=5)
    for _ in range(4):
        inf.record_trace(["a", "b"])
    assert not inf.warm
    assert inf.infer("a") is None           # tier (c) fallback
    inf.record_trace(["a", "b"])
    assert inf.warm
    assert inf.infer("a") is not None


def test_pattern_inference_accuracy():
    inf = PatternInferencer(min_tasks=1)
    for _ in range(20):
        inf.record_trace(["a", "b", "a", "b"])
    assert inf.predict_next("a") == "b"
    acc = inf.accuracy([["a", "b", "a", "b"]])
    assert acc >= 0.75


# --- WA-LRU (Eq. 1-3) ---------------------------------------------------------
def _entry(sid, size, t_last, **kw):
    return CacheEntry(session_id=sid, size_bytes=size, t_last=t_last, **kw)


def test_p_evict_weights():
    c = WALRUCache(100.0, EvictionWeights(0.3, 0.5, 0.2),
                   p_reuse_fn=lambda e: 1.0)
    e = _entry("s", 50.0, 0.0)
    # full reuse, max recency, half size
    v = c.p_evict(e, now=10.0, tau_max=10.0, size_max=100.0)
    assert v == pytest.approx(0.3 * 1.0 + 0.5 * 0.0 + 0.2 * 0.5)


def test_walru_prefers_evicting_completed_sessions():
    c = WALRUCache(100.0, p_reuse_fn=lambda e: 0.9)
    c.insert(_entry("active", 50.0, 9.0), now=9.0)
    done = _entry("done", 50.0, 9.5, completed=True)
    c.insert(done, now=9.5)
    victim = c.select_victim(now=10.0)
    assert victim.session_id == "done"      # despite being more recent


def test_walru_ttl_expiry_drops_reuse_bonus():
    c = WALRUCache(100.0, p_reuse_fn=lambda e: 0.95)
    fresh = _entry("fresh", 50.0, 0.0, ttl_deadline=100.0)
    expired = _entry("expired", 50.0, 5.0, ttl_deadline=6.0)
    c.insert(fresh, now=0.0)
    c.insert(expired, now=5.0)
    assert c.select_victim(now=50.0).session_id == "expired"


def test_capacity_invariant():
    c = WALRUCache(100.0)
    for i in range(10):
        c.insert(_entry(f"s{i}", 30.0, float(i)), now=float(i))
        assert c.used <= 100.0


def test_lru_baseline_evicts_oldest():
    c = LRUCache(100.0)
    c.insert(_entry("old", 40.0, 0.0), now=0.0)
    c.insert(_entry("new", 40.0, 5.0), now=5.0)
    assert c.select_victim(10.0).session_id == "old"


# --- TTL (Algorithm 1 / Eq. 6) -------------------------------------------------
def test_memory_pressure_eq6():
    assert memory_pressure(0.5) == 0.0
    assert memory_pressure(0.7) == 0.0
    assert memory_pressure(0.8) == pytest.approx(0.5)
    assert memory_pressure(0.95) == 1.0


def test_ttl_percentile_and_cap():
    pol = ToolTTLPolicy(p=95.0, ttl_max_s=300.0)
    for v in [1.0] * 95 + [1000.0] * 5:
        pol.observe("t", v)
    assert pol.ttl("t", mem_pressure=0.0) <= 300.0   # TTL_max cap
    for v in [1.0] * 100:
        pol.observe("u", v)
    assert pol.ttl("u", 0.0) == pytest.approx(1.0)
    # pressure scaling: factor 1 - 0.5*m
    assert pol.ttl("u", 1.0) == pytest.approx(0.5)


def test_lognormal_fit():
    import random
    rng = random.Random(0)
    xs = [math.exp(1.0 + 0.5 * rng.gauss(0, 1)) for _ in range(2000)]
    mu, sigma = fit_lognormal(xs)
    assert abs(mu - 1.0) < 0.05
    assert abs(sigma - 0.5) < 0.05


# --- affinity routing (Eq. 7) ---------------------------------------------------
def test_eq7_routes_home_when_cached_and_underloaded():
    r = SessionRouter(theta=0.8)
    r.set_home("s", 2)
    w = r.route("s", [0.9, 0.5, 0.5], cached=lambda w, s: w == 2)
    assert w == 2


def test_eq7_falls_back_when_overloaded():
    r = SessionRouter(theta=0.8)
    r.set_home("s", 2)
    w = r.route("s", [0.3, 0.5, 0.9], cached=lambda w, s: w == 2)
    assert w == 0                            # least-loaded fallback


def test_eq7_falls_back_when_not_cached():
    r = SessionRouter(theta=0.8)
    r.set_home("s", 2)
    w = r.route("s", [0.5, 0.2, 0.1], cached=lambda w, s: False)
    assert w == 2 or w == 1  # least-loaded (2 is least but not cached)
    assert w == 2  # loads[2]=0.1 is least loaded -> re-homed there


# --- work stealing (§5.2) ---------------------------------------------------------
def test_steal_requires_both_conditions():
    ws = WorkStealer(t_idle_s=0.1, r_max=2.0)
    ws.note_queue_state(0, empty=True, now=0.0)
    # idle long enough but no overloaded victim
    assert ws.maybe_steal(0.2, [0.0, 0.1], [[], []]) is None
    # overloaded victim exists now
    q = [(0.0, "sess")]
    d = ws.maybe_steal(0.2, [0.0, 1.0], [[], q])
    assert d is not None and d.victim == 1 and d.session_id == "sess"


def test_steal_cooldown_prevents_thrash():
    ws = WorkStealer(t_idle_s=0.0, migration_cooldown_s=10.0)
    ws.note_queue_state(0, True, 0.0)
    d1 = ws.maybe_steal(0.5, [0.0, 1.0], [[], [(0.0, "s")]])
    assert d1 is not None
    ws.note_queue_state(0, True, 0.6)
    d2 = ws.maybe_steal(1.0, [0.0, 1.0], [[], [(0.0, "s")]])
    assert d2 is None                        # safeguard (b)


def test_stale_steal_rejected():
    ws = WorkStealer()
    from repro.core.stealing import StealDecision
    assert not ws.accept(StealDecision(0, 1, "s"), victim_queue_len=0,
                         now=1.0)            # safeguard (c)


# --- AFS (Eq. 8-9, Thm 2) -----------------------------------------------------------
def test_afs_prioritizes_urgent_tenants():
    afs = AFSScheduler()
    afs.add_task(TaskProgress("t1", "urgent", deadline=10.0,
                              work_remain_s=9.0))
    afs.add_task(TaskProgress("t2", "lazy", deadline=1000.0,
                              work_remain_s=9.0))
    shares = afs.recompute(now=0.0)
    assert shares["urgent"] > shares["lazy"]
    assert sum(shares.values()) == pytest.approx(1.0)


def test_afs_preemption_rules():
    afs = AFSScheduler(preempt_block_s=0.5)
    afs.add_task(TaskProgress("hi", "a", deadline=5.0, work_remain_s=4.0))
    afs.add_task(TaskProgress("lo", "b", deadline=500.0, work_remain_s=1.0))
    afs.recompute(0.0)
    afs.note_blocked("hi", now=0.0)
    assert not afs.should_preempt("hi", "lo", now=0.3)   # too soon
    assert afs.should_preempt("hi", "lo", now=0.6)


def test_afs_restoring_drift():
    """Thm 2's negative drift: an underserved tenant's share rises."""
    afs = AFSScheduler()
    afs.add_task(TaskProgress("t1", "behind", deadline=100.0,
                              work_remain_s=50.0))
    afs.add_task(TaskProgress("t2", "ahead", deadline=100.0,
                              work_remain_s=50.0))
    s0 = afs.recompute(0.0)
    # 'ahead' receives service; 'behind' does not
    afs.note_progress("t2", 30.0)
    s1 = afs.recompute(10.0)
    assert s1["behind"] > s1["ahead"]
    assert s1["behind"] > s0["behind"] - 1e-9


# --- prefetch (§4.3) -----------------------------------------------------------------
def test_prefetch_argmax_successor_and_accounting():
    pf = SpeculativePrefetcher(bandwidth_Bps=1e9)
    aeg = AEG.linear_chain(["a", "b", "c"])
    job = pf.maybe_issue("s", aeg, 0, 1e9, now=0.0, pool_used_frac=0.2)
    assert job is not None and job.node_id == 1
    assert job.ready_at == pytest.approx(1.0)
    # resolved after ready and correct -> absorbed
    assert pf.resolve("s", actual_node=1, now=2.0)
    assert pf.correct == 1


def test_prefetch_skips_under_pressure():
    pf = SpeculativePrefetcher()
    aeg = AEG.linear_chain(["a", "b"])
    assert pf.maybe_issue("s", aeg, 0, 1e9, 0.0, pool_used_frac=0.97) is None


# --- coordinator fault tolerance --------------------------------------------------------
def test_worker_failure_drops_cache_and_affinity():
    co = GlobalCoordinator(SAGAConfig(), 3, 1e9)
    co.register_task("s", "t", ["a"] * 3, 100.0, 10.0, 0.0)
    w = co.route("s", [0.1, 0.1, 0.1], 0.0)
    co.on_step_start("s", w, 100, 0.0)
    co.on_step_end("s", w, 200, 1000.0, "a", 1.0)
    assert co.pools[w].contains("s")
    lost = co.worker_failed(w)
    assert "s" in lost
    assert not co.pools[w].contains("s")
    w2 = co.route("s", [0.1, 0.1, 0.1], 2.0)
    assert w2 != w or co.alive[w]            # routed to a live worker


def test_coordinator_snapshot_restore_roundtrip():
    co = GlobalCoordinator(SAGAConfig(), 2, 1e9)
    co.register_task("s", "t", ["a", "b"], 100.0, 10.0, 0.0)
    co.on_step_end("s", 0, 200, 1000.0, "a", 1.0)
    co.ttl.observe("a", 0.5)
    snap = co.snapshot()
    co2 = GlobalCoordinator(SAGAConfig(), 2, 1e9)
    co2.restore(snap)
    assert "s" in co2.sessions
    assert co2.sessions["s"].node_id == co.sessions["s"].node_id
    assert co2.ttl.hist["a"] == co.ttl.hist["a"]


def test_elastic_add_worker():
    co = GlobalCoordinator(SAGAConfig(), 2, 1e9)
    w = co.add_worker()
    assert w == 2 and len(co.pools) == 3 and co.alive[2]
