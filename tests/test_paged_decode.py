"""True paged decode: serial-vs-paged token identity per architecture
family, block-lifecycle property tests, the multi-layer fused
append+attend kernel entry, and runtime-level paged-vs-gather
byte-identity with zero park/resume device copies.

The gather path (``Engine(paged=False)``) is the reference oracle: both
modes share prefill and policy arithmetic, and the masked paged
attention is constructed to be bit-identical, so token ids must match
exactly — not approximately."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, load_all, llava_next_34b, \
    mixtral_8x22b
from repro.kernels.paged_attention import ops
from repro.kernels.paged_attention.ref import paged_decode_ref
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.kvcache import PagedKVPool
from repro.serving.runtime import AgentRequest, ServingRuntime

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))


# --- serial-vs-paged token identity, per decoder-only family ---------------
def _identity_roundtrip(cfg, params, prompt, n_first=5, n_rest=3):
    """Paged engine with a park/resume in the middle must emit the same
    token ids as an uninterrupted gather-mode decode."""
    eg = Engine(cfg, params, n_slots=2, max_len=64, pool_blocks=16,
                paged=False)
    sg = eg.start_session("x", prompt, cached_hit=False)
    ref = eg.decode({sg: int(prompt[-1])}, n_steps=n_first + n_rest)[sg]

    ep = Engine(cfg, params, n_slots=2, max_len=64, pool_blocks=16,
                paged=True)
    sp = ep.start_session("x", prompt, cached_hit=False)
    first = ep.decode({sp: int(prompt[-1])}, n_steps=n_first)[sp]
    assert ep.park_session("x")
    ctx = np.concatenate([prompt, np.asarray(first, np.int32)])
    sp2 = ep.start_session("x", ctx, cached_hit=True)
    rest = ep.decode({sp2: int(ctx[-1])}, n_steps=n_rest)[sp2]
    assert first + rest == ref
    # the whole paged round-trip moved zero park/resume device bytes
    assert ep.park_copy_bytes == 0 and ep.resume_copy_bytes == 0
    assert ep.pool.audit_blocks() == []


def test_token_identity_dense():
    rng = np.random.RandomState(0)
    prompt = rng.randint(1, CFG.vocab, size=24).astype(np.int32)
    _identity_roundtrip(CFG, PARAMS, prompt)


def test_token_identity_moe_sliding_window():
    cfg = mixtral_8x22b.tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(1)
    prompt = rng.randint(1, cfg.vocab, size=21).astype(np.int32)
    _identity_roundtrip(cfg, params, prompt, n_first=4, n_rest=2)


def test_token_identity_vlm():
    cfg = llava_next_34b.tiny()
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.RandomState(2)
    prompt = rng.randint(1, cfg.vocab, size=19).astype(np.int32)
    _identity_roundtrip(cfg, params, prompt, n_first=4, n_rest=2)


# --- multi-layer fused append+attend entry ---------------------------------
def test_paged_decode_step_matches_ref():
    """ops.paged_decode_step (append the step's K/V, attend all layers)
    must match a manual per-layer scatter + paged_decode_ref."""
    L, B, H, K, dh, NB, blk = 3, 4, 4, 2, 8, 12, 4
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 6)
    q = jax.random.normal(ks[0], (L, B, H, dh), jnp.float32)
    k_new = jax.random.normal(ks[1], (L, B, K, dh), jnp.float32)
    v_new = jax.random.normal(ks[2], (L, B, K, dh), jnp.float32)
    k_pool = jax.random.normal(ks[3], (L, NB, blk, K, dh), jnp.float32)
    v_pool = jax.random.normal(ks[4], (L, NB, blk, K, dh), jnp.float32)
    tables = jnp.asarray([[0, 1, 2], [3, 4, 5], [6, 7, 8], [9, 10, 11]],
                         jnp.int32)
    # lens INCLUDE the just-appended token; row 2 is idle (drop sentinel)
    lens = jnp.asarray([7, 3, 1, 12], jnp.int32)
    ablk = jnp.asarray([1, 3, NB, 11], jnp.int32)     # NB = drop sentinel
    aoff = jnp.asarray([2, 2, 0, 3], jnp.int32)

    out, kp, vp = ops.paged_decode_step(q, k_new, v_new, k_pool, v_pool,
                                        tables, lens, ablk, aoff)
    kp_ref, vp_ref = k_pool, v_pool
    for b in (0, 1, 3):                                # row 2 dropped
        kp_ref = kp_ref.at[:, ablk[b], aoff[b]].set(k_new[:, b])
        vp_ref = vp_ref.at[:, ablk[b], aoff[b]].set(v_new[:, b])
    assert jnp.array_equal(kp, kp_ref) and jnp.array_equal(vp, vp_ref)
    for l in range(L):
        ref = paged_decode_ref(q[l], kp_ref[l], vp_ref[l], tables, lens)
        active = np.asarray(jnp.abs(out[l] - ref).max(axis=(1, 2)))
        for b in (0, 1, 3):
            assert active[b] < 1e-5, f"layer {l} row {b}"


# --- block-lifecycle property test -----------------------------------------
def test_random_lifecycle_interleavings_keep_pool_clean():
    """Random alloc/extend/append/park/resume/import/free interleavings
    under the engine's discipline (bounded residents, bounded session
    length) never break block conservation or exhaust the headroom."""
    L, blk, Kh, dh = 2, 4, 1, 4
    n_slots, max_nb = 3, 4
    max_len = max_nb * blk
    nominal = 6
    pool = PagedKVPool(L, nominal, blk, Kh, dh,
                       headroom_blocks=n_slots * max_nb)
    rng = np.random.RandomState(0)
    resident, parked = [], []
    next_sid = [0]

    def kv(n):
        a = jnp.asarray(rng.randn(L, n, Kh, dh), jnp.bfloat16)
        return a, a

    def check(tag):
        errs = pool.audit_blocks()
        assert errs == [], f"{tag}: {errs}"
        held = sum(len(t) for t in pool.tables.values())
        assert len(pool.free) + held == pool.total_blocks, tag
        assert pool.used_blocks() <= pool.num_blocks, tag

    for step in range(300):
        op = rng.choice(["alloc", "extend", "append", "park", "resume",
                         "import", "free"])
        if op == "alloc" and len(resident) < n_slots:
            sid = f"s{next_sid[0]}"
            next_sid[0] += 1
            pool.alloc(sid)
            resident.append(sid)
        elif op == "extend" and resident:
            sid = resident[rng.randint(len(resident))]
            room = max_len - pool.lens[sid]
            if room:
                k, v = kv(rng.randint(1, room + 1))
                pool.extend(sid, k, v, bucket=blk * 2)
        elif op == "append" and resident:
            sid = resident[rng.randint(len(resident))]
            if pool.lens[sid] < max_len:
                pool.ensure_tail_room(sid)
                pool.append_token(sid)
        elif op == "park" and resident:
            sid = resident[rng.randint(len(resident))]
            if pool.lens[sid] and pool.park_resident(sid):
                resident.remove(sid)
                parked.append(sid)
        elif op == "resume" and parked and len(resident) < n_slots:
            sid = parked[rng.randint(len(parked))]
            pool.mark_resident(sid)
            parked.remove(sid)
            resident.append(sid)
        elif op == "import":                # work-steal migration lands
            sid = f"m{next_sid[0]}"
            next_sid[0] += 1
            n = rng.randint(1, nominal * blk + 1)
            k, v = kv(n)
            if pool.park(sid, k, v, n):
                parked.append(sid)
        elif op == "free" and (resident or parked):
            pop = resident if (resident and
                               (not parked or rng.rand() < 0.5)) \
                else parked
            sid = pop[rng.randint(len(pop))]
            pool.free_session(sid)
            pop.remove(sid)
        check(f"step {step} op {op}")

    for sid in list(pool.tables):
        pool.free_session(sid)
    check("drain")
    assert len(pool.free) == pool.total_blocks


def test_failed_repark_keeps_existing_blocks():
    """Satellite regression: a re-park that does not fit must leave the
    session's previously parked KV intact (the old code freed first and
    lost it)."""
    pool = PagedKVPool(1, num_blocks=3, block_size=4, n_kv_heads=1,
                       head_dim=4)
    k = jnp.ones((1, 8, 1, 4), jnp.bfloat16)
    assert pool.park("a", k, k, 8)           # 2 blocks
    big = jnp.ones((1, 24, 1, 4), jnp.bfloat16)
    assert not pool.park("a", big, big, 24)  # net demand 6-2 > 3-2
    assert pool.has("a") and pool.lens["a"] == 8
    assert pool.audit_blocks() == []


def test_extend_rejects_bucket_splitting_blocks():
    pool = PagedKVPool(1, num_blocks=4, block_size=16, n_kv_heads=1,
                       head_dim=4)
    pool.alloc("s")
    k = jnp.ones((1, 8, 1, 4), jnp.bfloat16)
    with pytest.raises(AssertionError, match="bucket"):
        pool.extend("s", k, k, bucket=24)    # 24 % 16 != 0
    pool.extend("s", k, k, bucket=32)        # lcm quantum: fine


# --- runtime-level byte-identity + zero-copy accounting --------------------
def _mk_requests(n, n_steps=3, seed=0):
    tools = ["code_execution", "web_api", "file_operations"]
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab, size=8))), 4,
                  tools[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def test_runtime_paged_vs_gather_summary_identical():
    """Paged and gather runtimes make bit-identical scheduling decisions
    AND emit bit-identical tokens, so the whole summary repr matches;
    only the device-copy accounting differs (paged park/resume: 0)."""
    outs, stats = [], []
    for paged in (True, False):
        rt = ServingRuntime(CFG, PARAMS, seed=0, n_workers=2, n_slots=2,
                            max_len=256, pool_blocks=96, paged=paged)
        for r in _mk_requests(5):
            rt.submit(r)
        rt.run()
        rt.check_conservation()
        outs.append(repr(rt.summarize()))
        stats.append(rt.stats())
    assert outs[0] == outs[1]
    p, g = stats
    assert p["park_copy_bytes"] == 0 and p["resume_copy_bytes"] == 0
    assert g["park_copy_bytes"] > 0 and g["resume_copy_bytes"] > 0
    assert p["regen_tokens"] == g["regen_tokens"]
