"""Event-driven concurrent serving runtime tests: serial-vs-interleaved
token equivalence, lifecycle conservation, cross-process byte-identical
summaries, prefetch waste accounting, non-asserting engine admission."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.core.aeg import AEG
from repro.core.coordinator import SAGAConfig
from repro.core.prefetch import SpeculativePrefetcher
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.runtime import AgentRequest, ServingRuntime

load_all()
CFG = get_config("micro")
PARAMS = lm.init_params(CFG, jax.random.PRNGKey(0))
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

TOOLS = ["code_execution", "web_api", "file_operations"]


def _mk_requests(n, n_steps=3, seed=0, prompt_len=8, n_out=4):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        steps = [(list(map(int, rng.randint(1, CFG.vocab,
                                            size=prompt_len))),
                  n_out, TOOLS[s % 3], float(rng.uniform(0.05, 0.5)))
                 for s in range(n_steps)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 3}", steps))
    return reqs


def _run(reqs, concurrent, **kw):
    kw.setdefault("n_workers", 2)
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 256)
    kw.setdefault("pool_blocks", 96)
    rt = ServingRuntime(CFG, PARAMS, seed=0, **kw)
    if concurrent:
        for r in reqs:
            rt.submit(r)
        rt.run()
    else:
        for r in reqs:                  # strictly one task in flight
            rt.submit(r, arrival=rt.ev.now)
            rt.run()
    return rt


def test_interleaved_matches_serial():
    """N concurrent sessions through the runtime produce token-for-token
    identical outputs to serial one-at-a-time execution: per-slot decode
    rows are independent and park/resume copies are exact, so continuous
    batching must not change a single token.

    Slots and pool are sized so no session is ever evicted or diverted
    off its KV home — under overload the policies legitimately trade
    regeneration (a re-prefill whose low-order float bits may differ
    from incrementally-decoded KV) for throughput, which is measured by
    the benchmarks, not this exactness gate."""
    reqs = _mk_requests(8)
    serial = _run(_mk_requests(8), concurrent=False, n_slots=16)
    inter = _run(reqs, concurrent=True, n_slots=16)
    assert inter.n_done == len(reqs)
    assert inter.stats()["coordinator_misses"] == len(reqs)  # 1st steps
    for r in reqs:
        a = serial.sessions[r.session_id].step_outputs
        b = inter.sessions[r.session_id].step_outputs
        assert a == b, f"outputs diverged for {r.session_id}"
    # the interleaved run actually batched: fewer forward passes than
    # the sum of per-session decode tokens
    assert inter.summarize()["decode_rounds"] < \
        serial.summarize()["decode_rounds"]


def test_runtime_conservation_under_contention():
    """More sessions than total slots: queueing, AFS admission, steals
    and prefetch copies all fire, and every lifecycle invariant holds at
    quiescence (no leaked slots, blocks, or queue entries)."""
    reqs = _mk_requests(12, n_steps=4, seed=3)
    rt = _run(reqs, concurrent=True, n_slots=2, pool_blocks=48)
    rt.check_conservation()
    rt.verify_pool_mirrors()
    assert rt.n_done == 12
    assert all(s.finished_at >= s.arrival for s in rt.sessions.values())


def test_runtime_conservation_request_level():
    """The no-cache baseline exercises the miss path everywhere and must
    conserve too."""
    saga = SAGAConfig(cache_policy="none", enable_affinity=False,
                      enable_ttl=False, enable_prefetch=False,
                      enable_afs=False, observability="none")
    rt = _run(_mk_requests(6, seed=5), concurrent=True, saga=saga)
    rt.check_conservation()
    assert rt.co.cache_hits == 0


def test_trace_driven_requests_run_and_conserve():
    reqs = runtime_requests(n_sessions=6, vocab=CFG.vocab, seed=2,
                            n_steps=3, max_ctx=200)
    assert len(reqs) == 6 and all(len(r.steps) >= 2 for r in reqs)
    rt = _run(reqs, concurrent=True, n_slots=3, pool_blocks=128)
    rt.check_conservation()


def test_steal_migrates_parked_kv():
    """Asymmetric return bursts (half the sessions on short tool gaps,
    half asleep) build a queue on one engine while the other idles: the
    epoch tick must steal a queued session and migrate its parked KV
    blocks pool-to-pool, and everything still conserves."""
    rng = np.random.RandomState(0)
    reqs = []
    for i in range(8):
        gap = 0.05 if i % 2 == 0 else 10.0
        steps = [(list(map(int, rng.randint(1, CFG.vocab, size=8))), 4,
                  "code_execution", gap) for _ in range(3)]
        reqs.append(AgentRequest(f"s{i}", f"t{i % 2}", steps))
    rt = _run(reqs, concurrent=True, saga=SAGAConfig(theta=5.0))
    rt.check_conservation()
    s = rt.summarize()
    assert s["steals"] >= 1 and s["migrations"] >= 1
    assert s["n_done"] == 8


def test_session_queue_steal_then_reenqueue_no_resurrection():
    """Tombstones live on per-enqueue tickets: re-enqueueing a stolen
    session elsewhere must not revive its lazily-deleted entry in the
    victim's heap (shared-flag version double-admitted and drove the
    queue length negative)."""
    from repro.serving.events import SessionQueue
    from repro.serving.runtime import _QueueTicket
    q0, q1 = SessionQueue(), SessionQueue()
    q0.push(0.0, 0.0, _QueueTicket("s"))
    q0.push(0.0, 0.0, _QueueTicket("other"))
    assert q0.remove("s") is not None        # steal tombstones
    q1.push(0.0, 1.0, _QueueTicket("s"))     # re-enqueue on the thief
    assert q0.pop().session_id == "other"    # stale entry stays dead
    assert q0.pop() is None and len(q0) == 0
    assert q1.pop().session_id == "s" and len(q1) == 0


def test_engine_admission_returns_none_when_full():
    """Non-asserting admission: a full engine reports None so the
    runtime queues instead of crashing."""
    eng = Engine(CFG, PARAMS, n_slots=1, max_len=64, pool_blocks=16)
    prompt = np.arange(1, 9, dtype=np.int32)
    assert eng.start_session("a", prompt, cached_hit=False) == 0
    assert eng.start_session("b", prompt, cached_hit=False) is None
    eng.release_session("a")
    assert eng.start_session("b", prompt, cached_hit=False) == 0


def test_prefetcher_counts_superseded_job_bytes():
    """A prefetch replaced by a newer one for the same session was
    copied for nothing: its bytes must land in wasted_bytes (they used
    to vanish from the accounting)."""
    p = SpeculativePrefetcher(bandwidth_Bps=1e9)
    aeg = AEG.linear_chain(TOOLS)
    assert p.maybe_issue("s", aeg, 0, 100.0, 0.0, 0.0) is not None
    assert p.maybe_issue("s", aeg, 1, 50.0, 1.0, 0.0) is not None
    assert p.wasted_bytes == 100.0
    assert p.issued == 2
    # wrong-node resolve wastes the replacement too
    assert not p.resolve("s", 99, 10.0)
    assert p.wasted_bytes == 150.0
    # cancel() (task finished mid-gap) also counts
    p.maybe_issue("s", aeg, 0, 25.0, 20.0, 0.0)
    p.cancel("s")
    assert p.wasted_bytes == 175.0 and not p.inflight


_RUN_SNIPPET = """
from repro.cluster.workload import runtime_requests
from repro.configs import get_config, load_all
from repro.models import lm
from repro.serving.runtime import ServingRuntime
import jax
load_all()
cfg = get_config("micro")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rt = ServingRuntime(cfg, params, n_workers=2, n_slots=2, max_len=256,
                    pool_blocks=96, seed=0)
for r in runtime_requests(n_sessions=5, vocab=cfg.vocab, seed=4,
                          n_steps=2, max_ctx=200):
    rt.submit(r)
rt.run()
rt.check_conservation()
print(repr(rt.summarize()))
"""


def test_runtime_summary_identical_across_processes():
    """The runtime extends the simulator's determinism contract: two
    identical-seed runs are byte-identical even when the processes
    disagree on PYTHONHASHSEED."""
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _RUN_SNIPPET],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert "tct_mean" in outs[0] and "'n_done': 5" in outs[0]
