"""Trace-conservation suite for the observability layer (repro.obs).

Covers the zero-perturbation contract on BOTH substrates: a traced
run's ``summarize()`` is byte-identical to the untraced run; every
span the substrate opens is closed (properly nested under its
session/step parents); span counts reconcile with lifecycle/event
counts under chaos plans — a cancelled attempt closes its spans with
``status="cancelled"`` instead of leaking them; and the trace bytes
themselves are identical across processes with different
``PYTHONHASHSEED``.  Plus tracer/metrics unit behaviour and the
``report()``/Chrome-trace exporters.
"""
import os
import subprocess
import sys

import pytest

from repro.cluster.baselines import saga, vllm
from repro.cluster.faults import chaos_plan
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload
from repro.obs.export import (chrome_trace, latency_summary, percentile,
                              report)
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import ROOT, Tracer, as_tracer

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# --- tracer unit behaviour --------------------------------------------------
def test_tracer_nesting_and_double_end():
    tr = Tracer()
    ses = tr.begin("session/a", "session", 0.0)
    step = tr.begin("session/a", "step", 0.0, parent=ses, step=0)
    tr.instant("run", "fault", 0.5, kind="fail")
    tr.end(step, 1.0)
    tr.end(ses, 2.0, status="ok")
    with pytest.raises(ValueError):
        tr.end(ses, 3.0)                      # double end
    tr.check_closed()                         # everything closed
    kids = tr.children()
    assert [s.name for s in kids[ROOT]] == ["session", "fault"]
    assert [s.name for s in kids[ses]] == ["step"]
    assert tr.get(step).dur == 1.0
    assert tr.counts() == {"fault": 1, "session": 1, "step": 1}


def test_tracer_check_closed_reports_leaks():
    tr = Tracer()
    tr.begin("session/a", "step", 0.0)
    with pytest.raises(RuntimeError, match="never closed"):
        tr.check_closed()


def test_tracer_end_clamps_negative_duration():
    """A cancellation can land before a future-dated phase would have
    started (serial prefill pipeline): duration clamps to zero."""
    tr = Tracer()
    s = tr.begin("session/a", "decode", 5.0)
    sp = tr.end(s, 3.0, status="cancelled")
    assert sp.t1 == 5.0 and sp.dur == 0.0


def test_as_tracer_normalization():
    tr = Tracer()
    assert as_tracer(tr) is tr
    assert isinstance(as_tracer(True), Tracer)
    assert as_tracer(False) is None and as_tracer(None) is None


def test_metrics_registry_exports():
    m = MetricsRegistry()
    m.counter("steps", worker=1).inc()
    m.counter("steps", worker=0).inc(2)
    m.gauge("depth", worker=0).set(0.1, 3)
    h = m.histogram("lat_s", edges=(0.1, 1.0), window_s=1.0)
    for t, v in ((0.0, 0.05), (0.5, 0.5), (1.5, 2.0)):
        h.observe(t, v)
    assert h.count == 3 and h.counts == [1, 1, 1]
    assert h.quantile(0.5) == 1.0
    assert h.windows == {0: [2, 0.55], 1: [1, 2.0]}
    prom = m.to_prometheus()
    assert '# TYPE steps counter' in prom
    assert 'steps{worker="0"} 2' in prom
    assert 'lat_s_bucket{le="+Inf"} 3' in prom
    # kind mismatch on a registered name is an error
    with pytest.raises(ValueError):
        m.gauge("steps")
    # export order is label-sorted, independent of creation order
    js = m.to_json()
    assert list(js["steps"]["series"]) == ['{worker="0"}',
                                           '{worker="1"}']


def test_percentile_matches_summarize_convention():
    xs = list(range(10))
    assert percentile(xs, 0.99) == 9.0        # min(n-1, int(p*n))
    assert percentile(xs, 0.5) == 5.0
    assert percentile([], 0.5) == 0.0
    assert latency_summary([])["n"] == 0


# --- substrate conservation (simulator) -------------------------------------
def _sim(policy, trace, fault_plan=None, n_tasks=40):
    sim = ClusterSim(
        swebench_workload(n_tasks=n_tasks, rate_per_min=8.0, seed=0),
        policy, n_workers=8, seed=0, trace=trace, fault_plan=fault_plan)
    sim.run(horizon_s=864000)
    sim.check_conservation()
    return sim


def test_sim_traced_summary_identical_and_spans_closed():
    base = _sim(saga(), trace=False)
    traced = _sim(saga(), trace=True)
    assert repr(summarize(base)) == repr(summarize(traced))
    traced.tracer.check_closed()
    counts = traced.tracer.counts()
    # one session span per task, and the tree reconciles with the
    # executed workflow structure: every step got exactly one step span
    assert counts["session"] == len(traced.tasks)
    n_steps = sum(t.n_steps for t in traced.tasks.values())
    assert counts["step"] == n_steps
    assert counts["prefill"] + counts.get("resume", 0) == n_steps
    assert counts["decode"] == n_steps
    # non-terminal steps wait on a tool
    assert counts["tool_gap"] == n_steps - len(traced.tasks)


def test_sim_span_tree_properly_nested():
    traced = _sim(saga(), trace=True, n_tasks=20)
    tr = traced.tracer
    for sp in tr.spans:
        if sp.parent_id == ROOT:
            continue
        par = tr.get(sp.parent_id)
        assert par.track == sp.track
        assert par.t0 <= sp.t0 + 1e-9
        if sp.kind == "span":
            assert sp.t1 <= par.t1 + 1e-9, (sp.name, par.name)


def test_sim_chaos_cancelled_spans_not_leaked():
    plan = chaos_plan(n_workers=8, horizon_s=400.0, seed=1)
    base = _sim(vllm(), trace=False, fault_plan=plan)
    traced = _sim(vllm(), trace=True, fault_plan=plan)
    assert repr(summarize(base)) == repr(summarize(traced))
    traced.tracer.check_closed()                # cancelled, not open
    cancels = traced.tracer.counts().get("cancel", 0)
    assert cancels > 0, "chaos plan injected no cancellations"
    # every cancel instant pairs with a cancelled prefill AND decode
    by = traced.tracer.counts_by_status
    assert by("prefill")["cancelled"] + \
        by("resume").get("cancelled", 0) == cancels
    assert by("decode")["cancelled"] == cancels
    # fault instants reconcile with the plan events that fired
    faults = [sp for sp in traced.tracer.spans if sp.name == "fault"]
    fired = [e for e in plan if e[0] <= traced.now]
    assert len(faults) == len(fired)
    assert [sp.meta["kind"] for sp in faults] == [k for _, k, _ in fired]


def test_sim_trace_bytes_stable_in_process():
    a = _sim(saga(), trace=True, n_tasks=20)
    b = _sim(saga(), trace=True, n_tasks=20)
    assert a.tracer.canonical_bytes() == b.tracer.canonical_bytes()
    assert a.obs_metrics.canonical_bytes() == \
        b.obs_metrics.canonical_bytes()
    assert a.obs_metrics.to_prometheus() == b.obs_metrics.to_prometheus()


# --- substrate conservation (serving runtime) -------------------------------
@pytest.fixture(scope="module")
def rt_model():
    import jax
    from repro.configs import get_config, load_all
    from repro.models import lm
    load_all()
    cfg = get_config("micro")
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def _runtime(rt_model, trace, fault_plan=None):
    from repro.cluster.workload import runtime_requests
    from repro.serving.runtime import ServingRuntime
    cfg, params = rt_model
    rt = ServingRuntime(cfg, params, n_workers=2, n_slots=2, max_len=256,
                        pool_blocks=96, seed=0, trace=trace,
                        fault_plan=fault_plan)
    for r in runtime_requests(n_sessions=5, vocab=cfg.vocab, seed=4,
                              n_steps=2, max_ctx=200):
        rt.submit(r)
    rt.run()
    rt.check_conservation()
    return rt


def test_runtime_traced_summary_identical_and_spans_closed(rt_model):
    base = _runtime(rt_model, trace=False)
    traced = _runtime(rt_model, trace=True)
    assert repr(base.summarize()) == repr(traced.summarize())
    traced.tracer.check_closed()
    counts = traced.tracer.counts()
    assert counts["session"] == len(traced.sessions)
    n_steps = sum(s.step_idx + 1 for s in traced.sessions.values())
    assert counts["step"] == n_steps
    assert counts["decode"] >= n_steps          # preempt resumes add more
    assert counts["round"] == traced.summarize()["decode_rounds"]
    rep = report(traced.tracer)
    assert rep["n_sessions"] == len(traced.sessions)
    assert rep["round_latency"]["n"] == counts["round"]


def test_runtime_chaos_traced_summary_identical(rt_model):
    plan = chaos_plan(n_workers=2, horizon_s=3.0, seed=1)
    base = _runtime(rt_model, trace=False, fault_plan=plan)
    traced = _runtime(rt_model, trace=True, fault_plan=plan)
    assert repr(base.summarize()) == repr(traced.summarize())
    traced.tracer.check_closed()
    cancelled = traced.summarize()["cancelled_attempts"]
    by_cancel = sum(v.get("cancelled", 0)
                    for v in (traced.tracer.counts_by_status("prefill"),
                              traced.tracer.counts_by_status("resume"),
                              traced.tracer.counts_by_status("decode")))
    assert by_cancel == cancelled
    assert traced.tracer.counts().get("cancel", 0) == cancelled


def test_runtime_trace_env_gate(rt_model, monkeypatch):
    from repro.serving.runtime import ServingRuntime
    cfg, params = rt_model
    monkeypatch.delenv("SAGA_TRACE", raising=False)
    assert ServingRuntime(cfg, params, n_workers=1).tracer is None
    monkeypatch.setenv("SAGA_TRACE", "1")
    assert ServingRuntime(cfg, params, n_workers=1).tracer is not None
    monkeypatch.setenv("SAGA_TRACE", "0")
    assert ServingRuntime(cfg, params, n_workers=1).tracer is None


# --- exporters ---------------------------------------------------------------
def test_chrome_trace_export_shape():
    traced = _sim(saga(), trace=True, n_tasks=10)
    doc = chrome_trace(traced.tracer, traced.obs_metrics)
    evs = doc["traceEvents"]
    names = {e["ph"] for e in evs}
    assert {"M", "X", "C"} <= names
    xs = [e for e in evs if e["ph"] == "X"]
    assert all(e["dur"] >= 0 for e in xs)
    # every span event carries its id and the track got a thread name
    tids = {e["tid"] for e in evs if e["ph"] == "M"}
    assert all(e["tid"] in tids for e in xs)


def test_report_phase_decomposition_sums_to_tct():
    traced = _sim(saga(), trace=True, n_tasks=20)
    rep = report(traced.tracer)
    tct_total = rep["tct"]["mean"] * rep["tct"]["n"]
    attributed = sum(rep["phase_totals_s"].values())
    # phases + residual account for every TCT second exactly
    assert attributed == pytest.approx(tct_total, rel=1e-9)
    assert all(v >= 0 for v in rep["phase_totals_s"].values())


def test_export_cli_demo(tmp_path):
    out = tmp_path / "trace.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.obs.export", str(out)],
        env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    assert out.exists()
    assert "phase" not in r.stderr
    assert "wrote" in r.stdout


# --- cross-process / cross-PYTHONHASHSEED byte identity ---------------------
_TRACE_SNIPPET = """
import hashlib
from repro.cluster.baselines import saga
from repro.cluster.faults import chaos_plan
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload
plan = chaos_plan(n_workers=8, horizon_s=400.0, seed=1)
sim = ClusterSim(swebench_workload(n_tasks=40, rate_per_min=8.0, seed=0),
                 saga(), n_workers=8, seed=0, trace=True, fault_plan=plan)
sim.run(horizon_s=864000)
sim.check_conservation()
sim.tracer.check_closed()
print(repr(summarize(sim)))
print(hashlib.sha256(sim.tracer.canonical_bytes()).hexdigest())
print(hashlib.sha256(sim.obs_metrics.canonical_bytes()).hexdigest())
"""


def test_trace_bytes_identical_across_hashseeds():
    """The trace and metric exports extend the summarize() determinism
    contract: byte-identical across processes whose PYTHONHASHSEED
    disagree, even under a chaos plan."""
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _TRACE_SNIPPET],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
