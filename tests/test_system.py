"""End-to-end behaviour tests for the paper's system: the full SAGA
pipeline (AEG -> WA-LRU/TTL -> affinity/stealing -> AFS) against the
paper's qualitative claims."""
import pytest

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload
from repro.core.aeg import AEG, PatternInferencer
from repro.core.belady import BeladyOracle, competitive_ratio, \
    replay_policy
from repro.core.ttl import ToolTTLPolicy


@pytest.fixture(scope="module")
def swe():
    return swebench_workload(n_tasks=80, rate_per_min=4.0, seed=0)


@pytest.fixture(scope="module")
def results(swe):
    out = {}
    for name in ["vllm", "vllm_apc", "saga"]:
        sim = ClusterSim(swe, B.ALL_BASELINES[name](), n_workers=16,
                         seed=0)
        sim.run(horizon_s=36000)
        out[name] = summarize(sim)
    return out


def test_workflow_awareness_beats_prefix_caching(results):
    """§9.2: SAGA < vLLM+APC < vLLM on task completion time."""
    assert results["saga"]["tct_mean"] < results["vllm_apc"]["tct_mean"]
    assert results["vllm_apc"]["tct_mean"] < results["vllm"]["tct_mean"]


def test_regen_time_breakdown_direction(results):
    """Fig 1(a): vLLM spends far more time regenerating than SAGA."""
    assert results["vllm"]["regen_time_frac"] > 0.3
    assert results["saga"]["regen_time_frac"] < 0.25
    assert results["vllm_apc"]["regen_time_frac"] < \
        results["vllm"]["regen_time_frac"]


def test_memory_holds_more_useful_cache_under_saga(results):
    """Fig 1(b) direction: workflow-aware retention keeps more KV
    resident than discard-at-request-end."""
    assert results["saga"]["mem_util"] >= results["vllm"]["mem_util"] * 0.8


def test_slo_attainment_ordering(results):
    assert results["saga"]["slo_attainment"] >= \
        results["vllm"]["slo_attainment"]


def test_throughput_tradeoff_bounded(results):
    """§9.8: SAGA trades some throughput for latency, but completes the
    same task set."""
    assert results["saga"]["n_tasks"] == results["vllm"]["n_tasks"]


def test_pattern_inference_tier_is_between_hints_and_none(swe):
    """Table 5 direction: hints <= pattern <= no-AEG on TCT."""
    small = swe[:50]
    tcts = {}
    for obs in ["hints", "pattern"]:
        sim = ClusterSim(small, B.saga(observability=obs), n_workers=16,
                         seed=0)
        sim.run(horizon_s=36000)
        tcts[obs] = summarize(sim)["tct_mean"]
    sim = ClusterSim(small, B.saga_ablation("affinity"), n_workers=16,
                     seed=0)
    sim.run(horizon_s=36000)
    tcts["none"] = summarize(sim)["tct_mean"]
    assert tcts["hints"] <= tcts["pattern"] * 1.1
    assert tcts["pattern"] <= tcts["none"] * 1.1


def test_competitive_ratio_pipeline():
    """Theorem 3 pipeline: WA-LRU's empirical CR on an agent trace is
    finite, >= 1, and better than LRU's."""
    from tests.test_belady import _agent_trace, _mk_walru
    from repro.core.walru import LRUCache
    trace = _agent_trace(n_tasks=40, steps=12, seed=7)
    cap = 420.0
    opt = BeladyOracle(cap).replay(trace)
    wal = replay_policy(trace, _mk_walru(cap, trace),
                        ttl_policy=ToolTTLPolicy())
    lru = replay_policy(trace, LRUCache(cap))
    cr_wal = competitive_ratio(wal, opt)
    cr_lru = competitive_ratio(lru, opt)
    assert 1.0 <= cr_wal <= cr_lru
