"""Golden-equivalence suite: the scripted Task adapter must reproduce
the PRE-redesign simulator byte-for-byte.

The summary strings below were captured from the simulator BEFORE the
AgentProgram API landed (commit be4899f: ``ClusterSim`` consumed raw
``Task`` objects).  Every quantity is pure-Python float arithmetic plus
one numpy integer division, so the bytes are stable across platforms —
a mismatch means the adapter path changed scheduling behaviour, which
breaks the ROADMAP determinism contract.

(The serving runtime's adapter equivalence is covered structurally in
``tests/test_workflow_runtime.py`` — request-vs-program dual runs — and
its cross-process identity by ``test_runtime_summary_identical_across_
processes``.)
"""
from repro.cluster import baselines as B
from repro.cluster.faults import chaos_plan
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import burstgpt_workload, swebench_workload

GOLDEN_SAGA_SWE = (
    "{'n_tasks': 19, 'tct_mean': 739.0524923335296, 'tct_p50': "
    "422.7220788048555, 'tct_p99': 4466.624000224905, 'ideal_mean': "
    "384.6751990812226, 'slo_attainment': 0.7894736842105263, "
    "'slo_by_tenant': {'tenant0': 0.7894736842105263}, 'mem_util': "
    "0.2817345680288517, 'regen_time_frac': 0.4008425215716565, "
    "'throughput_tasks_per_min': 0.2445521413816167, 'cache_hit_rate': "
    "0.782565130260521, 'migrations_per_task': 0.05263157894736842, "
    "'evict_rate': 0.20224719101123595, 'regen_tokens_total': "
    "36348421.0072245}")

GOLDEN_VLLM_SWE = (
    "{'n_tasks': 19, 'tct_mean': 1290.0062485175908, 'tct_p50': "
    "914.5125871185885, 'tct_p99': 4883.932462000449, 'ideal_mean': "
    "384.6751990812226, 'slo_attainment': 0.10526315789473684, "
    "'slo_by_tenant': {'tenant0': 0.10526315789473684}, 'mem_util': "
    "0.29475903644317236, 'regen_time_frac': 0.6312115793851865, "
    "'throughput_tasks_per_min': 0.22445844823434444, 'cache_hit_rate': "
    "0.0, 'migrations_per_task': 0.0, 'evict_rate': 0.0, "
    "'regen_tokens_total': 90782212.22184642}")

GOLDEN_SAGA_PATTERN_SWE = (
    "{'n_tasks': 19, 'tct_mean': 847.7723649591358, 'tct_p50': "
    "404.77629309050764, 'tct_p99': 4466.857145421547, 'ideal_mean': "
    "384.6751990812226, 'slo_attainment': 0.6842105263157895, "
    "'slo_by_tenant': {'tenant0': 0.6842105263157895}, 'mem_util': "
    "0.31081080743759104, 'regen_time_frac': 0.44915912962008386, "
    "'throughput_tasks_per_min': 0.24453991092023403, 'cache_hit_rate': "
    "0.7294589178356713, 'migrations_per_task': 0.05263157894736842, "
    "'evict_rate': 0.25638406537282943, 'regen_tokens_total': "
    "44226559.03450646}")

GOLDEN_SAGA_CHAOS_BG = (
    "{'n_tasks': 38, 'tct_mean': 362.16117997913085, 'tct_p50': "
    "433.26503111575124, 'tct_p99': 782.5263294843527, 'ideal_mean': "
    "301.72803218232355, 'slo_attainment': 1.0, 'slo_by_tenant': "
    "{'light': 1.0, 'heavy': 1.0, 'medium': 1.0}, 'mem_util': "
    "0.28623766841722176, 'regen_time_frac': 0.06910628896836163, "
    "'throughput_tasks_per_min': 2.7422168043172155, 'cache_hit_rate': "
    "0.9359861591695502, 'migrations_per_task': 0.0, 'evict_rate': "
    "0.040354767184035474, 'regen_tokens_total': 5947291.609522446}")


def _swe():
    return swebench_workload(n_tasks=20, rate_per_min=4.0, seed=0)


def _run(tasks, policy, n_workers, seed, plan=None):
    sim = ClusterSim(tasks, policy, n_workers=n_workers, seed=seed,
                     fault_plan=plan)
    sim.run(horizon_s=36000)
    sim.check_conservation()
    return repr(summarize(sim))


def test_golden_saga_swebench():
    assert _run(_swe(), B.saga(), 4, 0) == GOLDEN_SAGA_SWE


def test_golden_request_level_swebench():
    assert _run(_swe(), B.vllm(), 4, 0) == GOLDEN_VLLM_SWE


def test_golden_pattern_inference_swebench():
    assert _run(_swe(), B.saga("pattern"), 4, 1) == \
        GOLDEN_SAGA_PATTERN_SWE


def test_golden_saga_chaos_burstgpt():
    bg = burstgpt_workload(horizon_s=120.0, seed=0, load_factor=0.2)
    plan = chaos_plan(6, 600.0, n_events=10, seed=2)
    assert _run(bg, B.saga(), 6, 3, plan) == GOLDEN_SAGA_CHAOS_BG
