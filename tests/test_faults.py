"""Fault-correctness regression tests for the execution lifecycle:
in-flight step cancellation on worker failure, dead-worker stealing /
migration, conservation under chaos, deterministic routing."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

import repro.cluster
from repro.cluster import baselines as B
from repro.cluster.faults import chaos_plan, preemption_storm_plan, \
    straggler_plan
from repro.cluster.perf import PerfModel
from repro.cluster.simulator import ClusterSim, _fnv1a, summarize
from repro.cluster.workload import Step, Task, make_task, \
    scale_workload, swebench_workload
from repro.core.afs import AFSScheduler, TaskProgress
from repro.core.coordinator import GlobalCoordinator, SAGAConfig
from repro.core.stealing import WorkStealer

SRC = str(Path(repro.cluster.__file__).resolve().parents[2])


def _tiny_tasks(n=4, steps=3, seed=0):
    """Identical-arrival short tasks: deterministic contention."""
    import random
    rng = random.Random(seed)
    return [make_task(f"t{i}", f"ten{i % 2}", "burstgpt", 0.0, rng,
                      n_steps=steps) for i in range(n)]


# --- conservation under chaos ------------------------------------------------
@pytest.mark.parametrize("mode", ["session", "least", "group", "sticky"])
def test_chaos_conservation(mode):
    """Every admitted task finishes exactly once under random
    fail/recover/scale-up injection; no job strands on a dead worker,
    no negative slot/KV accounting (violations raise mid-run).  All
    four routing modes exercise their own liveness fallbacks."""
    tasks = swebench_workload(n_tasks=40, rate_per_min=8.0, seed=2)
    plan = chaos_plan(8, horizon_s=900.0, n_events=14, seed=3)
    assert any(k == "fail" for _, k, _ in plan)     # chaos actually chaotic
    pol = B.saga()
    pol.routing = mode
    sim = ClusterSim(tasks, pol, n_workers=8, seed=0, fault_plan=plan)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == len(tasks)


@pytest.mark.parametrize("mode", ["session", "least", "group", "sticky"])
def test_straggler_conservation(mode):
    """Transient stragglers (slow/heal plan events) slow service but
    must not break the lifecycle: every task finishes exactly once and
    all accounting returns to zero."""
    tasks = swebench_workload(n_tasks=30, rate_per_min=10.0, seed=4)
    horizon = max(t.arrival_s for t in tasks) + 60.0
    plan = straggler_plan(8, horizon_s=horizon, n_stragglers=3,
                          slow_for_s=90.0, seed=7)
    assert any(k == "slow" for _, k, _ in plan)
    pol = B.saga()
    pol.routing = mode
    sim = ClusterSim(tasks, pol, n_workers=8, seed=0, fault_plan=plan)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == len(tasks)


@pytest.mark.parametrize("mode", ["session", "least", "group", "sticky"])
def test_preemption_storm_conservation(mode):
    """Mass simultaneous worker kills (spot reclamation): the displaced
    in-flight and queued steps all land on survivors and every task
    still finishes exactly once."""
    tasks = scale_workload(8, tasks_per_worker=4.0, seed=6,
                           horizon_s=300.0, burst_frac=0.5)
    plan = preemption_storm_plan(8, horizon_s=300.0, n_storms=2,
                                 kill_frac=0.5, downtime_s=45.0, seed=9)
    fails_by_t = {}
    for t, k, _ in plan:
        if k == "fail":
            fails_by_t[t] = fails_by_t.get(t, 0) + 1
    assert fails_by_t and max(fails_by_t.values()) >= 2, \
        "storm must kill several workers at the same instant"
    pol = B.saga()
    pol.routing = mode
    sim = ClusterSim(tasks, pol, n_workers=8, seed=0, fault_plan=plan)
    sim.run(horizon_s=86400)
    sim.check_conservation()
    assert summarize(sim)["n_tasks"] == len(tasks)


def test_straggler_actually_slows_service():
    """A permanently slow worker must stretch its steps' service time
    (the injection is real, not a no-op)."""
    from repro.cluster.faults import StragglerInjector
    tasks = _tiny_tasks(n=2, steps=2)
    base = ClusterSim(tasks, B.saga(), n_workers=1, seed=0)
    base.run(horizon_s=86400)
    slow = ClusterSim(tasks, B.saga(), n_workers=1, seed=0,
                      straggler=StragglerInjector({0: 4.0}))
    slow.run(horizon_s=86400)
    slow.check_conservation()
    assert summarize(slow)["tct_mean"] > summarize(base)["tct_mean"]


# --- incremental AFS ---------------------------------------------------------
def test_incremental_vs_full_afs_equivalence():
    """Property test: after any interleaving of add/progress/finish
    events, the incremental column path returns bit-identical shares to
    a fresh full rebuild (``recompute_full``)."""
    import random as _random
    for seed in range(5):
        rng = _random.Random(seed)
        afs = AFSScheduler()
        live = []
        next_id = 0
        now = 0.0
        for step in range(400):
            now += rng.uniform(0.0, 0.3)
            r = rng.random()
            if r < 0.45 or not live:
                tid = f"t{next_id}"
                next_id += 1
                afs.add_task(TaskProgress(
                    tid, f"ten{rng.randrange(6)}",
                    deadline=now + rng.uniform(0.05, 50.0),
                    work_remain_s=rng.uniform(0.0, 20.0)))
                live.append(tid)
            elif r < 0.75:
                afs.note_progress(rng.choice(live),
                                  rng.uniform(0.0, 5.0))
            else:
                afs.finish_task(live.pop(rng.randrange(len(live))))
            if step % 7 == 0:
                reference = afs.recompute_full(now)
                incremental = afs.recompute(now)
                assert incremental == reference, (seed, step)
        # drain everything: zero-task recompute stays consistent too
        for tid in live:
            afs.finish_task(tid)
        assert afs.recompute(now + 1.0) == afs.recompute_full(now + 1.0)


def test_afs_compaction_preserves_shares():
    """Mass finishes trigger tombstone compaction; shares must stay
    bit-identical to the full rebuild through it."""
    afs = AFSScheduler()
    for i in range(300):
        afs.add_task(TaskProgress(f"t{i}", f"ten{i % 4}",
                                  deadline=100.0 + i, work_remain_s=1.0 + i))
    for i in range(280):                  # force compaction
        afs.finish_task(f"t{i}")
    assert afs._n < 300, "compaction never ran"
    assert afs.recompute(3.0) == afs.recompute_full(3.0)


# --- indexed idle-worker set -------------------------------------------------
def test_idle_set_matches_queue_state_mid_run():
    """At every pause point, the stealer's indexed idle set holds
    exactly the live workers with empty pending queues, and the
    nonempty-queue index is its complement."""
    tasks = swebench_workload(n_tasks=24, rate_per_min=30.0, seed=8)
    plan = chaos_plan(6, horizon_s=300.0, n_events=10, seed=2)
    perf = PerfModel(max_batch=2)         # force queueing
    sim = ClusterSim(tasks, B.saga(), n_workers=6, perf=perf, seed=0,
                     fault_plan=plan)
    for h in (5.0, 30.0, 90.0, 200.0, 86400.0):
        sim.run(horizon_s=h)
        idle = set(sim.co.stealer.idle_since)
        expect_idle = {w for w, ws in enumerate(sim.workers)
                       if ws.alive and not ws.queue}
        assert idle == expect_idle, (h, idle, expect_idle)
        expect_nonempty = {w for w, ws in enumerate(sim.workers)
                           if ws.queue}
        assert sim._nonempty == expect_nonempty, h
    sim.check_conservation()


def test_fail_cancels_inflight_steps():
    """A worker failure cancels the steps running on it: their llm_done
    events become stale no-ops, the steps requeue on live workers, and
    the task still finishes exactly once."""
    tasks = _tiny_tasks(n=3, steps=3)
    sim = ClusterSim(tasks, B.saga(), n_workers=2, seed=0)
    sim.run(horizon_s=0.5)            # arrivals processed, steps running
    assert sim.inflight, "expected in-flight steps at t=0.5s"
    victim_w = next(iter(sim.inflight.values())).worker
    cancelled = sorted(t for t, r in sim.inflight.items()
                       if r.worker == victim_w)
    sim._on_fail(victim_w)
    # cancelled steps left the registry or restarted on the live worker
    for tid in cancelled:
        rec = sim.inflight.get(tid)
        assert rec is None or rec.worker != victim_w
    assert sim.workers[victim_w].active == 0
    assert sim.workers[victim_w].active_kv == 0.0
    sim.run(horizon_s=86400)          # stale llm_done events drain safely
    sim.check_conservation()


def test_all_workers_dead_terminates():
    """A cluster-wide blackout with no recovery scheduled must let
    run() return (orphans parked, unfinished tasks visible) instead of
    livelocking on self-perpetuating epoch ticks."""
    tasks = _tiny_tasks(n=2, steps=2)
    plan = [(0.5, "fail", 0), (0.5, "fail", 1)]
    sim = ClusterSim(tasks, B.saga(), n_workers=2, seed=0,
                     fault_plan=plan)
    sim.run(horizon_s=86400)              # must terminate
    assert any(m.finish < 0 for m in sim.metrics.values())
    with pytest.raises(RuntimeError):
        sim.check_conservation()


def test_run_noop_after_completion():
    """run() on a completed sim must not process the leftover epoch
    event — staged-horizon runs stay byte-identical to one-shot runs."""
    tasks = _tiny_tasks(n=2, steps=2)
    sim = ClusterSim(tasks, B.saga(), n_workers=2, seed=0)
    sim.run(horizon_s=86400)
    snap = (sim.now, len(sim.mem_samples), sim.events_processed)
    sim.run(horizon_s=86400)
    assert (sim.now, len(sim.mem_samples), sim.events_processed) == snap
    staged = ClusterSim(tasks, B.saga(), n_workers=2, seed=0)
    for h in (1.0, 5.0, 86400, 86400):
        staged.run(horizon_s=h)
    assert summarize(staged) == summarize(sim)


def test_fail_charges_regeneration():
    """Steps retried after a crash pay cache-loss regeneration."""
    tasks = swebench_workload(n_tasks=12, rate_per_min=20.0, seed=5)
    horizon = max(t.arrival_s for t in tasks) + 30.0
    plan = [(horizon * 0.4, "fail", 0), (horizon * 0.4, "fail", 1)]
    sim_f = ClusterSim(tasks, B.saga(), n_workers=4, seed=0,
                       fault_plan=plan)
    sim_f.run(horizon_s=86400)
    sim_f.check_conservation()
    sim_c = ClusterSim(tasks, B.saga(), n_workers=4, seed=0)
    sim_c.run(horizon_s=86400)
    assert summarize(sim_f)["regen_tokens_total"] >= \
        summarize(sim_c)["regen_tokens_total"]


# --- dead-worker stealing / migration ---------------------------------------
def test_dead_worker_never_thief_or_victim():
    ws = WorkStealer(t_idle_s=0.1, r_max=2.0)
    # worker 0 is dead and 'idle'; worker 2 is a live idle thief
    ws.note_queue_state(0, empty=True, now=0.0)
    ws.note_queue_state(2, empty=True, now=0.0)
    q = [(0.0, "sess")]
    d = ws.maybe_steal(0.2, [0.0, 1.0, 0.0], [[], q, []],
                       alive=[False, True, True])
    assert d is not None and d.thief == 2
    # dead victim is excluded even with a (stale) non-empty queue
    d2 = ws.maybe_steal(0.4, [0.0, 1.0, 0.0], [[], q, []],
                        alive=[True, False, True])
    assert d2 is None
    # thief death between decision and acceptance is rejected
    assert not ws.accept(d, victim_queue_len=1, now=0.5,
                         thief_alive=False)


def test_migration_to_dead_worker_requeues_live():
    """migr_done arriving after the destination died re-routes the job
    to a live worker instead of parking it on the corpse."""
    tasks = _tiny_tasks(n=4, steps=3)
    perf = PerfModel(max_batch=1)     # force queueing
    sim = ClusterSim(tasks, B.saga(), n_workers=2, perf=perf, seed=0)
    sim.run(horizon_s=0.2)
    src = next((w for w in range(2) if len(sim.workers[w].queue)), None)
    assert src is not None, "expected a queued step under max_batch=1"
    job = sim.workers[src].queue.peek()
    sid = job.task.task_id
    dst = 1 - src
    # emulate an accepted steal whose destination dies mid-transfer
    assert sim._queue_remove(src, sid) is not None
    sim.migrating[sid] = dst
    sim._on_fail(dst)
    sim._on_migr_done(sid, job.step_idx, src, dst)
    assert sid not in sim.migrating
    assert len(sim.workers[dst].queue) == 0 and \
        sim.workers[dst].active == 0
    sim.run(horizon_s=86400)
    sim.check_conservation()


def test_migrated_job_lands_with_real_afs_priority():
    """The migration landing path computes the tenant's actual AFS
    priority and inserts in order — no hardcoded 0.0 bypass."""
    tasks = _tiny_tasks(n=4, steps=3)
    perf = PerfModel(max_batch=1)
    sim = ClusterSim(tasks, B.saga(), n_workers=2, perf=perf, seed=0)
    sim.run(horizon_s=0.2)
    sim.co.afs.recompute(sim.now)
    src = next(w for w in range(2) if len(sim.workers[w].queue))
    job = sim.workers[src].queue.peek()
    sid, tenant = job.task.task_id, job.task.tenant
    dst = 1 - src
    assert sim._queue_remove(src, sid) is not None
    sim.migrating[sid] = dst
    sim._on_migr_done(sid, job.step_idx, src, dst)
    expect = -sim.co.afs.priority(tenant)
    assert expect != 0.0              # tenant has real pending work
    landed = [(p, j) for p, _, _, j in sim.workers[dst].queue._heap
              if j.task.task_id == sid and not j.cancelled]
    if landed:                        # queued (dst busy): priority is real
        assert landed[0][0] == expect
    else:                             # admitted straight into a slot
        assert sim.inflight[sid].worker == dst
    sim.run(horizon_s=86400)
    sim.check_conservation()


# --- pin lifecycle -----------------------------------------------------------
def test_hit_entries_unpinned_on_step_end_and_finish():
    co = GlobalCoordinator(SAGAConfig(), 2, 1e9)
    co.register_task("s", "t", ["a"] * 3, 100.0, 10.0, 0.0)
    co.on_step_end("s", 0, 200.0, 1000.0, "a", 1.0)
    hit, extra, bg = co.on_step_start("s", 0, 300.0, 2.0)
    assert hit and co.pools[0].entries["s"].pinned
    co.on_step_end("s", 0, 300.0, 1500.0, "a", 3.0)
    assert not co.pools[0].entries["s"].pinned
    hit, _, _ = co.on_step_start("s", 0, 400.0, 4.0)
    assert hit and co.pools[0].entries["s"].pinned
    co.task_finished("s", 5.0)
    assert not co.pools[0].contains("s")


# --- deterministic routing ---------------------------------------------------
def test_fnv1a_reference_vectors():
    # standard 64-bit FNV-1a vectors
    assert _fnv1a("") == 0xCBF29CE484222325
    assert _fnv1a("a") == 0xAF63DC4C8601EC8C
    assert _fnv1a("foobar") == 0x85944171F73967E8


_RUN_SNIPPET = """
import sys
from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload
pol = B.saga()
pol.routing = sys.argv[1]
tasks = swebench_workload(n_tasks=10, rate_per_min=30.0, seed=5)
sim = ClusterSim(tasks, pol, n_workers=4, seed=1)
sim.run(horizon_s=86400)
print(repr(summarize(sim)))
"""


@pytest.mark.parametrize("mode", ["session", "least", "group", "sticky"])
def test_summary_identical_across_processes(mode):
    """Identical-seed runs are byte-identical even when the processes
    disagree on PYTHONHASHSEED (the old group router hashed with the
    randomized builtin ``hash``)."""
    outs = []
    for hashseed in ("0", "424242"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = hashseed
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", _RUN_SNIPPET, mode],
                           env=env, capture_output=True, text=True,
                           timeout=300)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    assert "tct_mean" in outs[0]
