"""Per-arch smoke tests (deliverable f): REDUCED same-family configs run
one forward/train step on CPU; output shapes + no NaNs.  Full configs
are exercised only by the dry-run (ShapeDtypeStruct, no allocation)."""
import importlib

import jax
import jax.numpy as jnp
import pytest

from repro import models as Mo
from repro.models.sharding import ShardingEnv

ARCH_MODULES = [
    "jamba_v0_1_52b", "deepseek_v2_236b", "mixtral_8x22b",
    "command_r_35b", "mistral_nemo_12b", "qwen3_32b", "llama3_2_3b",
    "llava_next_34b", "rwkv6_7b", "seamless_m4t_large_v2",
]

ENV = ShardingEnv(None, opts={"remat": False, "sp": False,
                              "moe_impl": "dense"})


def _tiny(mod_name):
    return importlib.import_module(f"repro.configs.{mod_name}").tiny()


def _batch(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.enc_dec:
        return {"frames": jax.random.normal(
                    key, (B, 24, cfg.d_model), jnp.bfloat16) * 0.02,
                "tgt_tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "tgt_labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(
                    key, (B, 8, cfg.d_model), jnp.bfloat16) * 0.02,
                "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("mod", ARCH_MODULES)
def test_forward_loss_finite(mod):
    cfg = _tiny(mod)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    loss = Mo.forward_train(params, _batch(cfg), cfg, ENV)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{cfg.name} loss not finite"


@pytest.mark.parametrize("mod", ARCH_MODULES)
def test_train_step_no_nans(mod):
    cfg = _tiny(mod)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: Mo.forward_train(p, batch, cfg, ENV))(params)
    assert bool(jnp.isfinite(loss))
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (cfg.name, path)


@pytest.mark.parametrize("mod", ARCH_MODULES)
def test_logits_shape(mod):
    cfg = _tiny(mod)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = Mo.forward_logits(params, batch, cfg, ENV)
    B = 2
    if cfg.enc_dec:
        S = batch["tgt_tokens"].shape[1]
    elif cfg.family == "vlm":
        S = batch["patches"].shape[1] + batch["tokens"].shape[1]
    else:
        S = batch["tokens"].shape[1]
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("mod", ARCH_MODULES)
def test_prefill_decode_matches_full_forward(mod):
    """Serving-path correctness: prefill(S-1) + decode(1) == forward(S)."""
    cfg = _tiny(mod)
    params = Mo.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, S = 2, 12
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, 16, cfg.d_model),
                                   jnp.bfloat16) * 0.02
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full = Mo.forward_logits(params, {"frames": frames,
                                          "tgt_tokens": toks}, cfg, ENV)
        last, cache = Mo.prefill(params, {"frames": frames,
                                          "tgt_tokens": toks[:, :S - 1]},
                                 cfg, ENV, max_len=S + 2)
    elif cfg.family == "vlm":
        patches = jax.random.normal(key, (B, 8, cfg.d_model),
                                    jnp.bfloat16) * 0.02
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full = Mo.forward_logits(params, {"patches": patches,
                                          "tokens": toks}, cfg, ENV)
        last, cache = Mo.prefill(params, {"patches": patches,
                                          "tokens": toks[:, :S - 1]},
                                 cfg, ENV, max_len=8 + S + 2)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        full = Mo.forward_logits(params, {"tokens": toks}, cfg, ENV)
        last, cache = Mo.prefill(params, {"tokens": toks[:, :S - 1]},
                                 cfg, ENV, max_len=S + 2)
    assert float(jnp.max(jnp.abs(last[:, 0] - full[:, -2]))) < 1e-2

    pos = (8 + S - 1) if cfg.family == "vlm" else (S - 1)
    logits, _ = Mo.decode_step(params, toks[:, S - 1:S], cache,
                               jnp.int32(pos), cfg, ENV)
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))) < 2e-2
