"""Sharding-rule tests: divisibility pruning + per-arch rule coverage."""
import importlib

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, load_all
from repro.models import lm
from repro.models.sharding import ShardingEnv

load_all()


def fake_env(pod=False):
    env = ShardingEnv(None)
    env.axis_sizes = ({"pod": 2, "data": 16, "model": 16} if pod
                      else {"data": 16, "model": 16})
    return env


def test_spec_prunes_indivisible_dims():
    env = fake_env()
    # seamless vocab is not divisible by 16 -> pruned to None
    assert env.spec((256206, 1024), ["model", None]) == P(None, None)
    assert env.spec((151936, 1024), ["model", None]) == P("model", None)
    # multi-axis want keeps only the divisible prefix
    assert env.spec((256,), [("data", "model")]) == P(("data", "model"))
    assert env.spec((32,), [("data", "model")]) == P("data")
    assert env.spec((24,), [("data", "model")]) == P(None)


def test_batch_axes_single_vs_multipod():
    assert fake_env().batch_axes == ("data",)
    assert fake_env(pod=True).batch_axes == ("pod", "data")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_rules_cover_every_leaf(arch):
    """Every parameter leaf gets a wish list of the right rank, and 2D+
    weight matrices are 2D-sharded (FSDP x TP) where divisible."""
    cfg = get_config(arch)
    env = fake_env()
    rules = lm.param_rules(cfg, env)
    import jax
    ab = lm.abstract_params(cfg)
    n_sharded = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(ab)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        wants = rules(key, leaf.shape)
        assert len(wants) == len(leaf.shape), key
        spec = env.spec(leaf.shape, wants)
        shard_factor = 1
        for dim, s in zip(leaf.shape, spec):
            if s is not None:
                n_sharded += 1
                axes = (s,) if isinstance(s, str) else s
                f = 1
                for a in axes:
                    f *= env.axis_sizes[a]
                assert dim % f == 0, (key, dim, s)
    assert n_sharded > 0, "no parameter sharded at all"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "llava-next-34b"])
def test_indivisible_heads_fall_back_to_head_dim(arch):
    """24/56 q heads don't divide tp=16: rules must shard head_dim."""
    cfg = get_config(arch)
    env = fake_env()
    assert not env.heads_shardable(cfg.n_heads)
    rules = lm.param_rules(cfg, env)
    wants = rules("layers/attn/wq", (cfg.n_layers, cfg.d_model,
                                     cfg.n_heads, cfg.head_dim))
    assert wants[-2] is None and wants[-1] == "model"


def test_moe_ep_vs_tp_decision():
    env = fake_env()
    assert env.moe_ep(160)      # deepseek: 160 % 16 == 0 -> EP
    assert env.moe_ep(16)       # jamba
    assert not env.moe_ep(8)    # mixtral: d_ff TP fallback
