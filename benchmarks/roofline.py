"""Roofline table generator: reads dry-run JSONs and prints/saves the
per-(arch x shape) three-term roofline analysis (§Roofline)."""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import RESULTS, emit, save_json

DRYRUN = RESULTS / "dryrun"


def load_cells(tag: str = "baseline", mesh: str = "single"):
    cells = []
    for f in sorted(DRYRUN.glob(f"{tag}.*.{mesh}.json")):
        r = json.loads(f.read_text())
        cells.append(r)
    return cells


def bottleneck_sentence(r) -> str:
    dom = r["roofline"]["dominant"]
    kind = r["kind"]
    if dom == "collective":
        return ("collective-bound: FSDP weight all-gathers dominate; "
                "replicate weights over 'data' for serving, or overlap "
                "gathers with compute")
    if dom == "memory":
        if kind == "decode":
            return ("HBM-bound: KV-cache reads dominate (inherent to "
                    "decode); quantize KV or batch more sequences")
        return ("HBM-bound: online-softmax accumulator + remat traffic; "
                "fuse attention inner loop (Pallas) / larger blocks")
    return "compute-bound: good — push useful-flops ratio toward 1"


def table(cells):
    rows = []
    for r in cells:
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:90]})
            continue
        row = {
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "kind": r["kind"],
            "mem_gb": r["memory"]["peak_per_device_gb"],
            "fits_16gb": r["memory"].get("fits_hbm_16gb"),
        }
        if "roofline" in r:
            rf = r["roofline"]
            row.update({
                "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
                "collective_s": rf["collective_s"],
                "dominant": rf["dominant"],
                "useful_flops_ratio": r.get("useful_flops_ratio", 0.0),
                "roofline_fraction": (rf["compute_s"] /
                                      max(rf["step_lower_bound_s"], 1e-12)),
                "what_to_do": bottleneck_sentence(r),
            })
        rows.append(row)
    return rows


def main():
    t0 = time.time()
    for mesh in ["single", "multi"]:
        cells = load_cells(mesh=mesh)
        rows = table(cells)
        save_json(f"roofline_{mesh}", rows)
        ok = [r for r in rows if r.get("status") == "ok"]
        skip = [r for r in rows if r.get("status") == "skip"]
        err = [r for r in rows if r.get("status") == "error"]
        wall = time.time() - t0
        emit(f"roofline/{mesh}_cells", wall,
             f"ok={len(ok)} skip={len(skip)} err={len(err)}")
        if mesh == "single":
            for r in ok:
                if "dominant" not in r:
                    continue
                emit(f"roofline/{r['arch']}/{r['shape']}", 0,
                     f"dom={r['dominant']} "
                     f"c={r['compute_s']:.3f}s m={r['memory_s']:.3f}s "
                     f"i={r['collective_s']:.3f}s "
                     f"useful={r['useful_flops_ratio']:.2f} "
                     f"mem={r['mem_gb']:.1f}GB "
                     f"frac={r['roofline_fraction']:.2f}")


if __name__ == "__main__":
    main()
