"""Theorem 2 empirical validation: AFS's urgency-proportional allocation
is a restoring drift on the service deviation — V(t) = sum_i e_i(t)^2
must trend DOWN when tenants start unevenly served, and completion-time
deviation stays bounded."""
from __future__ import annotations

import random
import time

from repro.core.afs import AFSScheduler, TaskProgress

from benchmarks.common import emit, save_json


def simulate(n_tenants=8, epochs=400, capacity=8.0, seed=0,
             rho=3.0):
    """Epoch loop: allocate capacity ∝ AFS shares, serve, repeat.
    Tenants have heterogeneous workloads (max/min = rho).  Inject an
    initial imbalance and track the Lyapunov function."""
    rng = random.Random(seed)
    afs = AFSScheduler()
    workloads = {}
    for i in range(n_tenants):
        w = 100.0 * (1.0 + (rho - 1.0) * i / (n_tenants - 1))
        workloads[f"t{i}"] = w
        afs.add_task(TaskProgress(f"task{i}", f"t{i}", deadline=2000.0,
                                  work_remain_s=w))
    # initial imbalance: tenant 0 pre-served (service AND progress)
    afs.note_service("t0", 30.0)
    afs.note_progress("task0", 30.0)
    vs = []
    t0 = 0.0
    for ep in range(epochs):
        now = ep * 1.0
        shares = afs.recompute(now)
        for ten, share in shares.items():
            grant = share * capacity
            afs.note_service(ten, grant)
            task = f"task{list(workloads).index(ten)}"
            afs.note_progress(task, grant)
        vs.append(afs.lyapunov_v(now + 1.0, t0, capacity, workloads))
    return vs


def main():
    t0 = time.time()
    vs = simulate()
    early = sum(vs[5:25]) / 20
    late = sum(vs[-20:]) / 20
    # restoring drift: V decreases from the injected imbalance
    head = vs[1]
    trough = min(vs[:100])
    out = {"v_initial": head, "v_trough": trough, "v_early": early,
           "v_late": late, "restored": trough < 0.5 * head}
    save_json("thm2_drift", out)
    wall = time.time() - t0
    emit("thm2/lyapunov_drift", wall,
         f"V(1)={head:.1f} -> min V={trough:.1f} "
         f"({'NEGATIVE DRIFT CONFIRMED' if out['restored'] else 'no drift'}) "
         "— urgency-proportional allocation restores underserved tenants")


if __name__ == "__main__":
    main()
