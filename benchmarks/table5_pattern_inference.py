"""Table 5: framework hints vs pattern inference vs no AEG — TCT +
next-step prediction accuracy on held-out traces."""
from __future__ import annotations

import time

from repro.cluster import baselines as B
from repro.cluster.workload import swebench_workload
from repro.core.aeg import PatternInferencer

from benchmarks.common import emit, mean_std, run_seeds, save_json


def aeg_accuracy(seed=0) -> float:
    tasks = swebench_workload(n_tasks=300, rate_per_min=10.0, seed=seed)
    train, held = tasks[:240], tasks[240:]
    inf = PatternInferencer(min_tasks=30)
    for t in train:
        inf.record_trace(t.tools())
    return inf.accuracy([t.tools() for t in held])


def main():
    t0 = time.time()
    seeds = (0, 1)
    rows = {}
    for mode, fn in [("hints", lambda: B.saga("hints")),
                     ("pattern", lambda: B.saga("pattern")),
                     ("no_aeg", lambda: B.saga_ablation("affinity"))]:
        r = run_seeds(fn, "swebench", 200, seeds)
        tct, std = mean_std(r["tct_mean"])
        rows[mode] = {"tct": tct, "std": std}
    base = rows["hints"]["tct"]
    for mode in rows:
        rows[mode]["vs_hints"] = f"+{(rows[mode]['tct'] / base - 1) * 100:.1f}%"
    acc = aeg_accuracy()
    rows["pattern"]["aeg_accuracy"] = acc
    save_json("table5_pattern_inference", rows)
    wall = time.time() - t0
    emit("table5/hints", wall / 3, f"tct={rows['hints']['tct']:.0f}s")
    emit("table5/pattern", wall / 3,
         f"tct={rows['pattern']['tct']:.0f}s {rows['pattern']['vs_hints']} "
         f"acc={acc:.2f} (paper +15.6%, acc .87)")
    emit("table5/no_aeg", wall / 3,
         f"tct={rows['no_aeg']['tct']:.0f}s {rows['no_aeg']['vs_hints']} "
         f"(paper +95.8%)")


if __name__ == "__main__":
    main()
