"""Table 10: sensitivity to tool-latency variance (CV scaling with the
mean held ~constant): TCT, TTL accuracy, eviction rate."""
from __future__ import annotations

import time

from repro.cluster import baselines as B
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload

from benchmarks.common import emit, save_json

PAPER = {0.5: "+0/96%/9%", 1.0: "ref/93%/12%", 1.5: "+12%/88%/18%",
         2.0: "+24%/82%/24%", 3.0: "+53%/71%/35%"}


def ttl_accuracy(sim) -> float:
    """Fraction of tool calls whose actual latency fell inside the TTL
    the policy would have granted (no premature expiry)."""
    ttl = sim.co.ttl
    hit = tot = 0
    for tool, hist in ttl.hist.items():
        for lat in hist[-300:]:
            tot += 1
            if lat <= ttl.ttl(tool, 0.0):
                hit += 1
    return hit / max(tot, 1)


def main():
    t0 = time.time()
    rows = {}
    base_tct = None
    for cv in [0.5, 1.0, 1.5, 2.0, 3.0]:
        tasks = swebench_workload(n_tasks=150, rate_per_min=5.0, seed=0,
                                  cv_scale=cv)
        sim = ClusterSim(tasks, B.saga(), n_workers=16, seed=0)
        sim.run(horizon_s=86400)
        s = summarize(sim)
        acc = ttl_accuracy(sim)
        rows[cv] = {"tct": s["tct_mean"], "ttl_accuracy": acc,
                    "evict_rate": s["evict_rate"]}
        if cv == 1.0:
            base_tct = s["tct_mean"]
    for cv, r in rows.items():
        r["vs_cv1"] = f"{(r['tct'] / base_tct - 1) * 100:+.0f}%"
    save_json("table10_tool_variance", rows)
    wall = time.time() - t0
    for cv, r in rows.items():
        emit(f"table10/cv_{cv}", wall / 5,
             f"tct={r['tct']:.0f}s ({r['vs_cv1']}) "
             f"ttl_acc={r['ttl_accuracy']:.2f} evict={r['evict_rate']:.2f} "
             f"(paper {PAPER[cv]})")


if __name__ == "__main__":
    main()
