"""§9.1.2: CPU-DRAM swap as an alternative architecture — the paper's
three quantitative reasons for HBM retention, recomputed from our
constants (PCIe Gen4 x16 ~25 GB/s sustained; Table 1 tool latencies)."""
from __future__ import annotations

import time

from repro.cluster.workload import TOOL_LATENCY_TABLE

from benchmarks.common import emit, save_json

PCIE_GBPS = 25e9            # practical sustained, A100 servers (§9.1.2)
CACHE_GB = 10.7e9           # Llama-3-70B @32K GQA session


def main():
    t0 = time.time()
    one_way = CACHE_GB / PCIE_GBPS
    round_trip = 2 * one_way
    contended = 2 * round_trip          # <50% bandwidth under load (§9.1.2)
    rows = {"round_trip_s": round_trip, "contended_s": contended,
            "tools": {}}
    slower = 0
    for tool, (p50, p95, p99) in TOOL_LATENCY_TABLE.items():
        swap_is_pure_overhead = p50 < round_trip
        slower += swap_is_pure_overhead
        rows["tools"][tool] = {
            "p50_s": p50, "p95_s": p95,
            "swap_pure_overhead_at_p50": swap_is_pure_overhead,
            "breakeven_vs_contended": p95 < contended,
        }
    save_json("swap_analysis", rows)
    wall = time.time() - t0
    emit("swap/round_trip", wall / 2,
         f"{round_trip * 1e3:.0f}ms uncontested, {contended * 1e3:.0f}ms "
         "contended (paper ~860ms/~1.7s)")
    emit("swap/verdict", wall / 2,
         f"{slower}/4 tool classes complete faster than the swap round "
         "trip at P50 (paper: 3/4) -> HBM retention + predictive "
         "eviction, swap only for >95% oversubscription")


if __name__ == "__main__":
    main()
