"""Table 8: BFS / DFS / hybrid execution strategies — TCT vs throughput
vs eviction rate (the latency/throughput tradeoff, §9.8)."""
from __future__ import annotations

import time

from repro.cluster import baselines as B
from repro.cluster.perf import PerfModel
from repro.cluster.simulator import ClusterSim, summarize
from repro.cluster.workload import swebench_workload

from benchmarks.common import emit, save_json

PAPER = {"pure_bfs": (487.2, 12.4, 0.78), "pure_dfs": (623.1, 4.2, 0.03),
         "hybrid": (203.4, 8.7, 0.12)}


def main():
    t0 = time.time()
    # reduced scale (32 GPUs = 8 workers) like the paper, pressured pool
    tasks = swebench_workload(n_tasks=150, rate_per_min=7.0, seed=0)
    perf = PerfModel(kv_pool_bytes=60e9)
    rows = {}
    for strat, admission in [("bfs", None), ("dfs", 10), ("hybrid", 60)]:
        pol = B.strategy(strat)
        if admission is not None:
            pol.admission_max_tasks = admission
        sim = ClusterSim(tasks, pol, n_workers=8, perf=perf, seed=0)
        sim.run(horizon_s=86400)
        s = summarize(sim)
        rows[pol.name] = {"tct": s["tct_mean"],
                          "throughput": s["throughput_tasks_per_min"],
                          "evict_rate": s["evict_rate"]}
    save_json("table8_strategy", rows)
    wall = time.time() - t0
    for name, r in rows.items():
        p = PAPER.get(name, ("-", "-", "-"))
        emit(f"table8/{name}", wall / 3,
             f"tct={r['tct']:.0f}s thr={r['throughput']:.1f}/min "
             f"evict={r['evict_rate']:.2f} "
             f"(paper {p[0]}s/{p[1]}tm/{p[2]})")
    # headline: hybrid trades throughput for TCT
    if rows["hybrid"]["tct"] < rows["pure_bfs"]["tct"]:
        emit("table8/tradeoff", wall,
             f"hybrid tct best; bfs thr/hybrid thr="
             f"{rows['pure_bfs']['throughput'] / max(rows['hybrid']['throughput'], 1e-9):.2f}x"
             " (paper ~1.43x)")


if __name__ == "__main__":
    main()
