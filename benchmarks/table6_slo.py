"""Table 6: multi-tenant SLO attainment by tenant class (BurstGPT-derived
workload, 10 tenants: 3 heavy / 4 medium / 3 light)."""
from __future__ import annotations

import time

from repro.cluster import baselines as B

from benchmarks.common import emit, mean_std, run_seeds, save_json

SYSTEMS = ["vllm", "sglang", "llumnix", "saga"]
PAPER = {"vllm": (89.4, 72.1, 43.2, 67.3),
         "sglang": (91.2, 78.6, 51.4, 73.4),
         "llumnix": (92.8, 81.3, 58.9, 77.2),
         "saga": (99.1, 99.4, 98.7, 99.2)}


def main():
    t0 = time.time()
    seeds = (0, 1)
    rows = {}
    for name in SYSTEMS:
        r = run_seeds(B.ALL_BASELINES[name], "burstgpt", 60, seeds)
        per = {"heavy": [], "medium": [], "light": []}
        for row in r["_rows"]:
            for k in per:
                if k in row["slo_by_tenant"]:
                    per[k].append(row["slo_by_tenant"][k])
        overall, _ = mean_std(r["slo_attainment"])
        rows[name] = {k: mean_std(v)[0] if v else 0.0
                      for k, v in per.items()}
        rows[name]["overall"] = overall
    save_json("table6_slo", rows)
    wall = time.time() - t0
    for name in SYSTEMS:
        r = rows[name]
        p = PAPER[name]
        emit(f"table6/{name}", wall / 4,
             f"heavy={r['heavy']:.2f} med={r['medium']:.2f} "
             f"light={r['light']:.2f} overall={r['overall']:.2f} "
             f"(paper {p[0]}/{p[1]}/{p[2]}/{p[3]}%)")


if __name__ == "__main__":
    main()
