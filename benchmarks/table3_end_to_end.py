"""Table 3: end-to-end TCT + memory utilization, 7 systems x 2 agent
benchmarks, multiple seeds, Welch's t-test vs each baseline."""
from __future__ import annotations

import time

from repro.cluster import baselines as B

from benchmarks.common import emit, geo_mean, mean_std, run_seeds, \
    save_json, stars, welch_t

SYSTEMS = ["vllm", "vllm_apc", "sglang", "llumnix", "trt_scaffolding",
           "kvflow", "saga"]


def run(seeds=(0, 1, 2), n_tasks=250):
    out = {}
    for wl in ["swebench", "webarena"]:
        out[wl] = {}
        for name in SYSTEMS:
            out[wl][name] = run_seeds(B.ALL_BASELINES[name], wl, n_tasks,
                                      seeds)
    return out


def main():
    t0 = time.time()
    res = run()
    wall = time.time() - t0
    table = {}
    for wl in res:
        table[wl] = {}
        saga_tct = res[wl]["saga"]["tct_mean"]
        for name in SYSTEMS:
            tm, ts = mean_std(res[wl][name]["tct_mean"])
            mm, ms = mean_std(res[wl][name]["mem_util"])
            row = {"tct_mean": tm, "tct_std": ts, "mem": mm,
                   "mem_std": ms}
            if name != "saga":
                sp = [a / b for a, b in
                      zip(res[wl][name]["tct_mean"], saga_tct)]
                row["speedup_vs_saga"], _ = mean_std(sp)
                t, df, p = welch_t(res[wl][name]["tct_mean"], saga_tct)
                row["welch_p"] = p
                row["sig"] = stars(p)
            table[wl][name] = row
    # geometric-mean headline (paper: 1.64x vs vLLM+APC)
    gm = geo_mean([table[wl]["vllm_apc"]["speedup_vs_saga"]
                   for wl in table])
    gm_vllm = geo_mean([table[wl]["vllm"]["speedup_vs_saga"]
                        for wl in table])
    table["headline"] = {"geo_mean_vs_apc": gm,
                         "geo_mean_vs_vllm": gm_vllm}
    save_json("table3_end_to_end", {"raw": {
        wl: {k: {kk: vv for kk, vv in v.items() if kk != "_rows"}
             for k, v in res[wl].items()} for wl in res},
        "table": table})
    for wl in ["swebench", "webarena"]:
        for name in SYSTEMS:
            r = table[wl][name]
            d = (f"tct={r['tct_mean']:.0f}±{r['tct_std']:.0f}s "
                 f"mem={r['mem']:.2f}")
            if name != "saga":
                d += (f" saga_speedup={r['speedup_vs_saga']:.2f}x"
                      f"{r['sig']}")
            emit(f"table3/{wl}/{name}", wall / 14, d)
    emit("table3/geomean_vs_apc", wall,
         f"{gm:.2f}x (paper 1.64x); vs vllm {gm_vllm:.2f}x (paper ~2.5x)")


if __name__ == "__main__":
    main()
